"""Substrate: optimizer, checkpointing, fault tolerance, compression,
data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TokenPipelineConfig, token_batch
from repro.optim.adam import AdamConfig, adam_update, init_adam, schedule
from repro.optim.compress import compressed_psum, ef_state, quantize, dequantize
from repro.train import checkpoint as ckpt
from repro.train.fault import DataSkipper, Heartbeat, StragglerDetector, elastic_mesh_shapes


def test_adam_converges_quadratic():
    cfg = AdamConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init_adam(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp p^2
        params, state, _ = adam_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_shape():
    cfg = AdamConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, 0)) < 0.15
    assert float(schedule(cfg, 10)) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(cfg, 100)) == pytest.approx(0.1, abs=1e-3)


def test_clipping_applied():
    cfg = AdamConfig(lr=0.1, clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = init_adam(params)
    _, _, metrics = adam_update(cfg, params, {"w": jnp.array([100.0, 0, 0])}, state)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


# -- checkpointing ----------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
    ckpt.save(str(tmp_path), 7, tree, extra={"loss": 1.5})
    steps = ckpt.list_steps(str(tmp_path))
    assert steps == [7]
    restored, extra = ckpt.restore(str(tmp_path), 7, tree)
    assert extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_skips_incomplete(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # simulate a crash mid-write of step 3: no .complete marker
    bad = tmp_path / "step_00000003"
    bad.mkdir()
    (bad / "manifest.json").write_text("{broken")
    hit = ckpt.restore_latest(str(tmp_path), tree)
    assert hit is not None and hit[0] == 2


def test_checkpoint_prune(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(4)})


# -- fault tolerance ---------------------------------------------------------


def test_heartbeat_detects_dead():
    hb = Heartbeat(timeout_s=10)
    hb.beat(0, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead_workers([0, 1], now=112.0) == [0]
    assert hb.dead_workers([0, 1, 2], now=112.0) == [0, 2]


def test_straggler_detector_flags_slow_worker():
    det = StragglerDetector(k_sigma=3.0, patience=3)
    flagged = False
    for i in range(20):
        flagged = det.observe(0, 1.0 + 0.01 * np.sin(i))
    assert not flagged
    for _ in range(3):
        flagged = det.observe(0, 5.0)
    assert flagged


def test_elastic_mesh_shapes():
    assert elastic_mesh_shapes(256, 16) == (16, 16)
    assert elastic_mesh_shapes(240, 16) == (15, 16)  # lost a host: shrink data
    assert elastic_mesh_shapes(512, 16) == (32, 16)


def test_data_skipper_deterministic():
    cfg = TokenPipelineConfig(vocab=101, seq_len=16, global_batch=4)
    sk = DataSkipper(seed=0)
    ids = [sk.next_batch_id() for _ in range(5)]
    sk2 = DataSkipper(seed=0)
    sk2.skip_to(3)
    assert sk2.next_batch_id() == 3
    b3a = token_batch(cfg, 3)
    b3b = token_batch(cfg, 3)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    assert not np.array_equal(token_batch(cfg, 3)["tokens"], token_batch(cfg, 4)["tokens"])


# -- gradient compression -----------------------------------------------------


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_compressed_psum_error_feedback():
    """Error feedback: accumulated compressed updates converge to the true
    sum (residual is recycled, not lost)."""
    try:
        from jax import shard_map
    except ImportError:  # older jax keeps it under experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("pod",))
    rng = np.random.default_rng(1)
    grads = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
    errors = ef_state(grads)

    def step(g, e):
        return compressed_psum(g, e, "pod")

    fn = shard_map(step, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for i in range(30):
        g = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        mean, errors = fn(g, errors)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(mean["w"])
    # single-step error is ~scale/2; accumulated error stays bounded by one
    # quantization step (not 30x), proving the feedback works
    resid = np.abs(total_true - total_comp).max()
    assert resid < 0.1, resid


def test_master_weights_adam_matches_f32_updates():
    """bf16 params + f32 master track plain f32 Adam closely."""
    cfg = AdamConfig(lr=0.05, warmup_steps=1, total_steps=50, weight_decay=0.0,
                     clip_norm=100.0)
    w0 = jnp.array([1.0, -2.0, 0.5])
    p_f32 = {"w": w0}
    s_f32 = init_adam(p_f32)
    p_bf16 = {"w": w0.astype(jnp.bfloat16)}
    s_mw = init_adam(p_bf16, master_weights=True)
    for _ in range(50):
        g = jax.tree.map(lambda p: 2 * p.astype(jnp.float32), p_f32)
        p_f32, s_f32, _ = adam_update(cfg, p_f32, g, s_f32)
        g2 = jax.tree.map(lambda p: 2 * p.astype(jnp.float32), p_bf16)
        p_bf16, s_mw, _ = adam_update(cfg, p_bf16, g2, s_mw)
    assert p_bf16["w"].dtype == jnp.bfloat16
    err = float(jnp.abs(s_mw["master"]["w"] - p_f32["w"]).max())
    assert err < 5e-2, err
