"""Property tests: every sparse format aggregates identically to the dense
oracle, and all conversions round-trip."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ROW_MAJOR,
    ZMORTON,
    aggregate,
    coo_from_dense,
    coo_to_bcsr,
    coo_to_csb,
    coo_to_csc,
    coo_to_csr,
    coo_to_scv,
    coo_to_scv_tiles,
    csc_to_coo,
    csr_to_coo,
)


def _dense(seed, m, n, density):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    density=st.floats(0.0, 0.3),
    block=st.sampled_from([4, 8, 16]),
    f=st.sampled_from([1, 5, 32]),
)
def test_all_formats_match_dense(seed, m, n, density, block, f):
    a = _dense(seed, m, n, density)
    coo = coo_from_dense(a)
    z = np.random.default_rng(seed + 1).standard_normal((n, f)).astype(np.float32)
    ref = a @ z
    formats = [
        coo,
        coo_to_csr(coo),
        coo_to_csc(coo),
        coo_to_bcsr(coo, block),
        coo_to_scv(coo, block, ROW_MAJOR),
        coo_to_scv(coo, block, ZMORTON),
        coo_to_scv_tiles(coo, block),
    ]
    for fmt in formats:
        out = np.asarray(aggregate(fmt, jnp.asarray(z)))
        np.testing.assert_allclose(out, ref, atol=5e-4, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    m=st.integers(1, 60),
    n=st.integers(1, 60),
    density=st.floats(0.0, 0.4),
    block=st.sampled_from([4, 8]),
)
def test_roundtrips(seed, m, n, density, block):
    a = _dense(seed, m, n, density)
    coo = coo_from_dense(a)
    assert np.allclose(csr_to_coo(coo_to_csr(coo)).to_dense(), a)
    assert np.allclose(csc_to_coo(coo_to_csc(coo)).to_dense(), a)
    for order in (ROW_MAJOR, ZMORTON):
        scv = coo_to_scv(coo, block, order)
        assert np.allclose(scv.to_coo().dedup().to_dense(), a)
        assert scv.nnz == coo.nnz
    tiles = coo_to_scv_tiles(coo, block)
    assert np.allclose(tiles.to_coo().dedup().to_dense(), a)
    assert tiles.nnz == coo.nnz


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    m=st.integers(2, 64),
    block=st.sampled_from([4, 8, 16]),
)
def test_csb_column_major_within_block(seed, m, block):
    """SCV discipline: entries within a block are stored column-major."""
    a = _dense(seed, m, m, 0.2)
    coo = coo_from_dense(a)
    csb = coo_to_csb(coo, block, block)
    for b in range(csb.n_blocks):
        s, e = csb.blk_ptr[b], csb.blk_ptr[b + 1]
        key = csb.col_id[s:e].astype(np.int64) * block + csb.row_id[s:e]
        assert np.all(np.diff(key) > 0), "within-block order must be (col, row)"


def test_scv_index_bits():
    a = _dense(0, 128, 128, 0.05)
    scv = coo_to_scv(coo_from_dense(a), 64, ZMORTON)
    assert scv.index_bits_per_entry == 6  # log2(64) < log2(128*128)


def test_tiles_row_grouping_invariant():
    """Kernel schedule invariant: equal tile_row values are contiguous."""
    a = _dense(3, 200, 180, 0.03)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16)
    tr = tiles.tile_row
    # each row id appears in exactly one contiguous run
    change = np.flatnonzero(np.diff(tr) != 0)
    runs = np.split(tr, change + 1)
    seen = set()
    for run in runs:
        v = run[0]
        assert v not in seen
        seen.add(v)
