"""Sharding rule resolution: divisibility fallback, conflict handling, and
validity of every arch's param specs on a tiny mesh."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.train import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # single-device container: a 1x1 mesh exercises the full code path
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_divisibility_fallback(mesh):
    # every dim divides 1 -> all rules apply
    spec = shd._resolve((16, 32), ("embed", "mlp"), shd.PARAM_RULES, mesh)
    assert spec == P("data", "model")


def test_resolve_conflict_first_dim_wins(mesh):
    # expert and mlp both want "model": expert (first) wins, mlp drops
    spec = shd._resolve((8, 16, 32), ("expert", "embed", "mlp"), shd.PARAM_RULES, mesh)
    assert spec == P("model", "data", None)


def test_resolve_indivisible_drops():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = shd._resolve((4, 128), ("kv_heads", "head_dim"), shd.PARAM_RULES, FakeMesh())
    assert spec == P(None, None)  # kv=4 cannot shard 16 ways
    spec2 = shd._resolve((48, 128), ("heads", "head_dim"), shd.PARAM_RULES, FakeMesh())
    assert spec2 == P("model", None)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_shardings_build_for_all_archs(arch, mesh):
    spec = ARCHS[arch]
    shapes = jax.eval_shape(lambda: spec.init(jax.random.PRNGKey(0))[0])
    _, axes = spec.init(jax.random.PRNGKey(0), reduced=True)
    shardings = shd.make_param_sharding(mesh, shapes, axes)
    n = len(jax.tree.leaves(shardings))
    assert n == len(jax.tree.leaves(shapes))


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.ones((4, 8))
    assert shd.constrain(x, ("batch", "embed")) is x
