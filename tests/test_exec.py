"""The plan executor (core/exec.py): decision rule, ShardedPlan pytree
contract, single-device placement equivalence, and — in an 8-fake-device
subprocess (flags must be set before jax initializes) — multi-device
parity: bucketed shard_map == single-device bucketed == jnp reference bit
for bit, Z-sharded == replicated, engine sharded composite == unsharded
engine, and grad parity through the sharded path for all four model
kinds."""
import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    PlanExecutor,
    ShardedPlan,
    ShardingDecision,
    coo_to_scv_tiles,
    decide_sharding,
    load_imbalance,
    plan_from_tiles,
    plan_from_tiles_bucketed,
    split_equal_nnz,
)
from repro.core.aggregate import aggregate, aggregate_scv_plan
from repro.core.dist import DistributedGraph, distribute_plan
from repro.simul.datasets import gcn_normalize, powerlaw_graph


# ---------------------------------------------------------------------------
# decision rule
# ---------------------------------------------------------------------------
def test_decide_sharding_axes():
    # plenty of nnz AND a 2-way-splittable feature width: the byte model
    # balances both axes (t4f2 moves fewer bytes/device than t8f1 — the
    # allreduce term grows with tp while the gather term shrinks)
    assert decide_sharding(10**6, 256, 8) == ShardingDecision("2d", 4, 2)
    # tiny graph, wide features: all devices to the feature axis
    assert decide_sharding(100, 1024, 8) == ShardingDecision("features", 1, 8)
    # both floors bind partway: 2-D
    d = decide_sharding(20_000, 256, 8)
    assert d.kind == "2d" and d.tile_parts == 4 and d.feature_parts == 2
    # the feature floor is one full kernel feature block: a 512-col Z only
    # splits 4 ways even with devices to spare
    assert decide_sharding(100, 512, 8).feature_parts == 4
    # nothing to shard
    assert decide_sharding(10, 4, 8).kind == "replicated"
    assert decide_sharding(10**6, 256, 1).kind == "replicated"
    # a known row count sharpens the model: dense-ish graphs (high avg
    # degree -> small out slab) tilt back toward pure tile spans
    dense = decide_sharding(10**6, 256, 8, n_rows=2_000)
    assert dense.tile_parts > decide_sharding(10**6, 256, 8).tile_parts // 2


def test_placement_bytes_model():
    from repro.core.exec import placement_bytes

    pb = placement_bytes(10**6, 256, 4, 2, n_rows=125_000)
    # components add up, and the psum term vanishes at tp == 1
    assert pb["total"] == pb["plan"] + pb["z_gather"] + pb["out"] + pb[
        "collective"
    ]
    assert pb["resident"] == pb["plan"] + pb["z_slab"] + pb["out"]
    assert placement_bytes(10**6, 256, 1, 2)["collective"] == 0
    # the tile axis divides plan + gather; the feature axis divides slabs
    half = placement_bytes(10**6, 256, 8, 2, n_rows=125_000)
    assert half["plan"] == pb["plan"] / 2 and half["z_gather"] == pb[
        "z_gather"
    ] / 2
    assert half["out"] == pb["out"]


def test_decision_validation():
    with pytest.raises(ValueError):
        ShardingDecision("2d", 1, 4)  # 2d needs both axes > 1
    with pytest.raises(ValueError):
        ShardingDecision("replicated", 2, 1)
    with pytest.raises(ValueError):
        ShardingDecision("sideways", 2, 1)
    # degenerate 1-span tile placement is legal (distribute_plan(n_parts=1))
    ShardingDecision("tiles", 1, 1)


def test_decision_signature_stable():
    assert ShardingDecision("2d", 4, 2).signature == "2d:t4f2"


# ---------------------------------------------------------------------------
# placement on one device (mesh (1, 1)): pure layout equivalence
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def graph_and_plans():
    adj = gcn_normalize(powerlaw_graph(500, 3000, seed=0))
    tiles = coo_to_scv_tiles(adj, 32, cap=64)
    return (
        adj,
        plan_from_tiles(tiles),
        plan_from_tiles_bucketed(tiles, caps=(8, 32, 64)),
    )


def test_distribute_plan_accepts_bucketed(graph_and_plans):
    """The PR-4 TypeError escape hatch is gone: bucketed plans place."""
    adj, plan, bplan = graph_and_plans
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((adj.shape[1], 16)).astype(np.float32))
    ref = np.asarray(aggregate_scv_plan(plan, z, backend="jnp"))
    for p in (plan, bplan):
        sp = distribute_plan(p, 1)
        assert isinstance(sp, DistributedGraph)  # == ShardedPlan
        assert len(sp.segments) == (len(bplan.segments) if p is bplan else 1)
        out = np.asarray(aggregate_scv_plan(sp, z, backend="jnp"))
        np.testing.assert_allclose(out, ref, atol=1e-4)
        # format dispatch through the generic entry point too
        out2 = np.asarray(aggregate(sp, z, backend="jnp"))
        np.testing.assert_allclose(out2, ref, atol=1e-4)


def test_sharded_plan_pytree_roundtrip(graph_and_plans):
    _, _, bplan = graph_and_plans
    sp = distribute_plan(bplan, 1)
    leaves, treedef = jax.tree.flatten(sp)
    sp2 = jax.tree.unflatten(treedef, leaves)
    assert sp2.decision == sp.decision and sp2.mesh == sp.mesh
    assert sp2.caps == sp.caps and sp2.shape == sp.shape


def test_sharded_plan_reweighted(graph_and_plans):
    adj, _, bplan = graph_and_plans
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal((adj.shape[1], 8)).astype(np.float32))
    ev = jnp.asarray(rng.standard_normal(adj.nnz).astype(np.float32))
    ref = np.asarray(aggregate_scv_plan(bplan.reweighted(ev), z, backend="jnp"))
    sp = distribute_plan(bplan, 1)
    out = np.asarray(aggregate_scv_plan(sp.reweighted(ev), z, backend="jnp"))
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_load_imbalance_per_segment(graph_and_plans):
    _, plan, bplan = graph_and_plans
    part = split_equal_nnz(bplan, 4)
    per = load_imbalance(part, per_segment=True)
    assert len(per) == len(bplan.segments) and all(r >= 1.0 for r in per)
    # the flat aggregate is nnz-weighted across segments, not the mean of
    # the per-segment ratios — both views must be available
    flat = load_imbalance(part)
    assert flat >= 1.0
    # single-cap partitions report a 1-tuple
    assert len(load_imbalance(split_equal_nnz(plan, 4), per_segment=True)) == 1
    # the placed plan exposes the same breakdown
    sp = distribute_plan(bplan, 1)
    assert len(sp.imbalance_per_segment) == len(bplan.segments)
    assert sp.imbalance == pytest.approx(1.0)  # one part holds everything


def test_prepare_replicated_is_identity(graph_and_plans):
    _, plan, _ = graph_and_plans
    ex = PlanExecutor()
    assert ex.prepare(plan, decision=ShardingDecision("replicated")) is plan


# ---------------------------------------------------------------------------
# 8 fake devices: the real multi-device parity matrix (subprocess)
# ---------------------------------------------------------------------------
PARITY_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import (PlanExecutor, ShardingDecision, coo_to_scv_tiles,
                        plan_from_tiles_bucketed)
from repro.core.aggregate import aggregate_scv_plan
from repro.core.dist import aggregate_distributed, distribute_plan
from repro.core.formats import COOMatrix
from repro.kernels.scv_spmm.ref import scv_spmm_reference_plan
from repro.simul.datasets import powerlaw_graph

res = {}
adj = powerlaw_graph(700, 5000, seed=0)
rng = np.random.default_rng(0)
# integer-valued inputs: psum/segment reassociation stays exact in f32
adj = COOMatrix(adj.rows, adj.cols,
                rng.integers(-3, 4, adj.nnz).astype(np.float32), adj.shape)
tiles = coo_to_scv_tiles(adj, 32, cap=64)
bplan = plan_from_tiles_bucketed(tiles, caps=(8, 32, 64))
z = jnp.asarray(rng.integers(-3, 4, (adj.shape[1], 48)).astype(np.float32))
single = np.asarray(aggregate_scv_plan(bplan, z, backend="jnp"))
ref = np.asarray(scv_spmm_reference_plan(bplan, z))[: adj.shape[0]]
res["single_eq_ref"] = bool((single == ref).all())

ex = PlanExecutor()
for dec in (ShardingDecision("tiles", 8, 1),
            ShardingDecision("features", 1, 8),
            ShardingDecision("2d", 4, 2)):
    sp = ex.prepare(bplan, decision=dec)
    out = np.asarray(aggregate_scv_plan(sp, z, backend="jnp"))
    res[f"bit_{dec.kind}"] = bool((out == single).all())
    res[f"imb_{dec.kind}"] = sp.imbalance

# compat entry point (bucketed through distribute_plan/aggregate_distributed)
g = distribute_plan(bplan, 8)
res["bit_dist_api"] = bool(
    (np.asarray(aggregate_distributed(g, z)) == single).all()
)

# the Pallas kernel body under shard_map (interpret mode): span padding
# repeats the last tile's coordinates and unvisited strips are masked, so
# the kernel path agrees bit for bit too
sp = ex.prepare(bplan, decision=ShardingDecision("tiles", 8, 1))
out_k = np.asarray(aggregate_scv_plan(sp, z, backend="pallas_interpret"))
single_k = np.asarray(aggregate_scv_plan(bplan, z, backend="pallas_interpret"))
res["bit_pallas"] = bool((out_k == single_k).all())
res["bit_pallas_vs_ref"] = bool((out_k == single).all())
print(json.dumps(res))
'''


GNN_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core.exec import PlanExecutor, ShardingDecision
from repro.models.gnn import GNNConfig, build_graph, gnn_forward_jit, init_gnn
from repro.serve.graph_engine import (GraphEngineConfig, GraphRequest,
                                      GraphServeEngine)
from repro.simul.datasets import gcn_normalize, powerlaw_graph

res = {}
rng = np.random.default_rng(0)
adj = gcn_normalize(powerlaw_graph(400, 2400, seed=1))
x = jnp.asarray(rng.standard_normal((adj.shape[0], 16)).astype(np.float32))
ex = PlanExecutor(min_nnz_per_part=64, min_features_per_part=4)

for kind in ("gcn", "sage", "gin", "gat"):
    cfg = GNNConfig(name=kind, kind=kind, d_in=16, d_hidden=16, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    g = build_graph(adj, tile=64, bucket_caps=(8, 32, 64))
    g_sharded = ex.prepare_graph(g, decision=ShardingDecision("2d", 4, 2))
    out = np.asarray(gnn_forward_jit(params, cfg, g, x))
    out_s = np.asarray(gnn_forward_jit(params, cfg, g_sharded, x))
    res[f"fwd_{kind}"] = float(np.abs(out - out_s).max())

    def loss(p, graph):
        return jnp.sum(gnn_forward_jit(p, cfg, graph, x) ** 2)

    gr = jax.grad(loss)(params, g)
    gr_s = jax.grad(loss)(params, g_sharded)
    res[f"grad_{kind}"] = max(
        float(np.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gr_s))
    )

# engine: over-threshold composites route through the executor
adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=i))
        for i, n in enumerate([300, 500, 800])]
cfg = GNNConfig(name="gcn", kind="gcn", d_in=16, d_hidden=16, n_classes=4)
params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
xs = [rng.standard_normal((a.shape[0], 16)).astype(np.float32) for a in adjs]

def serve(ecfg, executor=None):
    eng = GraphServeEngine({"gcn": (params, cfg)}, ecfg, executor=executor)
    for i, (a, xi) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=xi, model="gcn"))
    eng.run()
    return eng, {r.rid: r.out for r in eng.completed}

base = dict(tile=64, max_batch_nodes=2048, node_buckets=(512, 1024, 2048))
_, plain = serve(GraphEngineConfig(**base))
eng, shard = serve(
    GraphEngineConfig(**base, shard_nnz_threshold=1000),
    executor=PlanExecutor(min_nnz_per_part=256, min_features_per_part=8),
)
res["engine_sharded_batches"] = eng.metrics()["sharded_batches"]
res["engine_err"] = max(
    float(np.abs(plain[r] - shard[r]).max()) for r in plain
)
# hot oversized batch: the cached composite reuses its sharded layout
h0 = eng.plan_cache.stats.hits
for i, (a, xi) in enumerate(zip(adjs, xs)):
    eng.submit(GraphRequest(rid=10 + i, adj=a, x=xi, model="gcn"))
eng.run()
res["engine_repeat_hits"] = eng.plan_cache.stats.hits - h0
print(json.dumps(res))
'''


def _run(script):
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=".", timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_sharded_aggregation_parity_8_devices():
    """Bucketed shard_map == single-device bucketed == jnp reference, bit
    for bit, for tile-span, feature-axis, and 2-D sharding."""
    r = _run(PARITY_SCRIPT)
    assert r["single_eq_ref"], r
    for kind in ("tiles", "features", "2d"):
        assert r[f"bit_{kind}"], r
        assert r[f"imb_{kind}"] < 1.5, r
    assert r["bit_dist_api"], r
    assert r["bit_pallas"] and r["bit_pallas_vs_ref"], r


def test_sharded_gnn_and_engine_8_devices():
    """Forward + grad parity through the sharded path for all four model
    kinds; engine routes over-threshold composites through the executor
    with matching output and reuses the cached sharded layout."""
    r = _run(GNN_SCRIPT)
    for kind in ("gcn", "sage", "gin", "gat"):
        assert r[f"fwd_{kind}"] < 1e-4, r
        assert r[f"grad_{kind}"] < 1e-3, r
    assert r["engine_sharded_batches"] > 0, r
    assert r["engine_err"] < 1e-4, r
    assert r["engine_repeat_hits"] >= 1, r
