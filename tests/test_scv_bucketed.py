"""Hybrid MXU/VPU kernel + nnz-bucketed plans (DESIGN.md §2).

Covers the PR's acceptance criteria:

* vectorized kernel body == scalar body == jnp oracle, bit for bit on
  integer-valued inputs (order-independent sums) — including the
  in-kernel dense-tile branch,
* kernel edge cases: zero-tile plans, all-dummy (coverage-only) tiles,
  the nnz == cap boundary, cap not a multiple of the chunk size,
* bucketed segments are byte-identical to slices of the scalar-loop
  (`_coo_to_scv_tiles_loop`-era) tile construction,
* jit == eager for the bucketed plan under ``interpret=True``,
* grad parity (dvals / dZ) and forward parity for all four model kinds,
  bucketed plans flowing through ``gnn_forward_jit`` and
  ``assemble_batched_graph``,
* the legacy no-``nnz_in_tile`` path masks d/dvals on structural padding,
* ``ensure_row_coverage`` rejects 1-D entry arrays loudly,
* bucketed plans shard (``split_equal_nnz`` / ``shard_plan``) without
  changing the aggregate.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coo_from_dense, coo_to_scv_tiles
from repro.core.aggregate import aggregate, aggregate_scv_plan
from repro.core.formats import COOMatrix
from repro.core.partition import shard_plan, split_equal_nnz
from repro.core.scv import (
    SCVBucketedPlan,
    _coo_to_scv_tiles_loop,
    bucket_caps_for,
    bucket_tiles,
    dense_tile_threshold,
    plan_from_tiles,
    plan_from_tiles_bucketed,
    tile_nnz_histogram,
)
from repro.kernels.scv_spmm import ops as kops
from repro.kernels.scv_spmm import ref as kref
from repro.models.gnn import (
    GNNConfig,
    build_graph,
    gnn_forward,
    gnn_forward_batched,
    gnn_forward_jit,
    init_gnn,
)
from repro.serve.graph_engine import assemble_batched_graph
from repro.simul.datasets import gcn_normalize, powerlaw_graph

KINDS = ["gcn", "sage", "gin", "gat"]


def _int_coo(rng, m, n, density, dense_block=None):
    """Integer-valued sparse matrix: all partial sums exact in f32, so any
    accumulation order produces identical bits."""
    a = ((rng.random((m, n)) < density) * rng.integers(1, 5, (m, n))).astype(
        np.float32
    )
    if dense_block is not None:
        r0, c0, s = dense_block
        a[r0 : r0 + s, c0 : c0 + s] = rng.integers(1, 5, (s, s))
    return a


def _int_z(rng, n, f):
    return rng.integers(-4, 5, (n, f)).astype(np.float32)


# ---------------------------------------------------------------------------
# vector body == scalar body == oracle, bit for bit
# ---------------------------------------------------------------------------
def test_vector_scalar_oracle_bit_identical(rng):
    a = _int_coo(rng, 96, 96, 0.06, dense_block=(0, 32, 32))
    z = jnp.asarray(_int_z(rng, 96, 24))
    tiles = coo_to_scv_tiles(coo_from_dense(a), 32, cap=1024)
    plan = plan_from_tiles(tiles, with_perm=False)
    assert int(np.asarray(plan.nnz_in_tile).max()) > dense_tile_threshold(32)
    outs = {
        body: np.asarray(
            kops.scv_spmm_plan(plan, z, interpret=True, body=body)
        )
        for body in ("vector", "scalar")
    }
    ref = np.asarray(kref.scv_spmm_reference_plan(plan, z))
    np.testing.assert_array_equal(outs["vector"], ref)
    np.testing.assert_array_equal(outs["scalar"], ref)
    np.testing.assert_array_equal(ref[:96], a @ np.asarray(z))


def test_vector_body_chunk_not_dividing_cap(rng):
    """cap gets padded up to a chunk multiple inside the kernel wrapper."""
    a = _int_coo(rng, 64, 64, 0.2)
    z = jnp.asarray(_int_z(rng, 64, 8))
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16, cap=24)  # 24 % 16 != 0
    plan = plan_from_tiles(tiles, with_perm=False)
    out = np.asarray(
        kops.scv_spmm_plan(plan, z, interpret=True, body="vector", chunk=16)
    )
    np.testing.assert_array_equal(out[:64], a @ np.asarray(z))


# ---------------------------------------------------------------------------
# kernel edge cases
# ---------------------------------------------------------------------------
def test_zero_tile_bucketed_plan(rng):
    empty = coo_from_dense(np.zeros((48, 48), np.float32))
    plan = plan_from_tiles_bucketed(coo_to_scv_tiles(empty, 16))
    assert isinstance(plan, SCVBucketedPlan) and len(plan.segments) == 1
    # every tile is a coverage dummy
    assert int(np.asarray(plan.segments[0].nnz_in_tile).sum()) == 0
    z = jnp.asarray(_int_z(rng, 48, 8))
    for backend in ("jnp", "pallas_interpret"):
        out = np.asarray(aggregate_scv_plan(plan, z, backend=backend))
        assert out.shape == (48, 8) and np.all(out == 0)


def test_all_dummy_tiles_define_output(rng):
    """Edges only in block-row 0: rows 16.. are pure coverage dummies in
    every bucket segment, and each per-bucket launch must define them."""
    a = np.zeros((64, 64), np.float32)
    a[:8, :8] = _int_coo(rng, 8, 8, 0.8)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16, cap=64)
    plan = plan_from_tiles_bucketed(tiles, caps=(8, 64))
    z = jnp.asarray(_int_z(rng, 64, 12))
    out = np.asarray(aggregate_scv_plan(plan, z, backend="pallas_interpret"))
    np.testing.assert_array_equal(out, a @ np.asarray(z))


def test_nnz_equals_cap_boundary(rng):
    """A tile holding exactly cap entries sits in that bucket (no split,
    no off-by-one in the chunk loop bound)."""
    a = np.zeros((16, 16), np.float32)
    a[:4, 0] = [1, 2, 3, 4]  # tile (0,0) gets exactly 4 entries
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8, cap=4)
    assert list(np.asarray(tiles.nnz_in_tile)) == [4]
    segs = bucket_tiles(tiles, (4, 8))
    assert segs[0].n_tiles == 1 and segs[1].n_tiles == 0
    plan = plan_from_tiles_bucketed(tiles, caps=(4, 8))
    z = jnp.asarray(_int_z(rng, 16, 8))
    out = np.asarray(aggregate_scv_plan(plan, z, backend="pallas_interpret"))
    np.testing.assert_array_equal(out, a @ np.asarray(z))


def test_bucket_tiles_rejects_overflowing_ladder(rng):
    a = _int_coo(rng, 16, 16, 1.0)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8, cap=64)
    with pytest.raises(ValueError, match="largest bucket"):
        bucket_tiles(tiles, (8, 16))


# ---------------------------------------------------------------------------
# bucketed segments == scalar-loop-era construction, byte for byte
# ---------------------------------------------------------------------------
def test_bucketed_segments_byte_identical_to_loop_tiles(rng):
    for trial in range(8):
        m, n = rng.integers(20, 150, 2)
        coo = coo_from_dense(_int_coo(rng, m, n, 0.1))
        caps = bucket_caps_for(tile_nnz_histogram(coo, 16), 16)
        vec = bucket_tiles(coo_to_scv_tiles(coo, 16, cap=caps[-1]), caps)
        loop = bucket_tiles(_coo_to_scv_tiles_loop(coo, 16, cap=caps[-1]), caps)
        assert len(vec) == len(loop)
        for sv, sl in zip(vec, loop):
            for f in dataclasses.fields(sv):
                a, b = getattr(sv, f.name), getattr(sl, f.name)
                if isinstance(a, np.ndarray):
                    assert a.dtype == b.dtype and np.array_equal(a, b), f.name
                else:
                    assert a == b, f.name
        # the buckets partition the entries exactly
        total = sum(s.nnz for s in vec)
        assert total == coo.nnz


# ---------------------------------------------------------------------------
# jit == eager, bucketed plan, pallas interpret
# ---------------------------------------------------------------------------
def test_bucketed_jit_eq_eager_interpret(rng):
    adj = gcn_normalize(powerlaw_graph(70, 420, seed=2))
    g = build_graph(adj, tile=32, bucket_caps=(8, 32, 128))
    z = jnp.asarray(rng.standard_normal((70, 16)).astype(np.float32))

    def f(plan, zz):
        return aggregate_scv_plan(plan, zz, backend="pallas_interpret")

    eager = np.asarray(f(g.plan, z))
    jitted = np.asarray(jax.jit(f)(g.plan, z))
    np.testing.assert_array_equal(eager, jitted)
    # dispatch integration: aggregate() accepts the bucketed plan
    np.testing.assert_array_equal(
        np.asarray(aggregate(g.plan, z, backend="jnp")),
        np.asarray(aggregate_scv_plan(g.plan, z, backend="jnp")),
    )


# ---------------------------------------------------------------------------
# all four model kinds: forward + grads through gnn_forward_jit and
# assemble_batched_graph with bucketed plans
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_bucketed_forward_and_grads_all_kinds(kind, rng):
    adj = gcn_normalize(powerlaw_graph(60, 300, seed=3))
    g_b = build_graph(adj, tile=32, bucket_caps=(8, 32, 128))
    g_s = build_graph(adj, tile=32)
    assert isinstance(g_b.plan, SCVBucketedPlan)
    x = jnp.asarray(rng.standard_normal((60, 8)).astype(np.float32))
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=4,
                    backend="pallas_interpret")
    cfg_ref = dataclasses.replace(cfg, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)

    out_b = np.asarray(gnn_forward_jit(params, cfg, g_b, x))
    out_s = np.asarray(gnn_forward_jit(params, cfg_ref, g_s, x))
    np.testing.assert_allclose(out_b, out_s, atol=1e-4, rtol=1e-4)

    def loss(p, c, gg, xx):
        return (gnn_forward(p, c, gg, xx) ** 2).sum()

    grad = jax.jit(jax.grad(loss, argnums=(0, 3)), static_argnames=("c",))
    gp_b, gx_b = grad(params, cfg, g_b, x)
    gp_s, gx_s = grad(params, cfg_ref, g_s, x)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3
        ),
        (gp_b, gx_b), (gp_s, gx_s),
    )


@pytest.mark.parametrize("kind", ["gcn", "gat"])
def test_bucketed_composite_through_assemble(kind, rng):
    adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=5 + i))
            for i, n in enumerate([30, 50])]
    caps = (8, 32, 128)
    members = [build_graph(a, tile=32, bucket_caps=caps) for a in adjs]
    bg = assemble_batched_graph(members, 32, 128, with_edges=(kind == "gat"))
    assert isinstance(bg.graph.plan, SCVBucketedPlan)
    assert bg.graph.plan.caps == caps
    xs = [rng.standard_normal((a.shape[0], 8)).astype(np.float32) for a in adjs]
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=3)
    params, _ = init_gnn(jax.random.PRNGKey(1), cfg)
    outs = gnn_forward_batched(params, cfg, bg, xs)
    for a, x, o in zip(adjs, xs, outs):
        ref = gnn_forward(params, cfg, build_graph(a, tile=32), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=1e-4)


def test_engine_serves_bucketed_plans(rng):
    from repro.serve.graph_engine import (
        GraphEngineConfig, GraphRequest, GraphServeEngine,
    )

    cfg = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    eng = GraphServeEngine(
        {"gcn": (params, cfg)},
        GraphEngineConfig(tile=64, cap=64, bucket_caps=(8, 32, 64)),
    )
    adjs = [gcn_normalize(powerlaw_graph(40, 160, seed=8 + i)) for i in range(3)]
    xs = [rng.standard_normal((40, 8)).astype(np.float32) for _ in adjs]
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    assert len(done) == 3 and all(r.done for r in done)
    for r, a, x in zip(sorted(done, key=lambda r: r.rid), adjs, xs):
        ref = gnn_forward(params, cfg, build_graph(a, tile=64), jnp.asarray(x))
        np.testing.assert_allclose(r.out, np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------
def test_legacy_no_nnz_grad_masks_structural_padding(rng):
    """Without nnz_in_tile, d/dvals must still be zero on padding slots
    (they alias local (0,0), where <g[0], z[0]> is generally nonzero)."""
    a = _int_coo(rng, 32, 32, 0.1)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8, cap=16)
    plan = plan_from_tiles(tiles, with_perm=False)
    z = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    nnz = np.asarray(plan.nnz_in_tile)

    def loss(vv):
        out = kops.scv_spmm(
            plan.tile_row, plan.tile_col, plan.rows, plan.cols, vv, z,
            tile=8, n_rows=32, interpret=True,  # nnz_in_tile omitted
        )
        return (out ** 2).sum()

    dvals = np.asarray(jax.grad(loss)(plan.vals))
    slot = np.arange(dvals.shape[1])[None, :]
    assert np.all(dvals[slot >= nnz[:, None]] == 0), "padding slots got grads"

    def loss_ref(vv):
        out = kref.scv_spmm_reference(
            plan.tile_row, plan.tile_col, plan.rows, plan.cols, vv, z,
            tile=8, n_rows=32, nnz_in_tile=plan.nnz_in_tile,
        )
        return (out ** 2).sum()

    dref = np.asarray(jax.grad(loss_ref)(plan.vals))
    np.testing.assert_allclose(dvals, dref, atol=1e-4)


def test_ensure_row_coverage_rejects_1d():
    rows = np.zeros(5, np.int32)  # 1-D: the old code built (k, 1) pads and
    cols = np.zeros(5, np.int32)  # crashed in np.concatenate
    vals = np.zeros(5, np.float32)
    with pytest.raises(ValueError, match="2-D"):
        kops.ensure_row_coverage(
            np.zeros(5, np.int32), np.zeros(5, np.int32),
            rows, cols, vals, np.zeros(5, np.int32), 4,
        )


def test_bucketed_plan_shards_equivalently(rng):
    adj = gcn_normalize(powerlaw_graph(80, 600, seed=9))
    g = build_graph(adj, tile=16, bucket_caps=(8, 32))
    plan = g.plan
    z = jnp.asarray(rng.standard_normal((80, 8)).astype(np.float32))
    full = np.asarray(aggregate_scv_plan(plan, z, backend="jnp"))
    parts = split_equal_nnz(plan, 3)
    assert isinstance(parts, tuple) and len(parts) == len(plan.segments)
    stacked = shard_plan(plan, parts)
    assert isinstance(stacked, SCVBucketedPlan)
    # summing each part-span's aggregate reproduces the full result: shard
    # segment s into its P spans, aggregate each span, add
    acc = np.zeros_like(full)
    for seg, part in zip(stacked.segments, parts):
        width = part.part_tiles.shape[1]
        for p in range(part.n_parts):
            sl = slice(p * width, (p + 1) * width)
            acc += np.asarray(
                kref.scv_spmm_reference(
                    seg.tile_row[sl], seg.tile_col[sl], seg.rows[sl],
                    seg.cols[sl], seg.vals[sl], z,
                    tile=seg.tile, n_rows=seg.padded_shape[0],
                    nnz_in_tile=seg.nnz_in_tile[sl],
                )
            )[: full.shape[0]]
    np.testing.assert_allclose(acc, full, atol=1e-4)
