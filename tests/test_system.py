"""End-to-end behaviour: training improves loss, checkpoint-restart is
bit-deterministic, serve engine generates, GNN training on SCV backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import train as train_mod

pytestmark = pytest.mark.slow


def test_lm_training_loss_decreases(tmp_path):
    losses = train_mod.main(
        [
            "--arch", "gemma2-27b", "--reduced", "--steps", "12",
            "--batch", "4", "--seq", "32", "--lr", "3e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
        ]
    )
    assert losses[-1] < losses[0]


def test_checkpoint_restart_determinism(tmp_path):
    """Run 8 steps; run 4 + restart + 4 — identical final loss."""
    args = ["--arch", "qwen1.5-32b", "--reduced", "--batch", "2", "--seq", "16",
            "--lr", "1e-3", "--total-steps", "8"]
    full = train_mod.main(args + ["--steps", "8"])
    d1 = str(tmp_path / "a")
    train_mod.main(args + ["--steps", "4", "--ckpt-dir", d1, "--ckpt-every", "4"])
    resumed = train_mod.main(
        args + ["--steps", "8", "--ckpt-dir", d1, "--ckpt-every", "100", "--resume"]
    )
    assert resumed[-1] == pytest.approx(full[-1], rel=1e-5)


def test_serve_engine_end_to_end():
    from repro.launch import serve as serve_mod

    done = serve_mod.main(
        ["--arch", "gemma2-27b", "--requests", "5", "--prompt-len", "8",
         "--max-new", "4", "--max-batch", "3"]
    )
    assert len(done) == 5
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t for t in r.out)


def test_serve_greedy_matches_direct():
    """Engine decode tokens == greedy tokens from repeated full forwards."""
    from repro.models import layers as L
    from repro.models.transformer import hidden_states

    spec = ARCHS["gemma2-27b"]
    cfg = spec.cfg(reduced=True)
    params, _ = spec.init(jax.random.PRNGKey(0), reduced=True)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)

    from repro.serve.engine import EngineConfig, Request, ServeEngine
    from repro.models.transformer import decode_step as ds, prefill as pf

    prefill_fn = jax.jit(lambda p, t: pf(p, cfg, t, max_len=16))
    decode_fn = jax.jit(lambda p, s, t, pos: ds(p, cfg, t, s, pos))
    eng = ServeEngine(params, prefill_fn, decode_fn, EngineConfig(max_batch=1))
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    out = eng.run()[0].out

    toks = list(prompt)
    for _ in range(4):
        x, _, _ = hidden_states(params, cfg, jnp.asarray([toks], jnp.int32))
        logits = L.unembed_logits(params["embed"], x[:, -1:], cfg.final_softcap, true_vocab=cfg.vocab)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):], (out, toks[len(prompt):])


def test_gnn_training_scv_backend_improves():
    from repro.models.gnn import GNNConfig, build_graph, gnn_loss, init_gnn
    from repro.simul.datasets import gcn_normalize, powerlaw_graph

    adj = gcn_normalize(powerlaw_graph(150, 600, seed=0))
    g = build_graph(adj, tile=32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((150, 16)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 5, 150))
    mask = jnp.ones(150)
    cfg = GNNConfig(name="g", kind="gcn", d_in=16, d_hidden=32, n_classes=5,
                    backend="pallas_interpret")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    lr = 0.2
    loss0 = float(gnn_loss(params, cfg, g, x, labels, mask))
    grad_fn = jax.jit(jax.grad(lambda p: gnn_loss(p, cfg, g, x, labels, mask)))
    for _ in range(40):
        grads = grad_fn(params)
        params = jax.tree.map(lambda p, gr: p - lr * gr, params, grads)
    loss1 = float(gnn_loss(params, cfg, g, x, labels, mask))
    assert loss1 < loss0 - 0.1, (loss0, loss1)
