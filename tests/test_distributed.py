"""Multi-device distributed aggregation: run in a subprocess with 8 fake
CPU devices (flags must be set before jax initializes)."""
import json
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import coo_to_scv_tiles
from repro.core.dist import aggregate_distributed, distribute_tiles
from repro.simul.datasets import gcn_normalize, powerlaw_graph

adj = gcn_normalize(powerlaw_graph(800, 4000, seed=0))
tiles = coo_to_scv_tiles(adj, 32)
g = distribute_tiles(tiles, 8)
mesh = jax.make_mesh((8,), ("data",))
z = jnp.asarray(np.random.default_rng(0).standard_normal(
    (adj.shape[1], 16)).astype(np.float32))
out = np.asarray(aggregate_distributed(g, z, mesh))
ref = adj.to_dense() @ np.asarray(z)
err = float(np.abs(out - ref).max())
print(json.dumps({"err": err, "imbalance": g.imbalance}))
''' .replace("json.dumps", "__import__('json').dumps")


def test_shard_map_aggregation_8_devices():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=".", timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    payload = json.loads(res.stdout.strip().splitlines()[-1])
    assert payload["err"] < 1e-3, payload
    assert payload["imbalance"] < 1.5, payload
