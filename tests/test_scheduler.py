"""Async serving loop: continuous batching, admission control, intake
queue ownership, and failure semantics under concurrency.

The sync-path behaviors these build on (wave packing, parity, failure
isolation in ``run()``) are covered in test_serve_graph.py; this module
exercises the scheduler loop (``engine.start()``) and the intake
primitives it is built from.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    AdmissionRejected,
    EngineOverloaded,
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.serve.scheduler import IntakeQueue, _Control
from repro.simul.datasets import gcn_normalize, powerlaw_graph
from repro.stream import DeltaBatch


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _graphs(sizes, seed=0):
    return [
        gcn_normalize(powerlaw_graph(n, 4 * n, seed=seed + i))
        for i, n in enumerate(sizes)
    ]


def _features(rng, adjs, d):
    return [rng.standard_normal((a.shape[0], d)).astype(np.float32) for a in adjs]


def _engine(kind="gcn", **cfg_kw):
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    ecfg = GraphEngineConfig(tile=64, cap=64, **cfg_kw)
    return GraphServeEngine({kind: (params, cfg)}, ecfg), params, cfg


def _reference(params, cfg, adj, x):
    return np.asarray(
        gnn_forward(params, cfg, build_graph(adj, tile=64, backend_cap=64), x)
    )


# ---------------------------------------------------------------------------
# IntakeQueue: the single owner of queued serving state
# ---------------------------------------------------------------------------
def test_intake_queue_bounded_put():
    q = IntakeQueue(2)
    assert q.put(GraphRequest(rid=0), block=False)
    assert q.put(GraphRequest(rid=1), block=False)
    assert q.depth() == 2
    assert not q.put(GraphRequest(rid=2), block=False)
    assert not q.put(GraphRequest(rid=2), block=True, timeout=0.02)
    assert q.depth() == 2  # failed puts never enqueue


def test_intake_queue_requeue_exempt_from_capacity():
    q = IntakeQueue(1)
    a, b, c = (GraphRequest(rid=i) for i in range(3))
    assert q.put(a, block=False)
    # a failed wave's requests were already admitted once: requeue must
    # not drop them even when the queue is at capacity, and they go back
    # at the front (they were next in line)
    q.requeue([b, c])
    assert [r.rid for r in q.items()] == [1, 2, 0]
    assert q.depth() == 3


def test_intake_queue_snapshot_commit_preserves_late_arrivals():
    q = IntakeQueue(8)
    a, b, c = (GraphRequest(rid=i) for i in range(3))
    q.put(a), q.put(b)
    items, n = q.snapshot()
    assert [r.rid for r in items] == [0, 1] and n == 2
    q.put(c)  # arrives between snapshot and commit
    q.commit(n, [b])  # consumer took a, left b
    assert [r.rid for r in q.items()] == [1, 2]


def test_intake_queue_controls_bypass_capacity():
    q = IntakeQueue(1)
    q.put(GraphRequest(rid=0), block=False)
    ctrl = _Control(apply=lambda: "done")
    q.put_control(ctrl)  # full queue must not block a control message
    assert q.has_controls()
    assert q.wait_for_work(timeout=0)
    popped = q.pop_controls()
    assert popped == [ctrl] and not q.has_controls()


def test_intake_queue_wait_for_work_times_out():
    q = IntakeQueue(4)
    t0 = time.monotonic()
    assert not q.wait_for_work(timeout=0.02)
    assert time.monotonic() - t0 < 1.0


def test_engine_queue_property_is_a_snapshot():
    eng, _, _ = _engine()
    assert eng.queue == []
    # the property returns a copy: mutating it must not touch intake
    # state (the IntakeQueue is the single owner — scvlint SCV007)
    snap = eng.queue
    snap.append("garbage")
    assert eng.queue == []


# ---------------------------------------------------------------------------
# async loop: parity, lifecycle
# ---------------------------------------------------------------------------
def test_async_loop_outputs_match_reference(rng):
    adjs = _graphs([70, 130, 50, 200], seed=5)
    xs = _features(rng, adjs, 8)
    eng, params, cfg = _engine(max_wave_delay_ms=5.0)
    eng.start()
    try:
        reqs = [
            eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
            for i, (a, x) in enumerate(zip(adjs, xs))
        ]
        for r, a, x in zip(reqs, adjs, xs):
            out = r.result(timeout=60)
            np.testing.assert_allclose(
                out, _reference(params, cfg, a, x), atol=1e-5, rtol=1e-5
            )
            assert r.latency_s is not None and r.latency_s >= 0
    finally:
        eng.stop(timeout=30)
    assert not eng.running
    m = eng.metrics()
    assert m["completed"] == 4 and m["queue_depth"] == 0
    assert m["waves"] >= 1 and m["launches"] > 0


def test_sync_run_refused_while_loop_running():
    eng, _, _ = _engine()
    eng.start()
    try:
        with pytest.raises(RuntimeError, match="scheduler loop is running"):
            eng.run()
    finally:
        eng.stop(timeout=30)
    eng.run()  # fine again once stopped


def test_stop_drains_queued_work(rng):
    adjs = _graphs([60, 90], seed=3)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    eng.start()
    reqs = [
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
        for i, (a, x) in enumerate(zip(adjs, xs))
    ]
    eng.stop(timeout=60)  # drain=True: queued work completes first
    assert all(r.done for r in reqs)
    assert eng.metrics()["queue_depth"] == 0


def test_wait_idle(rng):
    adjs = _graphs([60], seed=4)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    eng.start()
    try:
        req = eng.submit(GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gcn"))
        assert eng.wait_idle(timeout=60)
        assert req.done
    finally:
        eng.stop(timeout=30)


# ---------------------------------------------------------------------------
# failure semantics under the async loop
# ---------------------------------------------------------------------------
def test_async_poison_ejected_healthy_complete(rng):
    """A request whose wave always fails is isolated and finally ejected
    after max_retries, while healthy requests — including those co-batched
    with it in the failing wave — keep completing under continuous intake."""
    adjs = _graphs([60, 80, 100], seed=9)
    eng, params, cfg = _engine(max_retries=1, max_wave_delay_ms=5.0)
    POISON = 999
    orig = eng._dispatch_wave

    def dispatch(wave):
        if any(r.rid == POISON for r in wave):
            raise RuntimeError("poisoned wave")
        return orig(wave)

    eng._dispatch_wave = dispatch
    eng.start()
    healthy = []
    try:
        rng2 = np.random.default_rng(1)
        for i in range(9):
            if i == 4:
                a = adjs[0]
                x = rng2.standard_normal((a.shape[0], 8)).astype(np.float32)
                poison = eng.submit(
                    GraphRequest(rid=POISON, adj=a, x=x, model="gcn")
                )
            a = adjs[i % len(adjs)]
            x = rng2.standard_normal((a.shape[0], 8)).astype(np.float32)
            healthy.append(
                eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
            )
            time.sleep(0.005)  # keep intake continuous, not one burst
        for r in healthy:
            out = r.result(timeout=60)
            np.testing.assert_allclose(
                out, _reference(params, cfg, r.adj, r.x), atol=1e-5, rtol=1e-5
            )
        with pytest.raises(RuntimeError, match="poisoned wave"):
            poison.result(timeout=60)
    finally:
        eng.stop(timeout=30)
    assert poison in eng.failed and not poison.done
    assert poison.retries > eng.cfg.max_retries
    m = eng.metrics()
    assert m["completed"] == 9 and m["failed"] == 1 and m["queue_depth"] == 0


def test_async_interrupt_restores_queue_untouched(rng):
    """KeyboardInterrupt mid-wave is not a request failure: the loop
    restores the wave to the front of the queue verbatim (no retries
    consumed, no isolation) and stop() re-raises the interrupt."""
    adjs = _graphs([60, 90, 120], seed=11)
    xs = _features(rng, adjs, 8)
    eng, params, cfg = _engine()
    orig = eng._dispatch_wave
    tripped = threading.Event()

    def dispatch(wave):
        tripped.set()
        raise KeyboardInterrupt

    eng._dispatch_wave = dispatch
    reqs = [
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
        for i, (a, x) in enumerate(zip(adjs, xs))
    ]
    eng.start()
    assert tripped.wait(timeout=60)
    deadline = time.monotonic() + 60
    while eng.scheduler.running and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not eng.scheduler.running  # the loop stopped itself
    # queue restored untouched: same requests, no retries, no isolation
    assert {id(r) for r in eng.queue} == {id(r) for r in reqs}
    assert all(r.retries == 0 and not r.isolate for r in reqs)
    with pytest.raises(KeyboardInterrupt):
        eng.stop(timeout=30)
    # recovery: the untouched queue drains normally
    del eng._dispatch_wave
    done = eng.run()
    assert {r.rid for r in done} == {0, 1, 2}
    for r in done:
        np.testing.assert_allclose(
            r.out, _reference(params, cfg, r.adj, r.x), atol=1e-5, rtol=1e-5
        )


# ---------------------------------------------------------------------------
# admission control: deadlines, backpressure
# ---------------------------------------------------------------------------
def test_deadline_rejected_at_submit(rng):
    adjs = _graphs([80], seed=13)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    eng.start()
    try:
        # first completed wave seeds the per-model service-time EMA
        eng.submit(
            GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gcn")
        ).result(timeout=60)
        assert eng.scheduler.service_estimate("gcn") is not None
        with pytest.raises(AdmissionRejected, match="infeasible"):
            eng.submit(
                GraphRequest(
                    rid=1, adj=adjs[0], x=xs[0], model="gcn", deadline_s=1e-4
                )
            )
    finally:
        eng.stop(timeout=30)
    m = eng.metrics()
    assert m["rejected"] == 1 and m["completed"] == 1
    assert m["service_ema_s"].get("gcn", 0) > 0


def test_deadline_shed_at_wave_formation(rng):
    """A request admitted optimistically (no EMA yet) whose budget expires
    while queued is shed at wave formation, not served late."""
    adjs = _graphs([80], seed=14)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    req = eng.submit(
        GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gcn", deadline_s=0.005)
    )
    time.sleep(0.05)  # budget expires while queued
    done = eng.run()
    assert done == [] and not req.done
    assert req in eng.shed
    with pytest.raises(RuntimeError, match="deadline shed"):
        req.result(timeout=1)
    assert eng.metrics()["shed"] == 1


def test_backpressure_bounded_intake(rng):
    adjs = _graphs([60], seed=15)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine(intake_capacity=2)
    for i in range(2):
        eng.submit(GraphRequest(rid=i, adj=adjs[0], x=xs[0], model="gcn"))
    with pytest.raises(EngineOverloaded, match="intake queue full"):
        eng.submit(
            GraphRequest(rid=2, adj=adjs[0], x=xs[0], model="gcn"),
            block=False,
        )
    with pytest.raises(EngineOverloaded, match="after waiting"):
        eng.submit(
            GraphRequest(rid=2, adj=adjs[0], x=xs[0], model="gcn"),
            timeout=0.02,
        )
    assert len(eng.run()) == 2  # backpressure never corrupted the queue


# ---------------------------------------------------------------------------
# update() as a serialized control message
# ---------------------------------------------------------------------------
def _value_update(adj, idx, val):
    coords = [(int(adj.rows[i]), int(adj.cols[i])) for i in idx]
    return DeltaBatch.of(inserts=[(r, c, val) for r, c in coords],
                         removes=coords)


def test_update_interleaved_with_inflight_requests(rng):
    """Deltas applied while the loop serves concurrent traffic: every
    probe submitted after update() returns must serve the post-delta
    graph, bit-matching a fresh rebuild of the tracked adjacency."""
    adjs = _graphs([90, 70], seed=17)
    x_tracked = rng.standard_normal((adjs[0].shape[0], 8)).astype(np.float32)
    x_noise = rng.standard_normal((adjs[1].shape[0], 8)).astype(np.float32)
    eng, params, cfg = _engine(max_wave_delay_ms=5.0)
    eng.start()
    stop_noise = threading.Event()
    noise_done = []

    def noise():
        i = 10_000
        while not stop_noise.is_set():
            r = eng.submit(
                GraphRequest(rid=i, adj=adjs[1], x=x_noise, model="gcn")
            )
            noise_done.append(r)
            i += 1
            time.sleep(0.002)

    t = threading.Thread(target=noise, daemon=True)
    try:
        eng.submit(
            GraphRequest(
                rid=0, adj=adjs[0], x=x_tracked, model="gcn", graph_id="g"
            )
        ).result(timeout=60)
        t.start()
        for k in range(4):
            delta = _value_update(
                eng.tracked_adj("g"), [k, k + 3], 0.25 + 0.1 * k
            )
            eng.update("g", delta)  # control message: applied between waves
            snapshot = eng.tracked_adj("g")
            probe = eng.submit(
                GraphRequest(rid=100 + k, x=x_tracked, model="gcn",
                             graph_id="g")
            )
            np.testing.assert_allclose(
                probe.result(timeout=60),
                _reference(params, cfg, snapshot, x_tracked),
                atol=1e-5, rtol=1e-5,
            )
    finally:
        stop_noise.set()
        t.join(timeout=30)
        eng.stop(timeout=60)
    for r in noise_done:
        np.testing.assert_allclose(
            r.result(timeout=60),
            _reference(params, cfg, adjs[1], x_noise),
            atol=1e-5, rtol=1e-5,
        )
    m = eng.metrics()
    assert m["graph_updates"] == 4
    assert m["plan_cache_revalidated"] >= 4  # deltas patched, not rebuilt


def test_update_applies_inline_when_loop_stopped(rng):
    adjs = _graphs([90], seed=19)
    x = rng.standard_normal((adjs[0].shape[0], 8)).astype(np.float32)
    eng, params, cfg = _engine()
    eng.submit(GraphRequest(rid=0, adj=adjs[0], x=x, model="gcn",
                            graph_id="g"))
    eng.run()
    key = eng.update("g", _value_update(eng.tracked_adj("g"), [0, 1], 0.5))
    assert isinstance(key, str) and key
    req = eng.submit(GraphRequest(rid=1, x=x, model="gcn", graph_id="g"))
    eng.run()
    np.testing.assert_allclose(
        req.out, _reference(params, cfg, eng.tracked_adj("g"), x),
        atol=1e-5, rtol=1e-5,
    )


def test_update_error_propagates_through_control(rng):
    adjs = _graphs([90], seed=21)
    x = rng.standard_normal((adjs[0].shape[0], 8)).astype(np.float32)
    eng, _, _ = _engine()
    eng.start()
    try:
        eng.submit(GraphRequest(rid=0, adj=adjs[0], x=x, model="gcn",
                                graph_id="g")).result(timeout=60)
        with pytest.raises(Exception):  # check_delta admission failure
            eng.update("g", DeltaBatch.of(inserts=[(10**6, 0, 1.0)]))
    finally:
        eng.stop(timeout=30)
    assert eng.metrics()["graph_updates"] == 0  # nothing applied


# ---------------------------------------------------------------------------
# metrics surface
# ---------------------------------------------------------------------------
def test_metrics_async_fields(rng):
    adjs = _graphs([60, 90], seed=23)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    eng.start()
    try:
        for i, (a, x) in enumerate(zip(adjs, xs)):
            eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
        assert eng.wait_idle(timeout=60)
        assert eng.metrics()["async_running"]
    finally:
        eng.stop(timeout=30)
    m = eng.metrics()
    assert not m["async_running"]
    assert m["waves"] >= 1 and 0 < m["wave_fill"] <= 1
    assert m["shed"] == 0 and m["rejected"] == 0
    assert m["queue_depth"] == 0 and m["queue_depth_by_group"] == {}
    assert m["latency_count"] == 2
    assert m["latency_p50_s"] > 0 and m["latency_p99_s"] >= m["latency_p50_s"]
    assert m["service_ema_s"]["gcn"] > 0
    # launches count non-empty kernel launches: at least one segment per
    # wave, times the model's layer count
    assert m["launches"] >= m["batches"]


def test_queue_depth_by_group_buckets(rng):
    adjs = _graphs([60, 600], seed=25)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    groups = eng.metrics()["queue_depth_by_group"]
    assert sum(groups.values()) == 2
    assert len(groups) == 2  # 60 and 600 nodes land in different buckets
    assert all(k.startswith("gcn:n") for k in groups)
    eng.run()
    assert eng.metrics()["queue_depth_by_group"] == {}
