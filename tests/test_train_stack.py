"""Smoke + unit tests for the dormant train/ stack (ISSUE 6 satellite).

The upcoming training PR should start from a tested baseline, not dead
code: these tests pin the host-testable contracts of
``train/sharding.py`` (logical-axis resolution with divisibility
fallback), ``train/checkpoint.py`` (atomic, versioned, resumable), and
``train/fault.py`` (heartbeats, stragglers, elastic re-meshing,
deterministic resume).
"""
import os

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.train import checkpoint, fault, sharding


# ---------------------------------------------------------------------------
# imports are not enough — but they are the floor
# ---------------------------------------------------------------------------
def test_train_modules_import():
    for mod in (sharding, checkpoint, fault):
        assert mod.__doc__  # real module, not an accidental namespace pkg


# ---------------------------------------------------------------------------
# train/sharding.py
# ---------------------------------------------------------------------------
def _mesh_1x1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_param_spec_resolves_rules():
    mesh = _mesh_1x1()
    # "model"/"data" axes both size 1: every divisibility check passes,
    # so the preferred rule axes are assigned as-is.
    spec = sharding.param_spec((8, 16), ("gnn_in", "gnn_out"), mesh=mesh)
    assert spec == P("data", "model")
    # rule-less / None-rule names replicate
    assert sharding.param_spec((8,), ("head_dim",), mesh=mesh) == P(None)


def test_param_spec_divisibility_fallback():
    # A 4-device model axis cannot shard a 6-wide dim: silently replicate.
    if len(jax.devices()) >= 4:
        dev = np.array(jax.devices()[:4]).reshape(1, 4)
    else:
        pytest.skip("needs >= 4 devices (forced host platform)")
    mesh = Mesh(dev, ("data", "model"))
    assert sharding.param_spec((6,), ("mlp",), mesh=mesh) == P(None)
    assert sharding.param_spec((8,), ("mlp",), mesh=mesh) == P("model")


def test_use_mesh_installs_and_restores():
    mesh = _mesh_1x1()
    assert sharding.active_mesh() is None
    with sharding.use_mesh(mesh) as m:
        assert m is mesh
        assert sharding.active_mesh() is mesh
        # attn_axes: heads divisible by model axis (1) -> head sharding
        assert sharding.attn_axes(4) == ("batch", None, "heads", None)
    assert sharding.active_mesh() is None


def test_constrain_noop_without_mesh():
    x = np.ones((4, 4), np.float32)
    assert sharding.constrain(x, ("batch", "embed")) is x


def test_unfsdp_refsdp_noop_without_mesh():
    params = {"w": np.ones((4, 4), np.float32)}
    axes = {"w": ("gnn_in", "gnn_out")}
    assert sharding.unfsdp_params(params, axes) is params
    assert sharding.refsdp_params(params, axes) is params


def test_constrain_under_mesh_preserves_value():
    mesh = _mesh_1x1()
    x = np.arange(16, dtype=np.float32).reshape(4, 4)
    with sharding.use_mesh(mesh):
        y = sharding.constrain(jax.numpy.asarray(x), ("batch", "embed"))
    np.testing.assert_array_equal(np.asarray(y), x)


# ---------------------------------------------------------------------------
# train/checkpoint.py
# ---------------------------------------------------------------------------
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer0": {"w": rng.standard_normal((4, 8)).astype(np.float32),
                   "b": np.zeros((8,), np.float32)},
        "step_scale": np.float32(0.5),
    }


def test_checkpoint_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    path = checkpoint.save(d, 3, tree, extra={"lr": 0.1})
    assert os.path.exists(os.path.join(path, ".complete"))
    restored, extra = checkpoint.restore(d, 3, jax.tree.map(np.zeros_like, tree))
    assert extra == {"lr": 0.1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_latest_skips_incomplete(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    checkpoint.save(d, 1, tree)
    checkpoint.save(d, 2, _tree(seed=2))
    # simulate a crash mid-write of step 5: directory without .complete
    partial = os.path.join(d, "step_00000005")
    os.makedirs(partial)
    assert checkpoint.list_steps(d) == [1, 2]
    step, restored, _ = checkpoint.restore_latest(
        d, jax.tree.map(np.zeros_like, tree)
    )
    assert step == 2
    np.testing.assert_array_equal(
        restored["layer0"]["w"], _tree(seed=2)["layer0"]["w"]
    )


def test_checkpoint_restore_latest_empty(tmp_path):
    assert checkpoint.restore_latest(str(tmp_path / "none"), _tree()) is None


def test_checkpoint_prune_keeps_newest(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(d, s, _tree(seed=s))
    checkpoint.prune(d, keep=2)
    assert checkpoint.list_steps(d) == [4, 5]


def test_checkpoint_no_tmp_dirs_after_save(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, _tree())
    assert not [n for n in os.listdir(d) if n.endswith(".tmp")]


def test_checkpoint_shape_mismatch_is_loud(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, _tree())
    wrong = _tree()
    wrong["layer0"]["w"] = np.zeros((2, 2), np.float32)
    with pytest.raises(AssertionError):
        checkpoint.restore(d, 1, wrong)


# ---------------------------------------------------------------------------
# train/fault.py
# ---------------------------------------------------------------------------
def test_heartbeat_deadline():
    hb = fault.Heartbeat(timeout_s=10.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead_workers([0, 1], now=109.0) == []
    assert hb.dead_workers([0, 1], now=112.0) == [0]
    # a never-seen worker is dead by definition
    assert hb.dead_workers([0, 1, 2], now=109.0) == [2]


def test_straggler_detector_flags_consistent_outlier():
    det = fault.StragglerDetector(k_sigma=3.0, patience=3)
    for _ in range(20):
        assert not det.observe(0, 1.0)
    flagged = [det.observe(0, 10.0) for _ in range(3)]
    assert flagged == [False, False, True]


def test_straggler_detector_recovers():
    det = fault.StragglerDetector(k_sigma=3.0, patience=3)
    for _ in range(20):
        det.observe(0, 1.0)
    det.observe(0, 10.0)
    det.observe(0, 10.0)
    assert not det.observe(0, 1.0)  # strike streak reset
    assert not det.observe(0, 10.0)  # streak restarts from zero


def test_elastic_mesh_shapes():
    assert fault.elastic_mesh_shapes(64, model_parallel=16) == (4, 16)
    assert fault.elastic_mesh_shapes(63, model_parallel=16) == (3, 16)
    # degenerate: fewer chips than the model axis still yields a mesh
    assert fault.elastic_mesh_shapes(8, model_parallel=16) == (1, 16)


def test_data_skipper_deterministic_resume():
    fresh = fault.DataSkipper(seed=0)
    ids = [fresh.next_batch_id() for _ in range(10)]
    resumed = fault.DataSkipper(seed=0)
    resumed.skip_to(step=4, batches_per_step=2)
    assert [resumed.next_batch_id() for _ in range(2)] == ids[8:10]
