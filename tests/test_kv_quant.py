"""int8 KV-cache quantization (qwen's 5.5 TB MHA cache; DESIGN.md §5)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import layers as L
from repro.models.transformer import decode_step, hidden_states, init_cache, prefill

pytestmark = pytest.mark.slow


def _setup(kv_quant):
    spec = ARCHS["qwen1.5-32b"]
    cfg = dataclasses.replace(spec.cfg(reduced=True), kv_quant=kv_quant)
    params, _ = spec.init(jax.random.PRNGKey(0), reduced=True)
    return cfg, params


def test_cache_dtype_and_size():
    cfg, _ = _setup(True)
    c = init_cache(cfg, 2, 32)
    leaf = c["blocks"]["pos0"]
    assert leaf["k"].dtype == jnp.int8
    assert "k_scale" in leaf and leaf["k_scale"].dtype == jnp.float32
    # int8 + f32/head scale ~= 0.5x of bf16 + negligible
    bf16 = init_cache(dataclasses.replace(cfg, kv_quant=False), 2, 32)
    b_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    b_f = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(bf16))
    assert b_q < 0.6 * b_f


def test_quantized_decode_close_and_argmax_stable():
    cfg, params = _setup(True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, cache = prefill(params, cfg, toks[:, :11], max_len=16)
    logits, _ = decode_step(
        params, cfg, toks[:, 11:], cache, jnp.full((2, 1), 11, jnp.int32)
    )
    x, _, _ = hidden_states(params, cfg, toks)
    direct = L.unembed_logits(params["embed"], x[:, -1:], true_vocab=cfg.vocab)
    lp, ld = jax.nn.log_softmax(logits), jax.nn.log_softmax(direct)
    err = float(jnp.abs(jnp.where(jnp.isfinite(lp), lp - ld, 0)).max())
    assert err < 0.15, err  # lossy but tight
    assert bool(jnp.all(jnp.argmax(logits, -1) == jnp.argmax(direct, -1)))


def test_quantized_multi_step_decode_stays_close():
    cfg_q, params = _setup(True)
    cfg_f = dataclasses.replace(cfg_q, kv_quant=False)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg_q.vocab)
    _, cq = prefill(params, cfg_q, toks[:, :3], max_len=16)
    _, cf = prefill(params, cfg_f, toks[:, :3], max_len=16)
    for t in range(3, 6):
        pos = jnp.full((1, 1), t, jnp.int32)
        lq, cq = decode_step(params, cfg_q, toks[:, t : t + 1], cq, pos)
        lf, cf = decode_step(params, cfg_f, toks[:, t : t + 1], cf, pos)
    err = float(jnp.abs(jax.nn.log_softmax(lq) - jax.nn.log_softmax(lf)).max())
    assert err < 0.2, err
