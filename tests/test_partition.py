"""Z-curve partitioning: coverage, balance, and distributed-equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    coo_from_dense,
    coo_to_scv_tiles,
    load_imbalance,
    shard_tiles,
    split_equal_nnz,
)
from repro.core.aggregate import aggregate_scv_tiles
from repro.simul.datasets import powerlaw_graph


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), parts=st.sampled_from([2, 4, 8]))
def test_partition_covers_all_nnz(seed, parts):
    rng = np.random.default_rng(seed)
    a = ((rng.random((96, 96)) < 0.05) * 1.0).astype(np.float32)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16)
    part = split_equal_nnz(tiles, parts)
    assert part.nnz_per_part.sum() == tiles.nnz
    ids = part.part_tiles[part.part_tiles >= 0]
    assert len(np.unique(ids)) == tiles.n_tiles  # each tile exactly once


def test_powerlaw_balance():
    """Paper §V-G: fine-grained vector/tile partitioning keeps equal-nnz
    splits balanced even on hub-heavy graphs."""
    adj = powerlaw_graph(2000, 20000, seed=1)
    tiles = coo_to_scv_tiles(adj, 64)
    part = split_equal_nnz(tiles, 8)
    assert load_imbalance(part) < 1.3


def test_sharded_aggregation_equals_full():
    """Each part aggregates its span into a local PS; summing local PS
    buffers (the paper's multi-processor merge) equals the full result."""
    rng = np.random.default_rng(2)
    a = ((rng.random((64, 64)) < 0.08) * rng.standard_normal((64, 64))).astype(
        np.float32
    )
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8)
    z = rng.standard_normal((64, 16)).astype(np.float32)
    full = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="jnp"))

    part = split_equal_nnz(tiles, 4)
    stacked = shard_tiles(tiles, part)
    width = part.part_tiles.shape[1]
    acc = np.zeros_like(full)
    import dataclasses

    for p in range(4):
        sl = slice(p * width, (p + 1) * width)
        sub = dataclasses.replace(
            tiles,
            tile_row=stacked.tile_row[sl],
            tile_col=stacked.tile_col[sl],
            rows=stacked.rows[sl],
            cols=stacked.cols[sl],
            vals=stacked.vals[sl],
            nnz_in_tile=stacked.nnz_in_tile[sl],
        )
        acc += np.asarray(aggregate_scv_tiles(sub, jnp.asarray(z), backend="jnp"))
    np.testing.assert_allclose(acc, full, atol=1e-4)


def test_shard_plan_matches_shard_tiles():
    """Sharding the device plan pytree == sharding the host tiles object:
    same spans, same padded layout, same per-part aggregation sum."""
    import dataclasses

    from repro.core import plan_from_tiles, shard_plan
    from repro.core.aggregate import aggregate_scv_plan

    rng = np.random.default_rng(7)
    a = ((rng.random((96, 96)) < 0.06) * rng.standard_normal((96, 96))).astype(
        np.float32
    )
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16)
    plan = plan_from_tiles(tiles, ensure_coverage=False)
    part = split_equal_nnz(plan, 4)
    stacked_t = shard_tiles(tiles, part)
    stacked_p = shard_plan(plan, part)
    for f in ("tile_row", "tile_col", "rows", "cols", "vals", "nnz_in_tile"):
        np.testing.assert_array_equal(
            np.asarray(getattr(stacked_p, f)), getattr(stacked_t, f)
        )
    # padded slots of the perm leaf are -1 (no source entry)
    pad = part.part_tiles.ravel() < 0
    if pad.any():
        assert (np.asarray(stacked_p.perm)[pad] == -1).all()
    # per-part aggregation sums to the full result
    z = jnp.asarray(rng.standard_normal((96, 8)).astype(np.float32))
    full = np.asarray(aggregate_scv_plan(plan, z, backend="jnp"))
    width = part.part_tiles.shape[1]
    acc = np.zeros_like(full)
    for p in range(4):
        sl = slice(p * width, (p + 1) * width)
        sub = dataclasses.replace(
            stacked_p,
            tile_row=stacked_p.tile_row[sl],
            tile_col=stacked_p.tile_col[sl],
            rows=stacked_p.rows[sl],
            cols=stacked_p.cols[sl],
            vals=stacked_p.vals[sl],
            nnz_in_tile=stacked_p.nnz_in_tile[sl],
            perm=stacked_p.perm[sl],
        )
        acc += np.asarray(aggregate_scv_plan(sub, z, backend="jnp"))
    np.testing.assert_allclose(acc, full, atol=1e-4)


def test_zorder_spans_preserve_locality():
    """Contiguous Z-curve spans touch fewer distinct tile rows+cols than
    random same-size subsets (the paper's locality claim)."""
    adj = powerlaw_graph(4000, 40000, seed=3)
    tiles = coo_to_scv_tiles(adj, 64)
    part = split_equal_nnz(tiles, 8)
    rng = np.random.default_rng(0)
    z_spread, r_spread = [], []
    for p in range(8):
        ids = part.part_tiles[p]
        ids = ids[ids >= 0]
        z_spread.append(
            len(np.unique(tiles.tile_row[ids])) + len(np.unique(tiles.tile_col[ids]))
        )
        rnd = rng.choice(tiles.n_tiles, size=len(ids), replace=False)
        r_spread.append(
            len(np.unique(tiles.tile_row[rnd])) + len(np.unique(tiles.tile_col[rnd]))
        )
    assert np.mean(z_spread) < np.mean(r_spread)
