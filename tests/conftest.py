import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_sparse(rng, m, n, density):
    a = (rng.random((m, n)) < density).astype(np.float32)
    return a * rng.standard_normal((m, n)).astype(np.float32)
