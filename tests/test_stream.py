"""stream/ delta plan maintenance: admission, COO semantics, byte-exact
parity with from-scratch rebuilds at every plan layer, in-place patching,
and identity preservation of untouched device leaves."""
import dataclasses

import numpy as np
import pytest

from repro.core.formats import COOMatrix
from repro.core.scv import (
    coo_to_scv_tiles,
    plan_from_tiles,
    plan_from_tiles_bucketed,
)
from repro.core.validate import validate_plan
from repro.models.gnn import build_graph
from repro.stream import DeltaBatch, apply_coo, apply_delta, check_delta

TILE = 16
CAPS = (4, 16, 64)


def _random_coo(rng, n, density):
    total = n * n
    k = max(1, int(total * density))
    flat = rng.choice(total, size=k, replace=False)
    vals = rng.standard_normal(k).astype(np.float32)
    vals[vals == 0] = 1.0
    return COOMatrix(
        rows=(flat // n).astype(np.int32),
        cols=(flat % n).astype(np.int32),
        vals=vals,
        shape=(n, n),
    )


def _random_delta(rng, coo, n_ins, n_rem):
    """Random inserts at absent coordinates + removes of stored edges."""
    n = coo.shape[1]
    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    rem_idx = rng.choice(coo.nnz, size=min(n_rem, coo.nnz), replace=False)
    removes = [(int(coo.rows[i]), int(coo.cols[i])) for i in rem_idx]
    inserts = []
    tries = 0
    while len(inserts) < n_ins and tries < 10_000:
        r, c = int(rng.integers(n)), int(rng.integers(n))
        if (r, c) not in have and all((r, c) != e[:2] for e in inserts):
            inserts.append((r, c, float(rng.standard_normal() + 2.0)))
        tries += 1
    return DeltaBatch.of(inserts=inserts, removes=removes)


def _eq_fields(a, b):
    assert type(a) is type(b)
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None or vb is None:
            assert va is vb, f.name
        elif hasattr(va, "dtype"):
            va, vb = np.asarray(va), np.asarray(vb)
            assert va.dtype == vb.dtype and np.array_equal(va, vb), f.name
        elif isinstance(va, tuple) and va and dataclasses.is_dataclass(va[0]):
            assert len(va) == len(vb), f.name
            for sa, sb in zip(va, vb):
                _eq_fields(sa, sb)
        else:
            assert va == vb, f.name


# ---------------------------------------------------------------------------
# DeltaBatch / check_delta admission
# ---------------------------------------------------------------------------
def test_delta_batch_of_and_len():
    d = DeltaBatch.of(inserts=[(0, 1, 2.0)], removes=[(3, 4), (5, 6)])
    assert (d.n_insert, d.n_remove, len(d)) == (1, 2, 3)
    assert len(DeltaBatch.of()) == 0


def test_delta_signature_framed():
    a = DeltaBatch.of(inserts=[(1, 2, 3.0)])
    b = DeltaBatch.of(inserts=[(1, 2, 3.0)])
    c = DeltaBatch.of(removes=[(1, 2)])
    assert a.signature() == b.signature()
    assert a.signature() != c.signature()
    # same bytes, different op: inserts vs removes must never collide
    d = DeltaBatch.of(inserts=[(1, 2, 3.0)], removes=[(9, 9)])
    assert a.signature() != d.signature()


def test_check_delta_rejects_out_of_range_ids():
    with pytest.raises(ValueError, match="out of range"):
        check_delta(DeltaBatch.of(inserts=[(99, 0, 1.0)]), shape=(8, 8))
    with pytest.raises(ValueError, match="non-negative"):
        check_delta(DeltaBatch.of(removes=[(-1, 0)]), shape=(8, 8))


def test_check_delta_rejects_non_finite_vals():
    with pytest.raises(ValueError, match="finite"):
        check_delta(DeltaBatch.of(inserts=[(0, 0, np.nan)]), shape=(8, 8))
    with pytest.raises(ValueError, match="finite"):
        check_delta(DeltaBatch.of(inserts=[(0, 0, np.inf)]), shape=(8, 8))


def test_check_delta_rejects_duplicates():
    with pytest.raises(ValueError, match="duplicate insert"):
        check_delta(DeltaBatch.of(inserts=[(0, 1, 1.0), (0, 1, 2.0)]))
    with pytest.raises(ValueError, match="duplicate remove"):
        check_delta(DeltaBatch.of(removes=[(0, 1), (0, 1)]))


def test_check_delta_rejects_length_mismatch():
    d = DeltaBatch(
        ins_rows=np.array([0], np.int32),
        ins_cols=np.array([0, 1], np.int32),
        ins_vals=np.array([1.0], np.float32),
        rem_rows=np.zeros(0, np.int32),
        rem_cols=np.zeros(0, np.int32),
    )
    with pytest.raises(ValueError, match="disagree on length"):
        check_delta(d)


def test_check_delta_presence_against_coo(rng):
    coo = _random_coo(rng, 16, 0.1)
    r0, c0 = int(coo.rows[0]), int(coo.cols[0])
    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    absent = next((r, c) for r in range(16) for c in range(16)
                  if (r, c) not in have)
    with pytest.raises(ValueError, match="absent edge"):
        check_delta(DeltaBatch.of(removes=[absent]), coo=coo)
    with pytest.raises(ValueError, match="already-present"):
        check_delta(DeltaBatch.of(inserts=[(r0, c0, 1.0)]), coo=coo)
    # the value-update idiom is admitted: remove + insert the same coord
    check_delta(
        DeltaBatch.of(inserts=[(r0, c0, 9.0)], removes=[(r0, c0)]), coo=coo
    )


# ---------------------------------------------------------------------------
# apply_coo: the canonical (hole-filling) final ordering
# ---------------------------------------------------------------------------
def test_apply_coo_value_update_keeps_positions(rng):
    coo = _random_coo(rng, 20, 0.1)
    i = 2
    d = DeltaBatch.of(
        inserts=[(int(coo.rows[i]), int(coo.cols[i]), 42.0)],
        removes=[(int(coo.rows[i]), int(coo.cols[i]))],
    )
    out = apply_coo(coo, d)
    assert np.array_equal(out.rows, coo.rows)
    assert np.array_equal(out.cols, coo.cols)
    assert out.vals[i] == 42.0
    mask = np.ones(coo.nnz, bool)
    mask[i] = False
    assert np.array_equal(out.vals[mask], coo.vals[mask])


def test_apply_coo_insert_fills_hole_then_appends(rng):
    coo = _random_coo(rng, 20, 0.1)
    # remove position 1, insert two fresh edges: first insert takes the
    # hole at position 1, second appends at the tail
    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    fresh = [(r, c) for r in range(20) for c in range(20)
             if (r, c) not in have][:2]
    d = DeltaBatch.of(
        inserts=[(fresh[0][0], fresh[0][1], 5.0),
                 (fresh[1][0], fresh[1][1], 6.0)],
        removes=[(int(coo.rows[1]), int(coo.cols[1]))],
    )
    out = apply_coo(coo, d)
    assert out.nnz == coo.nnz + 1
    assert (int(out.rows[1]), int(out.cols[1])) == fresh[0]
    assert (int(out.rows[-1]), int(out.cols[-1])) == fresh[1]
    # everything else untouched, in place
    mask = np.ones(coo.nnz, bool)
    mask[1] = False
    assert np.array_equal(out.rows[:-1][mask], coo.rows[mask])


def test_apply_coo_shrink_moves_only_tail(rng):
    coo = _random_coo(rng, 20, 0.2)
    # remove two low positions: the last two survivors back-fill the holes
    d = DeltaBatch.of(removes=[(int(coo.rows[0]), int(coo.cols[0])),
                               (int(coo.rows[3]), int(coo.cols[3]))])
    out = apply_coo(coo, d)
    L = coo.nnz - 2
    assert out.nnz == L
    # survivors below L that were not removed keep their exact position
    for j in range(L):
        if j in (0, 3):
            continue
        assert out.rows[j] == coo.rows[j] and out.cols[j] == coo.cols[j]
    # holes 0 and 3 hold the moved tail survivors, ascending
    assert (int(out.rows[0]), int(out.cols[0])) == \
        (int(coo.rows[L]), int(coo.cols[L]))
    assert (int(out.rows[3]), int(out.cols[3])) == \
        (int(coo.rows[L + 1]), int(coo.cols[L + 1]))


# ---------------------------------------------------------------------------
# byte-exact parity: apply_delta(build(adj), d) == build(apply_coo(adj, d))
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_ins,n_rem", [(0, 5), (5, 0), (7, 7), (3, 9), (9, 3)])
def test_parity_all_layers(rng, n_ins, n_rem):
    coo = _random_coo(rng, 257, 0.004)
    d = _random_delta(rng, coo, n_ins, n_rem)
    final = apply_coo(coo, d)

    t1 = apply_delta(coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1]), d)
    _eq_fields(t1, coo_to_scv_tiles(final, tile=TILE, cap=CAPS[-1]))

    p1 = apply_delta(plan_from_tiles(coo_to_scv_tiles(coo, TILE, cap=CAPS[-1])), d)
    _eq_fields(p1, plan_from_tiles(coo_to_scv_tiles(final, TILE, cap=CAPS[-1])))

    b1 = apply_delta(
        plan_from_tiles_bucketed(coo_to_scv_tiles(coo, TILE, cap=CAPS[-1]), caps=CAPS), d
    )
    _eq_fields(
        b1,
        plan_from_tiles_bucketed(coo_to_scv_tiles(final, TILE, cap=CAPS[-1]), caps=CAPS),
    )


def test_parity_random_sweep(rng):
    for trial in range(12):
        coo = _random_coo(rng, 129, 0.01 + 0.01 * (trial % 3))
        d = _random_delta(rng, coo, int(rng.integers(0, 10)),
                          int(rng.integers(0, 10)))
        if len(d) == 0:
            continue
        final = apply_coo(coo, d)
        t1 = apply_delta(coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1]), d)
        _eq_fields(t1, coo_to_scv_tiles(final, tile=TILE, cap=CAPS[-1]))


def test_parity_graph_layer(rng):
    coo = _random_coo(rng, 130, 0.02)
    d = _random_delta(rng, coo, 6, 6)
    final = apply_coo(coo, d)
    for caps in (None, CAPS):
        g1 = apply_delta(build_graph(coo, tile=TILE, bucket_caps=caps), d)
        g_ref = build_graph(final, tile=TILE, bucket_caps=caps)
        _eq_fields(g1.plan, g_ref.plan)
        for f in ("rows", "cols", "vals"):
            assert np.array_equal(
                np.asarray(getattr(g1, f)), np.asarray(getattr(g_ref, f))
            ), f


def test_parity_tile_birth_and_death(rng):
    # a delta that empties one tile entirely and creates a brand-new one
    coo = COOMatrix(
        rows=np.array([0, 1, 40], np.int32),
        cols=np.array([0, 1, 40], np.int32),
        vals=np.ones(3, np.float32),
        shape=(64, 64),
    )
    d = DeltaBatch.of(inserts=[(60, 60, 2.0)], removes=[(40, 40)])
    final = apply_coo(coo, d)
    t1 = apply_delta(coo_to_scv_tiles(coo, tile=TILE, cap=4), d)
    _eq_fields(t1, coo_to_scv_tiles(final, tile=TILE, cap=4))


def test_parity_chain_growth(rng):
    # inserts overflowing one tile's chunk so the chain grows
    coo = _random_coo(rng, 32, 0.05)
    tile0 = (int(coo.rows[0]) // TILE * TILE, int(coo.cols[0]) // TILE * TILE)
    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    ins = []
    for r in range(tile0[0], tile0[0] + TILE):
        for c in range(tile0[1], tile0[1] + TILE):
            if (r, c) not in have and len(ins) < 9:
                ins.append((r, c, 1.5))
    d = DeltaBatch.of(inserts=ins)
    final = apply_coo(coo, d)
    t1 = apply_delta(coo_to_scv_tiles(coo, tile=TILE, cap=4), d)
    _eq_fields(t1, coo_to_scv_tiles(final, tile=TILE, cap=4))


def test_parity_chain_tail_in_lower_bucket(rng):
    # a heavy tile whose chain-split tail lands in a LOWER capacity
    # bucket than its full chunks (282 nnz at caps=(8, 32, 128): chunks
    # 128+128 in the top segment, the 26-tail in cap=32).  The chain
    # check must read chunks in descending-cap reconstruction order or
    # it misreads the tail as a mid-chain partial chunk (the
    # examples/serve_gnn.py live-mutation regression).
    caps = (8, 32, 128)
    coo = _random_coo(rng, 60, 282 / (60 * 60))
    assert coo.nnz > 2 * caps[-1]  # needs >= 2 full chunks + a tail
    d = DeltaBatch.of(
        inserts=[(int(coo.rows[0]), int(coo.cols[0]), 9.0)],
        removes=[(int(coo.rows[0]), int(coo.cols[0]))],
    )
    final = apply_coo(coo, d)
    b1 = apply_delta(
        plan_from_tiles_bucketed(coo_to_scv_tiles(coo, 64, cap=caps[-1]),
                                 caps=caps), d
    )
    _eq_fields(
        b1,
        plan_from_tiles_bucketed(coo_to_scv_tiles(final, 64, cap=caps[-1]),
                                 caps=caps),
    )


def test_source_and_scan_paths_agree(rng):
    # net-shrinking delta: moved tail survivors must be located — by
    # coordinate arithmetic (source=) and by the perm-scan fallback alike
    coo = _random_coo(rng, 257, 0.01)
    d = _random_delta(rng, coo, 0, 12)
    t0 = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    t_src = apply_delta(t0, d, source=coo)
    t_scan = apply_delta(t0, d)
    _eq_fields(t_src, t_scan)
    _eq_fields(
        t_src, coo_to_scv_tiles(apply_coo(coo, d), tile=TILE, cap=CAPS[-1])
    )


# ---------------------------------------------------------------------------
# in-place fast path + identity preservation
# ---------------------------------------------------------------------------
def test_inplace_layout_equal_returns_same_object(rng):
    coo = _random_coo(rng, 64, 0.05)
    t = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    i = 1
    d = DeltaBatch.of(
        inserts=[(int(coo.rows[i]), int(coo.cols[i]), 9.5)],
        removes=[(int(coo.rows[i]), int(coo.cols[i]))],
    )
    out = apply_delta(t, d, inplace=True)
    assert out is t
    _eq_fields(t, coo_to_scv_tiles(apply_coo(coo, d), tile=TILE, cap=CAPS[-1]))


def test_inplace_layout_change_returns_fresh_object():
    # all edges in the top-left tile; the insert births a fresh tile
    coo = COOMatrix(
        rows=np.array([0, 1, 2], np.int32),
        cols=np.array([3, 4, 5], np.int32),
        vals=np.ones(3, np.float32),
        shape=(64, 64),
    )
    t = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    d = DeltaBatch.of(inserts=[(50, 50, 1.0)])
    out = apply_delta(t, d, inplace=True)
    assert out is not t  # tile birth: layout changed
    _eq_fields(out, coo_to_scv_tiles(apply_coo(coo, d), tile=TILE, cap=CAPS[-1]))


def test_inplace_rejected_on_plans(rng):
    coo = _random_coo(rng, 64, 0.05)
    p = plan_from_tiles(coo_to_scv_tiles(coo, TILE, cap=CAPS[-1]))
    with pytest.raises(ValueError, match="inplace"):
        apply_delta(p, DeltaBatch.of(inserts=[(0, 0, 1.0)]), inplace=True)


def test_untouched_bucketed_segments_kept_by_identity(rng):
    # a one-tile value update must leave every segment the delta doesn't
    # re-chunk as the SAME object (device arrays, jit traces survive)
    coo = _random_coo(rng, 257, 0.01)
    b = plan_from_tiles_bucketed(
        coo_to_scv_tiles(coo, TILE, cap=CAPS[-1]), caps=CAPS
    )
    i = 0
    d = DeltaBatch.of(
        inserts=[(int(coo.rows[i]), int(coo.cols[i]), 3.0)],
        removes=[(int(coo.rows[i]), int(coo.cols[i]))],
    )
    b2 = apply_delta(b, d)
    shared = sum(a is c for a, c in zip(b.segments, b2.segments))
    assert shared >= len(b.segments) - 1  # at most one segment re-chunked


def test_empty_delta_returns_same_object(rng):
    coo = _random_coo(rng, 64, 0.05)
    t = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    assert apply_delta(t, DeltaBatch.of()) is t


# ---------------------------------------------------------------------------
# error paths
# ---------------------------------------------------------------------------
def test_remove_absent_edge_raises(rng):
    coo = _random_coo(rng, 32, 0.05)
    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    absent = next((r, c) for r in range(32) for c in range(32)
                  if (r, c) not in have)
    t = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    with pytest.raises(ValueError, match="absent edge"):
        apply_delta(t, DeltaBatch.of(removes=[absent]))


def test_insert_present_edge_raises(rng):
    coo = _random_coo(rng, 32, 0.05)
    t = coo_to_scv_tiles(coo, tile=TILE, cap=CAPS[-1])
    d = DeltaBatch.of(inserts=[(int(coo.rows[0]), int(coo.cols[0]), 1.0)])
    with pytest.raises(ValueError, match="remove it in the same batch"):
        apply_delta(t, d, check=False)  # the splice itself also rejects


def test_plan_without_perm_raises(rng):
    coo = _random_coo(rng, 32, 0.05)
    p = plan_from_tiles(coo_to_scv_tiles(coo, TILE, cap=CAPS[-1]))
    p = dataclasses.replace(p, perm=None)
    with pytest.raises(ValueError, match="perm"):
        apply_delta(p, DeltaBatch.of(inserts=[(0, 0, 1.0)]))


def test_unknown_object_raises():
    with pytest.raises(TypeError, match="cannot patch"):
        apply_delta(object(), DeltaBatch.of(inserts=[(0, 0, 1.0)]), check=False)


# ---------------------------------------------------------------------------
# validate_plan stays green on patched plans
# ---------------------------------------------------------------------------
def test_patched_plans_validate_green(rng):
    coo = _random_coo(rng, 129, 0.02)
    d = _random_delta(rng, coo, 5, 5)
    final = apply_coo(coo, d)
    tiles = coo_to_scv_tiles(coo, TILE, cap=CAPS[-1])

    p1 = apply_delta(plan_from_tiles(tiles), d)
    validate_plan(p1, coo=final).raise_if_failed()

    b1 = apply_delta(plan_from_tiles_bucketed(tiles, caps=CAPS), d)
    validate_plan(b1, coo=final).raise_if_failed()
