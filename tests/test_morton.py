import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.morton import morton_decode, morton_encode, morton_order, zcurve_tiles


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**20), st.integers(0, 2**20)), min_size=1, max_size=200))
def test_encode_decode_roundtrip(coords):
    r = np.array([c[0] for c in coords], np.int64)
    c = np.array([c[1] for c in coords], np.int64)
    rr, cc = morton_decode(morton_encode(r, c))
    assert np.array_equal(r, rr) and np.array_equal(c, cc)


def test_no_collisions_exhaustive():
    r = np.repeat(np.arange(128), 128)
    c = np.tile(np.arange(128), 128)
    assert len(np.unique(morton_encode(r, c))) == 128 * 128


def test_canonical_curve_order():
    # top-left, top-right, bottom-left, bottom-right (Fig. 2(e))
    tiles = [tuple(t) for t in zcurve_tiles(2, 2)]
    assert tiles == [(0, 0), (0, 1), (1, 0), (1, 1)]
    tiles4 = [tuple(t) for t in zcurve_tiles(4, 4)]
    assert tiles4[:4] == [(0, 0), (0, 1), (1, 0), (1, 1)]
    assert tiles4[4:8] == [(0, 2), (0, 3), (1, 2), (1, 3)]


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 9), st.integers(1, 9))
def test_zcurve_tiles_cover(nr, nc):
    tiles = zcurve_tiles(nr, nc)
    assert len(tiles) == nr * nc
    assert len({tuple(t) for t in tiles}) == nr * nc


def test_locality_vs_rowmajor():
    """Z order has better 2-D locality than row-major: any window of W
    consecutive curve points touches ~2*sqrt(W) distinct rows+cols, vs up
    to W cols for row-major (paper §III-C: "any subsequence ... preserves
    data locality")."""
    n = 32
    W = 64
    z = zcurve_tiles(n, n)
    rm = np.stack([np.repeat(np.arange(n), n), np.tile(np.arange(n), n)], 1)

    def max_window_spread(pts):
        worst = 0
        for i in range(0, len(pts) - W, W):
            w = pts[i : i + W]
            worst = max(worst, len(np.unique(w[:, 0])) + len(np.unique(w[:, 1])))
        return worst

    assert max_window_spread(z) < max_window_spread(rm)
    assert max_window_spread(z) <= 2 * int(np.sqrt(W))  # 8+8 for a 64-block


def test_morton_order_sorts_by_curve():
    rng = np.random.default_rng(0)
    r = rng.integers(0, 64, 100)
    c = rng.integers(0, 64, 100)
    order = morton_order(r, c)
    keys = morton_encode(r[order], c[order])
    assert np.all(np.diff(keys.astype(np.int64)) >= 0)
