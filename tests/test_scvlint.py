"""Tests for tools/scvlint (scvcheck leg 3).

Each rule on synthetic snippets (fire + non-fire), pragma suppression,
the baseline engine, and the gate itself: the repo must lint clean
against the checked-in baseline (the same invocation scripts/lint.sh
makes).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # tools/ is importable from the repo root

from tools.scvlint import (  # noqa: E402
    RULES,
    Violation,
    check_paths,
    check_source,
    load_baseline,
    main,
)


def _rules(src, rel="src/repro/fake.py"):
    return [(v.rule, v.line) for v in check_source(src, rel)]


# ---------------------------------------------------------------------------
# SCV001 — np.* in traced bodies
# ---------------------------------------------------------------------------
def test_scv001_jit_decorator():
    src = (
        "import numpy as np, jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.sum(x)\n"
    )
    assert _rules(src) == [("SCV001", 4)]


def test_scv001_defvjp_and_module_level_jit():
    src = (
        "import numpy as np, jax\n"
        "def fwd(x):\n"
        "    return np.asarray(x), None\n"
        "def other(x):\n"
        "    return np.asarray(x)\n"
        "f = jax.custom_vjp(lambda x: x)\n"
        "f.defvjp(fwd, fwd)\n"
        "g = jax.jit(other)\n"
    )
    rules = _rules(src)
    assert ("SCV001", 3) in rules  # fwd registered via defvjp
    assert ("SCV001", 5) in rules  # other wrapped by module-level jit


def test_scv001_kernel_prefix_scoped_to_kernels_tree():
    src = (
        "import numpy as np\n"
        "def _kernel_body(ref):\n"
        "    return np.sum(ref)\n"
    )
    assert _rules(src, "src/repro/kernels/scv_spmm/k.py") == [("SCV001", 3)]
    assert _rules(src, "benchmarks/run.py") == []  # host-side driver idiom


def test_scv001_untraced_function_clean():
    src = (
        "import numpy as np\n"
        "def host(x):\n"
        "    return np.sum(x)\n"
    )
    assert _rules(src) == []


def test_scv001_calling_a_jitted_fn_does_not_taint_args():
    # `forward_jit(batch(x))` must not mark `batch` as traced
    src = (
        "import numpy as np, jax\n"
        "def batch(x):\n"
        "    return np.asarray(x)\n"
        "forward_jit = jax.jit(lambda x: x)\n"
        "def serve(x):\n"
        "    return forward_jit(batch(x))\n"
    )
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# SCV002 — magic constants duplicating core/scv.py
# ---------------------------------------------------------------------------
def test_scv002_ratio_and_chunk():
    src = (
        "RATIO = 1 / 16\n"
        "r2 = 0.0625\n"
        "chunk_size = 128\n"
        "def f(x, feature_chunk=128):\n"
        "    return x\n"
    )
    rules = [r for r, _ in _rules(src)]
    assert rules.count("SCV002") == 4


def test_scv002_owner_file_and_unrelated_literals_exempt():
    src = "MXU_VPU_RATIO = 1 / 16\nDEFAULT_CHUNK = 128\n"
    assert _rules(src, "src/repro/core/scv.py") == []
    # 128 bound to a non-chunk name is fine; so is dividing by other values
    assert _rules("block = 128\nx = 1 / 8\n") == []


def test_scv002_tunable_constants_in_repro_scope():
    src = (
        "tile = 64\n"
        "cap: int = 32\n"
        "def build(tile=128):\n"
        "    return tile\n"
        "bucket_caps = (8, 32)\n"
        "serve_ladder = [16, 64]\n"
    )
    rules = [r for r, _ in _rules(src)]
    assert rules.count("SCV002") == 5


def test_scv002_tunable_constants_scoped_and_owned():
    src = "tile = 64\nbucket_caps = (8, 32)\n"
    # benchmarks/tests sweep candidate values by design — out of scope
    assert _rules(src, "benchmarks/serve_bench.py") == []
    assert _rules(src, "tests/test_foo.py") == []
    # TunedConfig is the other sanctioned owner
    assert _rules(src, "src/repro/tune/config.py") == []
    # non-literal bindings thread constants legitimately
    clean = (
        "from repro.core.scv import DEFAULT_LADDER, DEFAULT_TILE\n"
        "tile = DEFAULT_TILE\n"
        "bucket_caps = DEFAULT_LADDER\n"
        "def f(tile=DEFAULT_TILE):\n"
        "    return tile\n"
    )
    assert _rules(clean) == []


# ---------------------------------------------------------------------------
# SCV003 — nondiff_argnums over plan leaves
# ---------------------------------------------------------------------------
def test_scv003_plan_leaf_positions():
    src = (
        "import jax, functools\n"
        "@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2))\n"
        "def f(tile_row, x, vals):\n"
        "    return x\n"
    )
    vs = check_source(src, "src/repro/fake.py")
    assert [v.rule for v in vs] == ["SCV003"]
    assert "tile_row" in vs[0].message and "vals" in vs[0].message


def test_scv003_static_config_positions_clean():
    src = (
        "import jax, functools\n"
        "@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))\n"
        "def f(vals, z, tile, n_rows):\n"
        "    return z\n"
    )
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# SCV004 — jax shim pin hygiene
# ---------------------------------------------------------------------------
SHIM = (
    "try:\n"
    "    from jax import shard_map\n"
    "except ImportError:\n"
    "    from jax.experimental.shard_map import shard_map\n"
)


def test_scv004_unpinned_shim_flagged():
    assert _rules(SHIM) == [("SCV004", 1)]


def test_scv004_pinned_shim_clean():
    pinned = "# jax >= 0.6 re-homes shard_map; drop the except branch then.\n" + SHIM
    assert _rules(pinned) == []


def test_scv004_non_jax_shims_exempt():
    src = (
        "try:\n"
        "    import tomllib\n"
        "except ImportError:\n"
        "    tomllib = None\n"
    )
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# SCV005 — fori_loop(unroll=)
# ---------------------------------------------------------------------------
def test_scv005_unroll_flagged():
    src = (
        "import jax\n"
        "def body(n, x):\n"
        "    return jax.lax.fori_loop(0, n, lambda i, c: c, x, unroll=4)\n"
    )
    assert _rules(src) == [("SCV005", 3)]
    clean = src.replace(", unroll=4", "")
    assert _rules(clean) == []


# ---------------------------------------------------------------------------
# SCV006 — full rebuilds inside src/repro/stream/
# ---------------------------------------------------------------------------
def test_scv006_rebuild_in_stream_flagged():
    src = (
        "from repro.core import coo_to_scv_tiles\n"
        "def patch(coo, delta):\n"
        "    return coo_to_scv_tiles(coo, 64)\n"
    )
    assert _rules(src, "src/repro/stream/delta.py") == [("SCV006", 3)]
    # dotted form fires too
    dotted = (
        "from repro import core\n"
        "def patch(coo, delta):\n"
        "    return core.plan_from_tiles_bucketed(core.coo_to_scv_tiles(coo, 64))\n"
    )
    assert {r for r, _ in _rules(dotted, "src/repro/stream/delta.py")} == {"SCV006"}


def test_scv006_scoped_to_stream_package():
    src = (
        "from repro.core import coo_to_scv_tiles\n"
        "def build(coo):\n"
        "    return coo_to_scv_tiles(coo, 64)\n"
    )
    # rebuilds are the whole point everywhere else
    assert _rules(src, "src/repro/serve/graph_engine.py") == []
    assert _rules(src, "benchmarks/stream_bench.py") == []
    assert _rules(src, "tests/test_stream.py") == []


# ---------------------------------------------------------------------------
# SCV007 — self.queue ownership in serve/
# ---------------------------------------------------------------------------
def test_scv007_direct_queue_mutation_flagged():
    src = (
        "class Engine:\n"
        "    def __init__(self):\n"
        "        self.queue = []\n"
        "    def submit(self, req):\n"
        "        self.queue.append(req)\n"
        "    def take(self):\n"
        "        self.queue = self.queue[2:]\n"
        "    def restore(self, batch):\n"
        "        self.queue[:0] = batch\n"
        "    def drop(self):\n"
        "        del self.queue[0]\n"
    )
    assert sorted(_rules(src, "src/repro/serve/other_engine.py"),
                  key=lambda rl: rl[1]) == [
        ("SCV007", 3), ("SCV007", 5), ("SCV007", 7), ("SCV007", 9),
        ("SCV007", 11),
    ]


def test_scv007_scoped_to_serve_outside_scheduler():
    # the scheduler/intake module owns the queue — exempt by design
    src = "class S:\n    def __init__(self):\n        self.queue = []\n"
    assert _rules(src, "src/repro/serve/scheduler.py") == []
    # outside serve/ other queues are unrelated
    assert _rules(src, "src/repro/train/loop.py") == []
    assert _rules(src, "tests/test_serve_graph.py") == []
    # reads and non-mutating calls don't fire; neither does someone
    # else's queue attribute
    clean = (
        "class Engine:\n"
        "    def peek(self):\n"
        "        return self.queue[0]\n"
        "    def depth(self):\n"
        "        return len(self.queue)\n"
        "    def relay(self):\n"
        "        self.scheduler.queue.put(1)\n"
    )
    assert _rules(clean, "src/repro/serve/graph_engine.py") == []


# ---------------------------------------------------------------------------
# pragmas, baseline, CLI
# ---------------------------------------------------------------------------
def test_pragma_suppression():
    src = (
        "import numpy as np, jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.sum(x)  # scvlint: ignore[SCV001]\n"
    )
    assert _rules(src) == []
    # rule-specific pragma does not blanket other rules
    src2 = "chunk = 128  # scvlint: ignore[SCV001]\n"
    assert _rules(src2) == [("SCV002", 1)]
    src3 = "chunk = 128  # scvlint: ignore\n"
    assert _rules(src3) == []


def test_baseline_keys_survive_line_drift(tmp_path):
    v = Violation(path="a.py", line=10, col=1, rule="SCV002",
                  message="m", source_line="chunk = 128")
    moved = Violation(path="a.py", line=99, col=1, rule="SCV002",
                      message="m", source_line="chunk = 128")
    assert v.baseline_key == moved.baseline_key
    bl = tmp_path / "baseline.txt"
    bl.write_text(f"# header\n{v.baseline_key}\n")
    assert load_baseline(str(bl)) == {v.baseline_key}


def test_main_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("chunk = 128\n")
    empty_bl = tmp_path / "bl.txt"
    empty_bl.write_text("")
    assert main([str(bad), "--baseline", str(empty_bl)]) == 1
    # --write-baseline accepts it; second run is then clean
    assert main([str(bad), "--baseline", str(empty_bl), "--write-baseline"]) == 0
    assert main([str(bad), "--baseline", str(empty_bl)]) == 0
    # --no-baseline resurrects it
    assert main([str(bad), "--no-baseline", "--baseline", str(empty_bl)]) == 1


def test_rules_registry_complete():
    assert set(RULES) == {
        "SCV001", "SCV002", "SCV003", "SCV004", "SCV005", "SCV006",
        "SCV007",
    }


# ---------------------------------------------------------------------------
# the gate: the repo lints clean against the checked-in baseline
# ---------------------------------------------------------------------------
def test_repo_lints_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.scvlint", "src/", "tools/", "benchmarks/"],
        cwd=REPO, capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_known_exceptions_carry_pragmas():
    """The two deliberate host-side exceptions stay pragma'd, not silently
    baselined: float0 cotangents (ops.py) and the linter's own ratio
    literal."""
    ops = os.path.join(REPO, "src/repro/kernels/scv_spmm/ops.py")
    with open(ops) as f:
        assert "scvlint: ignore[SCV001]" in f.read()
    vs = check_paths([ops], repo_root=REPO)
    assert [v for v in vs if v.rule == "SCV001"] == []
