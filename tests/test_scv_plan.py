"""SCVPlan pytree + end-to-end jitted GNN forwards.

Covers the PR's acceptance criteria:

* vectorized ``coo_to_scv_tiles`` is byte-identical to the scalar loop
  emitter on randomized inputs,
* ``SCVPlan`` / ``Graph`` / ``BatchedGraph`` flatten/unflatten as pytrees
  with the documented leaf vs static-aux split,
* ``gnn_forward`` and ``gnn_forward_batched`` run under a single outer
  ``jax.jit`` (including the Pallas interpret backend on CPU) and match
  the unjitted path bit-for-bit for all four model kinds,
* jit retraces at most once per padding bucket (``_cache_size``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import aggregate, aggregate_scv_plan, aggregate_scv_tiles
from repro.core.formats import coo_from_dense
from repro.core.scv import (
    SCVPlan,
    _coo_to_scv_tiles_loop,
    coo_to_scv_tiles,
    plan_from_tiles,
)
from repro.models.gnn import (
    GNNConfig,
    Graph,
    build_batched_graph,
    build_graph,
    gnn_forward,
    gnn_forward_batched,
    gnn_forward_jit,
    init_gnn,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph

KINDS = ["gcn", "sage", "gin", "gat"]


def _random_coo(rng, m, n, density):
    a = ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )
    return coo_from_dense(a)


# ---------------------------------------------------------------------------
# vectorized tile construction == scalar loop, byte for byte
# ---------------------------------------------------------------------------
def test_vectorized_tiles_byte_identical_to_loop(rng):
    for trial in range(25):
        m, n = rng.integers(1, 180, 2)
        density = float(rng.choice([0.0, 0.01, 0.08, 0.35]))
        coo = _random_coo(rng, m, n, density)
        tile = int(rng.choice([8, 16, 32, 64]))
        cap = [None, 8, 16][trial % 3]
        order = ["zmorton", "row_major"][trial % 2]
        vec = coo_to_scv_tiles(coo, tile, cap=cap, order=order)
        loop = _coo_to_scv_tiles_loop(coo, tile, cap=cap, order=order)
        for f in dataclasses.fields(vec):
            a, b = getattr(vec, f.name), getattr(loop, f.name)
            if isinstance(a, np.ndarray):
                assert a.dtype == b.dtype, (trial, f.name)
                assert np.array_equal(a, b), (trial, f.name)
            else:
                assert a == b, (trial, f.name)


# ---------------------------------------------------------------------------
# pytree structure
# ---------------------------------------------------------------------------
def test_scv_plan_pytree_leaf_aux_split(rng):
    coo = _random_coo(rng, 90, 90, 0.05)
    plan = plan_from_tiles(coo_to_scv_tiles(coo, 16))
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    # exactly the documented array leaves; aux round-trips identically
    assert len(leaves) == 7
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (rebuilt.tile, rebuilt.cap, rebuilt.shape, rebuilt.order) == (
        plan.tile, plan.cap, plan.shape, plan.order,
    )
    # tree_map touches every leaf and preserves the wrapper
    doubled = jax.tree.map(lambda x: x, plan)
    assert isinstance(doubled, SCVPlan) and doubled.cap == plan.cap


def test_graph_and_batched_graph_are_pytrees(rng):
    adj = gcn_normalize(powerlaw_graph(50, 200, seed=0))
    g = build_graph(adj, tile=32)
    g2 = jax.tree.map(lambda x: x, g)
    assert isinstance(g2, Graph) and g2.n_nodes == g.n_nodes
    bg = build_batched_graph([adj, adj], tile=32, pad_nodes=128)
    bg2 = jax.tree.map(lambda x: x, bg)
    assert list(bg2.node_offsets) == list(bg.node_offsets)
    assert bg2.n_real_nodes == bg.n_real_nodes


def test_plan_aggregate_matches_tiles_backend(rng):
    coo = _random_coo(rng, 70, 70, 0.06)
    z = jnp.asarray(rng.standard_normal((70, 12)).astype(np.float32))
    tiles = coo_to_scv_tiles(coo, 16)
    plan = plan_from_tiles(tiles)
    out_plan = np.asarray(aggregate_scv_plan(plan, z, backend="jnp"))
    out_tiles = np.asarray(aggregate_scv_tiles(tiles, z, backend="jnp"))
    np.testing.assert_array_equal(out_plan, out_tiles)
    # dispatch integration
    np.testing.assert_array_equal(np.asarray(aggregate(plan, z)), out_plan)


# ---------------------------------------------------------------------------
# whole-forward jit: exact equivalence, all kinds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_jitted_forward_bit_for_bit(kind, rng):
    adj = gcn_normalize(powerlaw_graph(90, 360, seed=1))
    g = build_graph(adj, tile=32)
    x = jnp.asarray(rng.standard_normal((90, 16)).astype(np.float32))
    cfg = GNNConfig(name=kind, kind=kind, d_in=16, d_hidden=16, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    with jax.disable_jit():
        ref = np.asarray(gnn_forward(params, cfg, g, x))
    out = np.asarray(gnn_forward_jit(params, cfg, g, x))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("kind", KINDS)
def test_jitted_batched_forward_bit_for_bit(kind, rng):
    adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=2 + i))
            for i, n in enumerate([40, 70])]
    xs = [rng.standard_normal((a.shape[0], 8)).astype(np.float32) for a in adjs]
    bg = build_batched_graph(adjs, tile=32, backend_cap=32, pad_nodes=192)
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=3)
    params, _ = init_gnn(jax.random.PRNGKey(1), cfg)
    with jax.disable_jit():
        ref = gnn_forward_batched(params, cfg, bg, xs)
    fwd = jax.jit(gnn_forward_batched, static_argnames=("cfg",))
    outs = fwd(params, cfg, bg, tuple(jnp.asarray(xi) for xi in xs))
    assert len(outs) == len(ref)
    for o, r in zip(outs, ref):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))


def test_jitted_forward_pallas_interpret_backend(rng):
    """Acceptance: the whole forward runs under one outer jit with the
    Pallas kernel in interpret mode on CPU — plan arrays arrive at the
    custom_vjp as tracers, not closure constants."""
    adj = gcn_normalize(powerlaw_graph(80, 320, seed=3))
    g = build_graph(adj, tile=32)
    x = jnp.asarray(rng.standard_normal((80, 8)).astype(np.float32))
    mk = lambda backend: GNNConfig(
        name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4, backend=backend
    )
    params, _ = init_gnn(jax.random.PRNGKey(0), mk("jnp"))
    out_p = np.asarray(gnn_forward_jit(params, mk("pallas_interpret"), g, x))
    out_r = np.asarray(gnn_forward_jit(params, mk("jnp"), g, x))
    np.testing.assert_allclose(out_p, out_r, atol=1e-5, rtol=1e-5)


def test_grad_through_jitted_pallas_plan_argument(rng):
    """The kernel's VJP must accept plan leaves as tracers (grad under an
    outer jit with the graph as an argument, not a closure constant)."""
    adj = gcn_normalize(powerlaw_graph(60, 240, seed=4))
    g = build_graph(adj, tile=32)
    x = jnp.asarray(rng.standard_normal((60, 8)).astype(np.float32))
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4,
                    backend="pallas_interpret")
    cfg_ref = dataclasses.replace(cfg, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)

    def loss(p, cfg, g, x):
        return (gnn_forward(p, cfg, g, x) ** 2).sum()

    gp = jax.jit(jax.grad(loss), static_argnames=("cfg",))(params, cfg, g, x)
    gr = jax.jit(jax.grad(loss), static_argnames=("cfg",))(params, cfg_ref, g, x)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4
        ),
        gp, gr,
    )


# ---------------------------------------------------------------------------
# retrace discipline: one trace per padding bucket
# ---------------------------------------------------------------------------
def test_jit_retraces_once_per_padding_bucket(rng):
    from repro.serve.graph_engine import (
        GraphEngineConfig, GraphRequest, GraphServeEngine,
    )

    cfg = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    eng = GraphServeEngine({"gcn": (params, cfg)}, GraphEngineConfig(tile=64, cap=64))

    def serve_wave(sizes, seed):
        adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=seed + i))
                for i, n in enumerate(sizes)]
        for i, a in enumerate(adjs):
            x = rng.standard_normal((a.shape[0], 8)).astype(np.float32)
            eng.submit(GraphRequest(rid=seed * 100 + i, adj=a, x=x, model="gcn"))
        eng.run()

    serve_wave([60, 90], seed=5)  # bucket 256: first trace
    base = gnn_forward_jit._cache_size()
    # different graphs, same node bucket and tile-count bucket -> NO retrace
    serve_wave([70, 80], seed=6)
    serve_wave([50, 95], seed=7)
    assert gnn_forward_jit._cache_size() == base
    # a new bucket may add at most one trace
    serve_wave([400, 500], seed=8)  # bucket 1024
    assert gnn_forward_jit._cache_size() <= base + 1


# ---------------------------------------------------------------------------
# lazy composite edges (model-kind component of the batch plan)
# ---------------------------------------------------------------------------
def test_non_gat_composite_skips_edge_arrays(rng):
    from repro.serve.graph_engine import assemble_batched_graph

    adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=9 + i))
            for i, n in enumerate([40, 60])]
    plans = [build_graph(a, tile=64, backend_cap=64) for a in adjs]
    lean = assemble_batched_graph(plans, 64, 128, with_edges=False)
    assert lean.graph.rows is None and lean.graph.plan.perm is None
    full = assemble_batched_graph(plans, 64, 128, with_edges=True)
    assert full.graph.rows is not None and full.graph.plan.perm is not None
    # the lean composite still aggregates identically for edge-free kinds
    xs = [rng.standard_normal((a.shape[0], 8)).astype(np.float32) for a in adjs]
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(2), cfg)
    o1 = gnn_forward_batched(params, cfg, lean, xs)
    o2 = gnn_forward_batched(params, cfg, full, xs)
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)
    # GAT on the edge-free composite fails loudly, not silently
    cfg_gat = GNNConfig(name="gat", kind="gat", d_in=8, d_hidden=8, n_classes=4)
    params_gat, _ = init_gnn(jax.random.PRNGKey(3), cfg_gat)
    with pytest.raises(ValueError, match="with_edges"):
        gnn_forward_batched(params_gat, cfg_gat, lean, xs)


def test_engine_composite_key_carries_edge_component(rng):
    """Same member graphs under a GAT model and a GCN model must resolve
    to different composite plans (edges vs no edges) while sharing the
    member plans."""
    from repro.serve.graph_engine import (
        GraphEngineConfig, GraphRequest, GraphServeEngine,
    )

    cfg_gcn = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    cfg_gat = GNNConfig(name="gat", kind="gat", d_in=8, d_hidden=8, n_classes=4)
    pg, _ = init_gnn(jax.random.PRNGKey(0), cfg_gcn)
    pa, _ = init_gnn(jax.random.PRNGKey(1), cfg_gat)
    eng = GraphServeEngine(
        {"gcn": (pg, cfg_gcn), "gat": (pa, cfg_gat)},
        GraphEngineConfig(tile=64, cap=64),
    )
    adjs = [gcn_normalize(powerlaw_graph(40, 160, seed=11 + i)) for i in range(2)]
    xs = [rng.standard_normal((40, 8)).astype(np.float32) for _ in adjs]
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    eng.run()
    m1 = eng.metrics()
    assert m1["plan_cache_misses"] == 3  # 2 members + 1 composite
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=10 + i, adj=a, x=x, model="gat"))
    eng.run()
    m2 = eng.metrics()
    # the GAT wave reuses both member plans (hits) but must build its own
    # composite (edge-bearing) -> exactly one new miss
    assert m2["plan_cache_misses"] == m1["plan_cache_misses"] + 1
    assert m2["plan_cache_hits"] >= m1["plan_cache_hits"] + 2
    assert all(r.done for r in eng.completed)
