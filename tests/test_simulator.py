"""Simulator invariants + the paper's qualitative claims (§V)."""
import numpy as np
import pytest

from repro.simul import MachineConfig, geomean, load, simulate
from repro.simul.datasets import gcn_normalize, powerlaw_graph


@pytest.fixture(scope="module")
def ultra():
    return load("arxiv", max_edges=120_000)


@pytest.fixture(scope="module")
def highly():
    return load("cobuy_photo", max_edges=120_000)


def test_iso_mac_across_formats(ultra):
    """Paper §V-A: comparisons are iso-MAC (BCSR is the deliberate dense
    exception)."""
    f = 64
    macs = {
        fmt: simulate(ultra.adj, f, fmt).compute.macs
        for fmt in ["csr", "csc", "scv", "scv_z", "mp"]
    }
    ref = macs["csr"]
    for fmt, m in macs.items():
        assert m == ref, (fmt, m, ref)
    bcsr = simulate(ultra.adj, f, "bcsr", block=16).compute.macs
    assert bcsr > ref  # dense blocks do extra MACs


def test_scv_compute_beats_csr_on_ultra_sparse(ultra):
    res = {fmt: simulate(ultra.adj, 128, fmt) for fmt in ["csr", "csc", "scv_z"]}
    assert res["csr"].compute_cycles > res["scv_z"].compute_cycles
    assert res["csc"].compute_cycles >= res["scv_z"].compute_cycles


def test_idle_cycles_ordering(ultra):
    res = {fmt: simulate(ultra.adj, 128, fmt) for fmt in ["csr", "scv_z"]}
    # Fig. 8: orders of magnitude more idle for CSR on ultra-sparse
    assert res["csr"].idle_cycles > 50 * max(res["scv_z"].idle_cycles, 1)


def test_traffic_reduction(ultra, highly):
    for g in (ultra, highly):
        res = {fmt: simulate(g.adj, 128, fmt) for fmt in ["csr", "csc", "scv_z"]}
        assert res["csr"].traffic_bytes > res["scv_z"].traffic_bytes
        assert res["csc"].traffic_bytes > res["scv_z"].traffic_bytes


def test_overall_speedup_positive(ultra, highly):
    for g in (ultra, highly):
        res = {
            fmt: simulate(g.adj, 128, fmt)
            for fmt in ["csr", "csc", "mp", "scv_z"]
        }
        for base in ["csr", "csc", "mp"]:
            assert res[base].total_cycles > res["scv_z"].total_cycles, base


def test_scv_z_no_worse_than_scv(ultra):
    rz = simulate(ultra.adj, 128, "scv_z")
    rr = simulate(ultra.adj, 128, "scv")
    # Z ordering helps (or at least does not hurt) cache-level traffic
    assert rz.memory.dram_bytes <= rr.memory.dram_bytes * 1.05


def test_width_sweep_width1_wins(ultra):
    """Fig. 13: widening tiles beyond 1 column hurts (zero-skipping
    granularity)."""
    from repro.simul.dataflows import run_scv_width

    cfg = MachineConfig()
    lat = {}
    for w in [1, 4, 16]:
        comp, traffic = run_scv_width(ultra.adj, 128, cfg, height=64, width=w)
        lat[w] = traffic.total_bytes
    assert lat[1] < lat[4] < lat[16]


def test_multipass_traffic_regular_but_compute_heavy(ultra):
    mp = simulate(ultra.adj, 128, "mp")
    scv = simulate(ultra.adj, 128, "scv_z")
    assert mp.compute_cycles > scv.compute_cycles  # re-scan overhead
    assert mp.memory.mat <= scv.memory.mat * 1.5  # regular DRAM access


def test_dataset_registry_stats():
    from repro.simul.datasets import TABLE_I

    assert len(TABLE_I) == 10
    g = load("citeseer", max_edges=50_000)
    spec = TABLE_I["citeseer"]
    assert abs(g.adj.shape[0] - spec.nodes) / spec.nodes < 0.05
    # density should be in the ballpark of Table I (self loops added)
    dens = g.adj.nnz / (g.adj.shape[0] ** 2)
    assert dens < 10 * (spec.edges / spec.nodes**2 + 1.0 / spec.nodes)
