"""Benchmark-harness smoke: each figure function returns sane rows (tiny
dataset budget so CI stays fast)."""
import benchmarks.figures as F
import pytest


@pytest.fixture(autouse=True)
def small_budget(monkeypatch):
    monkeypatch.setattr(F, "MAX_EDGES", 40_000)
    monkeypatch.setattr(F, "DATASETS", ["citeseer", "cobuy_photo"])


def test_fig7_rows():
    rows = F.fig7_compute_cycles()
    assert any(r["dataset"].startswith("geomean") for r in rows)
    for r in rows:
        assert r["speedup"] > 0


def test_fig9_scv_wins():
    rows = F.fig9_memory_traffic()
    # vs CSR/CSC the reduction holds even at toy scale; MP can tie when a
    # 40k-edge graph fits one pass, so it is excluded here
    g = [r for r in rows
         if r["dataset"] == "citeseer" and r["ours"] == "scv_z"
         and r["baseline"] in ("csr", "csc")]
    assert g and all(r["reduction"] > 1.0 for r in g)


def test_fig12_height_rows():
    rows = F.fig12_height_sweep()
    heights = {r["height"] for r in rows}
    assert heights == {128, 256, 512, 1024, 2048}


def test_fig14_speedup_monotone_early():
    rows = F.fig14_scalability()
    arx = {r["processors"]: r["speedup"] for r in rows if r["dataset"] == "arxiv"}
    assert arx[4] > arx[2] > 1.0
    assert all(r["speedup_no_merge"] >= r["speedup"] - 1e-9 for r in rows)


def test_roofline_builder():
    import os

    from benchmarks.roofline import build_table

    path = "results/dryrun_single_pod.json"
    if not os.path.exists(path):
        pytest.skip("no dry-run artifact")
    t = build_table(path)
    assert len(t) == 32
    for r in t:
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_fraction"] <= 1
