"""Autotuner subsystem (repro.tune): TunedConfig, histogram/ladder edge
cases, signature stability, slot-priced byte model, store round-trips,
two-stage search caching, and the serve engine's autotune integration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.exec import PlanExecutor, placement_bytes
from repro.core.formats import COOMatrix
from repro.core.scv import (
    DEFAULT_CAP,
    DEFAULT_CHUNK,
    DEFAULT_LADDER,
    DEFAULT_TILE,
    MIN_BUCKET_CAP,
    MXU_VPU_RATIO,
    bucket_caps_for,
    coo_to_scv_tiles,
    launched_slots,
    plan_from_tiles_bucketed,
    tile_nnz_histogram,
)
from repro.models.gnn import build_graph
from repro.simul.datasets import gcn_normalize, powerlaw_graph
from repro.simul.machine import MachineConfig
from repro.tune import (
    Autotuner,
    TuneStore,
    TunedConfig,
    cache_key,
    histogram_signature,
    machine_fingerprint,
    plan_launched_slots,
    quantize_histogram,
    spearman,
)


def _empty_coo(n=128):
    z = np.zeros(0, np.int32)
    return COOMatrix(z, z.copy(), np.zeros(0, np.float32), (n, n))


# ---------------------------------------------------------------------------
# histogram / ladder edge cases (satellite 3)
# ---------------------------------------------------------------------------
def test_tile_nnz_histogram_empty_graph():
    counts = tile_nnz_histogram(_empty_coo(), 64)
    assert counts.size == 0
    assert bucket_caps_for(counts, 64) == (MIN_BUCKET_CAP,)


def test_bucket_caps_single_mega_tile_clamps_to_dense():
    # one fully dense 64x64 tile: 4096 entries == T^2, the maximum any
    # tile can hold — the ladder tops out at T^2, never above
    r, c = np.meshgrid(np.arange(64, dtype=np.int32),
                       np.arange(64, dtype=np.int32))
    adj = COOMatrix(r.ravel(), c.ravel(),
                    np.ones(64 * 64, np.float32), (64, 64))
    counts = tile_nnz_histogram(adj, 64)
    assert list(counts) == [4096]
    caps = bucket_caps_for(counts, 64)
    assert caps == (64, 256, 1024, 4096)
    # a hypothetical count above T^2 (can't arise from unique entries)
    # still clamps to the dense size
    assert bucket_caps_for(np.array([5000]), 64) == (64, 256, 1024, 4096)


def test_launched_slots_edge_cases():
    # empty histogram: only the coverage bound
    assert launched_slots(np.zeros(0, np.int64), 64, (8, 32)) == 0
    assert launched_slots(np.zeros(0, np.int64), 64, (8, 32), n_row_blocks=4) == 32
    # chain-split at the top cap: 70 entries at caps (8, 32) ->
    # 2 full 32-chunks + remainder 6 in the 8-cap bucket
    assert launched_slots(np.array([70]), 64, (8, 32)) == 32 + 32 + 8
    # exact-fit remainder lands in its own cap, not the next one up
    assert launched_slots(np.array([8]), 64, (8, 32)) == 8
    with pytest.raises(ValueError):
        launched_slots(np.array([1]), 64, ())


def test_launched_slots_brackets_built_plan():
    adj = powerlaw_graph(1 << 12, 40_000, seed=3)
    T = 64
    counts = tile_nnz_histogram(adj, T)
    caps = bucket_caps_for(counts, T)
    tiles = coo_to_scv_tiles(adj, T, cap=caps[-1])
    plan = plan_from_tiles_bucketed(tiles, caps=caps)
    built = plan_launched_slots(plan)
    lo = launched_slots(counts, T, caps)  # no coverage dummies
    hi = launched_slots(
        counts, T, caps, n_row_blocks=-(-adj.shape[0] // T)
    )  # every block row dummied — the upper bound
    assert lo <= built <= hi
    # tile slots (sans coverage) must match the split arithmetic exactly
    n_cov = built - lo
    assert 0 <= n_cov <= (-(-adj.shape[0] // T)) * caps[0]


# ---------------------------------------------------------------------------
# signature stability (satellite 3: cache key under ±1 perturbations)
# ---------------------------------------------------------------------------
def test_histogram_signature_stable_under_unit_perturbations():
    adj = powerlaw_graph(1 << 13, 120_000, seed=0)
    counts = tile_nnz_histogram(adj, DEFAULT_TILE)
    sig = histogram_signature(counts)
    for idx in (0, counts.size // 2, counts.size - 1):
        for delta in (-1, +1):
            pert = counts.copy()
            pert[idx] = max(1, pert[idx] + delta)
            assert histogram_signature(pert) == sig, (idx, delta)
    # dropping / adding one whole tile is also sub-quantum
    assert histogram_signature(counts[1:]) == sig
    assert histogram_signature(np.append(counts, counts[-1])) == sig


def test_histogram_signature_separates_regimes():
    sparse = tile_nnz_histogram(powerlaw_graph(1 << 13, 120_000, seed=0),
                                DEFAULT_TILE)
    dense = tile_nnz_histogram(
        gcn_normalize(powerlaw_graph(256, 30_000, seed=0)), DEFAULT_TILE
    )
    assert histogram_signature(sparse) != histogram_signature(dense)
    assert quantize_histogram(sparse, DEFAULT_TILE) != quantize_histogram(
        dense, DEFAULT_TILE
    )


def test_machine_fingerprint_tracks_config():
    base = machine_fingerprint(MachineConfig())
    assert machine_fingerprint(MachineConfig()) == base
    assert machine_fingerprint(MachineConfig(dram_gbps=2.0)) != base
    assert cache_key("abc", base) != cache_key("abd", base)


# ---------------------------------------------------------------------------
# TunedConfig
# ---------------------------------------------------------------------------
def test_tuned_config_defaults_mirror_core_constants():
    cfg = TunedConfig.default()
    assert (cfg.tile, cfg.chunk, cfg.cap) == (
        DEFAULT_TILE, DEFAULT_CHUNK, DEFAULT_CAP
    )
    assert cfg.bucket_caps == DEFAULT_LADDER
    assert cfg.dense_threshold_ratio == MXU_VPU_RATIO
    assert cfg.dense_tile_threshold() == int(
        DEFAULT_TILE * DEFAULT_TILE * MXU_VPU_RATIO
    )


def test_tuned_config_equality_ignores_source():
    a = TunedConfig(source="default")
    b = dataclasses.replace(a, source="calibrated")
    assert a == b and hash(a) == hash(b)
    assert a != dataclasses.replace(a, tile=128)


def test_tuned_config_validation():
    with pytest.raises(ValueError):
        TunedConfig(tile=48)  # not a power of two
    with pytest.raises(ValueError):
        TunedConfig(bucket_caps=(32, 8))  # descending
    with pytest.raises(ValueError):
        TunedConfig(dense_threshold_ratio=0.0)
    assert TunedConfig(bucket_caps=()).cap_signature == DEFAULT_CAP
    assert TunedConfig().cap_signature == DEFAULT_LADDER


def test_tuned_config_json_roundtrip():
    cfg = TunedConfig(tile=128, chunk=64, bucket_caps=(16, 64, 256))
    assert TunedConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# slot-priced placement byte model (satellite 1)
# ---------------------------------------------------------------------------
def test_placement_bytes_n_slots_prices_launched_plan():
    nnz, slots, f = 10_000, 23_456, 64
    legacy = placement_bytes(nnz, f, 2, 1, n_rows=4096)
    slotted = placement_bytes(nnz, f, 2, 1, n_rows=4096, n_slots=slots)
    b = MachineConfig().bytes_per_elem
    assert legacy["plan"] == 3 * nnz * b / 2
    assert slotted["plan"] == 3 * slots * b / 2
    # only the plan term (and the totals through it) may move
    for k in ("z_slab", "out", "z_gather", "collective"):
        assert slotted[k] == legacy[k]
    assert slotted["resident"] - legacy["resident"] == pytest.approx(
        3 * (slots - nnz) * b / 2
    )


def test_executor_decide_uses_exact_plan_slots():
    adj = powerlaw_graph(1 << 12, 40_000, seed=1)
    g = build_graph(adj, config=TunedConfig.default())
    ex = PlanExecutor()
    dec = ex.decide(g.plan, 64)
    # single test device -> replicated; the point is the path runs and
    # prices the plan's real launched slots without touching device data
    assert dec.kind in ("replicated", "tiles", "features", "2d")
    assert plan_launched_slots(g.plan) == sum(
        int(s.n_tiles) * int(s.cap) for s in g.plan.segments
    )


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------
def test_store_roundtrip_on_disk(tmp_path):
    path = tmp_path / "tune.json"
    s1 = TuneStore(path)
    assert s1.get("k") is None
    cfg = TunedConfig(tile=128, bucket_caps=(16, 64), source="calibrated")
    s1.put("k", cfg, meta={"note": 1})
    s2 = TuneStore(path)  # fresh process view
    got = s2.get("k")
    assert got == cfg
    assert s2.hits == 1 and s1.misses == 1


def test_store_corrupt_file_is_empty(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{not json")
    s = TuneStore(path)
    assert len(s) == 0 and s.get("k") is None


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------
def test_autotuner_search_then_cache_hit(tmp_path):
    adj = powerlaw_graph(1 << 12, 60_000, seed=0)
    store = TuneStore(tmp_path / "tune.json")
    tuner = Autotuner(store=store, calibrate=False)
    cfg = tuner.tune(adj, n_features=16)
    assert tuner.searches == 1 and tuner.cache_hits == 0
    assert cfg.source == "simulated"
    assert len(tuner.last_result.candidates) > 3
    # same regime again: store hit, no re-search
    assert tuner.tune(adj, n_features=16) == cfg
    assert tuner.searches == 1 and tuner.cache_hits == 1
    assert tuner.last_result.cached
    # a fresh tuner sharing the on-disk store inherits the hit
    t2 = Autotuner(store=TuneStore(tmp_path / "tune.json"), calibrate=False)
    assert t2.tune(adj, n_features=16) == cfg
    assert t2.searches == 0 and t2.cache_hits == 1


def test_autotuner_machine_change_is_stale(tmp_path):
    adj = powerlaw_graph(1 << 12, 60_000, seed=0)
    store = TuneStore(tmp_path / "tune.json")
    Autotuner(store=store, calibrate=False).tune(adj, n_features=16)
    other = Autotuner(
        machine=MachineConfig(dram_gbps=4.0), store=store, calibrate=False
    )
    other.tune(adj, n_features=16)
    assert other.searches == 1  # fingerprint miss -> fresh search
    assert len(store) == 2


def test_autotuner_calibration_includes_default_control():
    adj = powerlaw_graph(1 << 12, 60_000, seed=0)
    tuner = Autotuner(top_k=2, calib_reps=1)
    cfg = tuner.tune(adj, n_features=16)
    res = tuner.last_result
    assert cfg.source == "calibrated"
    measured = {(c.config.tile, c.config.bucket_caps) for c in res.calibrated}
    assert (DEFAULT_TILE, DEFAULT_LADDER) in measured
    # winner is measured-best, so it can never lose to the default
    best = min(res.calibrated, key=lambda c: c.measured_s)
    assert (cfg.tile, cfg.bucket_caps) == (
        best.config.tile, best.config.bucket_caps
    )
    assert res.rank_correlation is not None


def test_autotuner_empty_graph_returns_default():
    tuner = Autotuner(calibrate=False)
    assert tuner.tune(_empty_coo()) == TunedConfig.default()
    assert tuner.searches == 0


def test_spearman():
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1.0], [2.0]) == 1.0


# ---------------------------------------------------------------------------
# build_graph / plan_from_tiles_bucketed config threading
# ---------------------------------------------------------------------------
def test_build_graph_accepts_tuned_config():
    adj = gcn_normalize(powerlaw_graph(300, 2_000, seed=2))
    cfg = TunedConfig(tile=32, bucket_caps=(8, 32))
    g = build_graph(adj, config=cfg)
    assert g.plan.tile == 32
    assert tuple(s.cap for s in g.plan.segments) == (8, 32)
    # explicit layout args conflict with a config
    with pytest.raises(ValueError):
        build_graph(adj, tile=32, config=cfg)
    # empty ladder -> single-cap plan at config.cap
    g2 = build_graph(adj, config=TunedConfig(bucket_caps=(), cap=16))
    assert not hasattr(g2.plan, "segments") and g2.plan.cap == 16


def test_plan_from_tiles_bucketed_config():
    adj = powerlaw_graph(1 << 10, 8_000, seed=4)
    cfg = TunedConfig(tile=64, bucket_caps=(8, 32))
    tiles = coo_to_scv_tiles(adj, cfg.tile, cap=cfg.bucket_caps[-1])
    plan = plan_from_tiles_bucketed(tiles, config=cfg)
    assert tuple(s.cap for s in plan.segments) == (8, 32)
    with pytest.raises(ValueError):
        plan_from_tiles_bucketed(tiles, caps=(8, 32), config=cfg)


# ---------------------------------------------------------------------------
# serve engine integration
# ---------------------------------------------------------------------------
def _autotune_engine(tmp_path=None, **cfg_kw):
    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve.graph_engine import GraphEngineConfig, GraphServeEngine

    mcfg = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), mcfg)
    ecfg = GraphEngineConfig(**cfg_kw)
    return GraphServeEngine({"gcn": (params, mcfg)}, ecfg), params, mcfg


def test_engine_autotune_matches_default_outputs(rng):
    from repro.models.gnn import gnn_forward
    from repro.serve.graph_engine import GraphRequest

    adjs = [gcn_normalize(powerlaw_graph(n, 4 * n, seed=9 + i))
            for i, n in enumerate([90, 150])]
    xs = [rng.standard_normal((a.shape[0], 8)).astype(np.float32)
          for a in adjs]
    eng, params, mcfg = _autotune_engine(autotune=True)
    assert eng.tuner is not None
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    assert len(done) == 2 and all(r.done for r in done)
    import jax.numpy as jnp

    for r in done:
        # the tuned layout must be numerically irrelevant
        ref = np.asarray(gnn_forward(
            params, mcfg, build_graph(r.adj), jnp.asarray(r.x)
        ))
        np.testing.assert_allclose(r.out, ref, atol=1e-5, rtol=1e-5)
    m = eng.metrics()
    assert m["autotune_enabled"] and m["autotune_searches"] >= 1
    assert m["resolved_configs"], "resolved configs must surface in metrics"


def test_engine_autotune_off_uses_fallback_literals():
    eng, _, _ = _autotune_engine()
    m = eng.metrics()
    assert not m["autotune_enabled"] and m["autotune_searches"] == 0
    assert eng._fallback_config.tile == DEFAULT_TILE
    assert eng._fallback_config.bucket_caps == DEFAULT_LADDER
