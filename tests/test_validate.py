"""Mutation tests for core/validate.py (scvcheck leg 1).

Each invariant class gets a green baseline plus a corrupted plan whose
failing ``ValidationReport`` must *name the offender* (tile / segment /
span indices) — the acceptance criterion of ISSUE 6.  Corruptions are
made on host numpy copies via ``dataclasses.replace`` so each test
mutates exactly one invariant's witness.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import coo_to_scv_tiles, plan_from_tiles, plan_from_tiles_bucketed
from repro.core.exec import PlanExecutor, ShardingDecision
from repro.core.formats import COOMatrix
from repro.core.validate import (
    PlanInvariantError,
    check_coo,
    validate_plan,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph


def _coo(n=96, edges=500, seed=0):
    return gcn_normalize(powerlaw_graph(n, edges, seed=seed))


def _plan(coo=None, tile=16, cap=32):
    coo = coo if coo is not None else _coo()
    return plan_from_tiles(coo_to_scv_tiles(coo, tile, cap=cap))


def _as_np(plan):
    """Writable numpy copies of every leaf (frozen pytrees hold jnp)."""
    return {
        f: np.array(getattr(plan, f))
        for f in ("tile_row", "tile_col", "rows", "cols", "vals", "nnz_in_tile")
    } | ({"perm": np.array(plan.perm)} if plan.perm is not None else {})


# ---------------------------------------------------------------------------
# green baselines
# ---------------------------------------------------------------------------
def test_valid_plan_passes_with_reassembly():
    coo = _coo()
    rep = validate_plan(_plan(coo), coo=coo)
    assert rep.ok, rep.summary()
    assert rep.kind == "plan"
    assert {c.invariant for c in rep.checks} >= {
        "shape-aux", "bounds", "cap", "packing", "order",
        "coverage", "coverage-contiguity", "perm", "reassembly",
    }


def test_valid_tiles_and_bucketed_pass():
    coo = _coo()
    tiles = coo_to_scv_tiles(coo, 16, cap=32)
    assert validate_plan(tiles, coo=coo).ok
    bplan = plan_from_tiles_bucketed(tiles, caps=(4, 8, 32))
    rep = validate_plan(bplan, coo=coo)
    assert rep.ok, rep.summary()
    assert rep.kind == "bucketed"
    assert any(c.invariant == "ladder" for c in rep.checks)


def test_report_summary_and_raise():
    rep = validate_plan(_plan())
    assert "passed" in rep.summary()
    assert rep.raise_if_failed() is rep


# ---------------------------------------------------------------------------
# mutations: each invariant class, offender named
# ---------------------------------------------------------------------------
def test_mutation_order_names_tile():
    p = _plan()
    leaves = _as_np(p)
    real = np.flatnonzero(leaves["nnz_in_tile"] > 0)
    assert len(real) >= 2
    i, j = int(real[0]), int(real[-1])
    for f in ("tile_row", "tile_col", "rows", "cols", "vals", "nnz_in_tile", "perm"):
        leaves[f][[i, j]] = leaves[f][[j, i]]
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("order")
    assert fails, rep.summary()
    assert any(f.offending for f in fails)
    with pytest.raises(PlanInvariantError) as ei:
        rep.raise_if_failed()
    assert ei.value.report is rep


def test_mutation_coverage_names_missing_row():
    p = _plan()
    leaves = _as_np(p)
    # orphan one block-row: point every tile that visits the last row at
    # row 0 instead
    last = int(leaves["tile_row"].max())
    leaves["tile_row"][leaves["tile_row"] == last] = 0
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("coverage")
    assert fails and last in fails[0].offending, rep.summary()


def test_mutation_contiguity_names_second_run():
    p = _plan()
    leaves = _as_np(p)
    rows = leaves["tile_row"]
    # split block-row 0 into two runs by moving its first visit to the end
    first = int(np.flatnonzero(rows == 0)[0])
    order = np.r_[np.delete(np.arange(len(rows)), first), first]
    for f in ("tile_row", "tile_col", "rows", "cols", "vals", "nnz_in_tile", "perm"):
        leaves[f] = leaves[f][order]
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("coverage-contiguity")
    assert fails and fails[0].offending, rep.summary()
    assert int(fails[0].offending[0]) == len(rows) - 1  # the moved tile


def test_mutation_cap_names_tile():
    p = _plan()
    leaves = _as_np(p)
    leaves["nnz_in_tile"][0] = p.cap + 5
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("cap")
    assert fails and fails[0].offending == (0,), rep.summary()
    assert str(p.cap) in fails[0].detail


def test_mutation_packing_names_tile():
    p = _plan()
    leaves = _as_np(p)
    t = int(np.flatnonzero(leaves["nnz_in_tile"] < p.cap)[0])
    leaves["vals"][t, -1] = 7.5  # dirty a padding slot
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("packing")
    assert fails and t in fails[0].offending, rep.summary()


def test_mutation_perm_duplicate_detected():
    p = _plan()
    leaves = _as_np(p)
    real = np.flatnonzero(leaves["nnz_in_tile"] >= 2)
    t = int(real[0])
    leaves["perm"][t, 1] = leaves["perm"][t, 0]  # gather one entry twice
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("perm")
    assert fails and "more than once" in fails[0].detail, rep.summary()


def test_mutation_bounds_names_tile():
    p = _plan()
    leaves = _as_np(p)
    t = int(np.flatnonzero(leaves["nnz_in_tile"] > 0)[0])
    leaves["rows"][t, 0] = p.tile  # local index past the tile edge
    rep = validate_plan(dataclasses.replace(p, **leaves))
    fails = rep.failed("bounds")
    assert fails and t in fails[0].offending, rep.summary()


def test_mutation_ladder_names_segment_and_tile():
    coo = _coo()
    bplan = plan_from_tiles_bucketed(coo_to_scv_tiles(coo, 16, cap=32), caps=(4, 8, 32))
    hot = None
    for j, seg in enumerate(bplan.segments):
        nnz = np.array(seg.nnz_in_tile)
        if j > 0 and (nnz > 0).any():
            hot = (j, seg, nnz)
            break
    assert hot is not None, "graph produced no tile past the first bucket"
    j, seg, nnz = hot
    t = int(np.flatnonzero(nnz > 0)[0])
    nnz[t] = 1  # belongs in bucket 0, claims segment j
    mutated = dataclasses.replace(seg, nnz_in_tile=nnz)
    segs = tuple(mutated if k == j else s for k, s in enumerate(bplan.segments))
    rep = validate_plan(dataclasses.replace(bplan, segments=segs))
    fails = rep.failed("ladder")
    assert fails, rep.summary()
    assert fails[0].segment == j and t in fails[0].offending


def test_mutation_reassembly_detects_value_drift():
    coo = _coo()
    p = _plan(coo)
    leaves = _as_np(p)
    t = int(np.flatnonzero(leaves["nnz_in_tile"] > 0)[0])
    leaves["vals"][t, 0] += 1.0
    rep = validate_plan(dataclasses.replace(p, **leaves), coo=coo)
    assert rep.failed("reassembly"), rep.summary()


# ---------------------------------------------------------------------------
# sharded plans (tile_parts=1 keeps this single-device; the multi-device
# spans are exercised by test_exec.py's subprocess tier + the round-trip
# property test)
# ---------------------------------------------------------------------------
def _sharded(coo=None):
    coo = coo if coo is not None else _coo()
    bplan = plan_from_tiles_bucketed(coo_to_scv_tiles(coo, 16, cap=32), caps=(8, 32))
    sp = PlanExecutor().prepare(bplan, decision=ShardingDecision("tiles", 1, 1))
    return coo, sp


def test_valid_sharded_passes():
    coo, sp = _sharded()
    rep = validate_plan(sp, coo=coo)
    assert rep.ok, rep.summary()
    assert rep.kind == "sharded"
    assert any(c.invariant == "shard-coverage" for c in rep.checks)


def test_mutation_shard_span_leading_axis():
    coo, sp = _sharded()
    # decision claims 2 spans, arrays carry 1: layout contract broken
    broken = dataclasses.replace(sp, decision=ShardingDecision("tiles", 2, 1))
    rep = validate_plan(broken)
    fails = rep.failed("shard-span")
    assert fails, rep.summary()
    assert fails[0].segment == 0 and "tile_parts" in fails[0].detail


def test_mutation_shard_span_order_names_segment_and_part():
    coo, sp = _sharded()
    segs = list(sp.segments)
    for j, seg in enumerate(segs):
        nnz = np.array(seg.nnz_in_tile)[0]
        real = np.flatnonzero(nnz > 0)
        if len(real) < 2:
            continue
        i, k = int(real[0]), int(real[-1])
        leaves = {}
        for f in ("tile_row", "tile_col", "rows", "cols", "vals",
                  "nnz_in_tile", "perm"):
            a = np.array(getattr(seg, f))
            a[0, [i, k]] = a[0, [k, i]]
            leaves[f] = a
        segs[j] = dataclasses.replace(seg, **leaves)
        rep = validate_plan(dataclasses.replace(sp, segments=tuple(segs)))
        fails = rep.failed("order")
        assert fails, rep.summary()
        assert fails[0].segment == j and fails[0].part == 0
        return
    pytest.skip("no segment with two real tiles in one span")


# ---------------------------------------------------------------------------
# COO admission hook
# ---------------------------------------------------------------------------
def test_check_coo_accepts_valid():
    check_coo(_coo(), square=True)
    check_coo(COOMatrix(rows=np.zeros(0, np.int32), cols=np.zeros(0, np.int32),
                        vals=np.zeros(0, np.float32), shape=(4, 4)))


@pytest.mark.parametrize(
    "mutate,match",
    [
        (lambda a: dataclasses.replace(a, rows=a.rows - a.rows.max() - 1),
         "non-negative"),
        (lambda a: dataclasses.replace(a, cols=a.cols + a.shape[1]),
         "out of range"),
        (lambda a: dataclasses.replace(a, vals=np.full_like(a.vals, np.nan)),
         "finite"),
        (lambda a: dataclasses.replace(a, vals=a.vals[:-1]), "disagree on nnz"),
        (lambda a: dataclasses.replace(a, shape=(a.shape[0], a.shape[1] + 1)),
         "square"),
    ],
)
def test_check_coo_rejections(mutate, match):
    with pytest.raises(ValueError, match=match):
        check_coo(mutate(_coo()), square=True)


def test_validate_plan_rejects_unknown_type():
    with pytest.raises(TypeError, match="unsupported object"):
        validate_plan(object())
