"""Plan cache semantics: content keys, hit/miss/eviction, byte budget."""
import numpy as np
import pytest

from repro.core.formats import COOMatrix
from repro.serve.plan_cache import (
    PlanCache,
    combine_keys,
    coo_content_key,
    delta_key,
    plan_nbytes,
)
from repro.stream import DeltaBatch


def _coo(seed=0, n=32, nnz=64):
    rng = np.random.default_rng(seed)
    return COOMatrix(
        rng.integers(0, n, nnz).astype(np.int32),
        rng.integers(0, n, nnz).astype(np.int32),
        rng.standard_normal(nnz).astype(np.float32),
        (n, n),
    )


# ---------------------------------------------------------------------------
# content keys
# ---------------------------------------------------------------------------
def test_content_key_is_content_addressed():
    a = _coo(0)
    b = COOMatrix(a.rows.copy(), a.cols.copy(), a.vals.copy(), a.shape)
    assert coo_content_key(a, tile=64) == coo_content_key(b, tile=64)


def test_content_key_separates_content_and_params():
    a, b = _coo(0), _coo(1)
    assert coo_content_key(a, tile=64) != coo_content_key(b, tile=64)
    assert coo_content_key(a, tile=64) != coo_content_key(a, tile=128)
    assert coo_content_key(a, tile=64, cap=32) != coo_content_key(a, tile=64, cap=64)


def test_content_key_framed_against_byte_aliasing():
    # int64 [5] and int32 [5, 0] share a byte representation; without
    # dtype/length framing these two DIFFERENT graphs would collide
    a = COOMatrix(
        np.array([5], np.int64),
        np.array([2], np.int64),
        np.array([1.0], np.float64),
        (8, 8),
    )
    b = COOMatrix(
        np.frombuffer(a.rows.tobytes(), np.int32).copy(),
        np.frombuffer(a.cols.tobytes(), np.int32).copy(),
        np.frombuffer(a.vals.tobytes(), np.float32).copy(),
        (8, 8),
    )
    assert coo_content_key(a, tile=64) != coo_content_key(b, tile=64)


def test_combine_keys_order_and_salt_sensitive():
    k1, k2 = coo_content_key(_coo(0), tile=64), coo_content_key(_coo(1), tile=64)
    assert combine_keys([k1, k2]) == combine_keys([k1, k2])
    assert combine_keys([k1, k2]) != combine_keys([k2, k1])
    assert combine_keys([k1, k2], salt="bucket=256") != combine_keys(
        [k1, k2], salt="bucket=512"
    )


# ---------------------------------------------------------------------------
# hit / miss / LRU / eviction
# ---------------------------------------------------------------------------
def test_hit_miss_counters():
    c = PlanCache(max_entries=4)
    assert c.get("k") is None
    c.put("k", "plan", nbytes=10)
    assert c.get("k") == "plan"
    assert (c.stats.hits, c.stats.misses) == (1, 1)
    assert c.stats.hit_rate == 0.5


def test_lru_eviction_order():
    c = PlanCache(max_entries=2)
    c.put("a", 1, nbytes=1)
    c.put("b", 2, nbytes=1)
    assert c.get("a") == 1  # refresh a; b is now LRU
    c.put("c", 3, nbytes=1)  # evicts b
    assert c.keys == ["a", "c"]
    assert c.stats.evictions == 1
    assert c.get("b") is None


def test_byte_budget_eviction():
    c = PlanCache(max_entries=100, max_bytes=100)
    c.put("a", 1, nbytes=60)
    c.put("b", 2, nbytes=60)  # 120 > 100 -> evict a
    assert "a" not in c and "b" in c
    assert c.stats.bytes_in_use == 60
    assert c.stats.evictions == 1


def test_put_same_key_replaces_bytes():
    c = PlanCache(max_entries=4, max_bytes=1000)
    c.put("a", 1, nbytes=100)
    c.put("a", 2, nbytes=300)
    assert c.stats.bytes_in_use == 300 and len(c) == 1
    assert c.peek("a") == 2


def test_get_or_build_builds_once():
    c = PlanCache(max_entries=4)
    calls = []
    for _ in range(3):
        v = c.get_or_build("k", lambda: calls.append(1) or "built", nbytes=1)
        assert v == "built"
    assert len(calls) == 1
    assert (c.stats.hits, c.stats.misses) == (2, 1)


def test_oversized_plan_not_retained():
    c = PlanCache(max_entries=4, max_bytes=10)
    v = c.get_or_build("big", lambda: "plan", nbytes=100)
    assert v == "plan" and len(c) == 0


def test_oversized_put_keeps_resident_entries():
    c = PlanCache(max_entries=4, max_bytes=100)
    c.put("a", 1, nbytes=40)
    c.put("b", 2, nbytes=40)
    c.put("big", 3, nbytes=500)  # can never fit: must not flush a and b
    assert c.keys == ["a", "b"]
    assert c.stats.bytes_in_use == 80 and c.stats.evictions == 0


def test_clear_resets_bytes():
    c = PlanCache()
    c.put("a", 1, nbytes=5)
    c.clear()
    assert len(c) == 0 and c.stats.bytes_in_use == 0


def test_invalid_budgets_rejected():
    with pytest.raises(ValueError):
        PlanCache(max_entries=0)
    with pytest.raises(ValueError):
        PlanCache(max_bytes=0)
    with pytest.raises(ValueError):
        PlanCache(max_age_s=0)


# ---------------------------------------------------------------------------
# TTL / refresh policy (injected clock — no sleeping)
# ---------------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def test_ttl_expires_entries_and_counts():
    clk = _FakeClock()
    c = PlanCache(max_entries=4, max_age_s=10.0, clock=clk)
    c.put("a", 1, nbytes=5)
    clk.now = 9.0
    assert c.get("a") == 1  # still fresh
    clk.now = 10.5
    assert c.get("a") is None  # expired -> miss
    assert c.stats.expired == 1
    assert (c.stats.hits, c.stats.misses) == (1, 1)
    assert c.stats.bytes_in_use == 0 and len(c) == 0


def test_ttl_get_or_build_refreshes():
    clk = _FakeClock()
    c = PlanCache(max_entries=4, max_age_s=5.0, clock=clk)
    builds = []
    for t in (0.0, 3.0, 6.0):  # 6.0 is > 5s after the t=0 build
        clk.now = t
        v = c.get_or_build("k", lambda: builds.append(clk.now) or clk.now, nbytes=1)
        assert v == builds[-1]
    assert builds == [0.0, 6.0]  # rebuilt exactly once, on expiry
    assert c.stats.expired == 1
    # the refreshed entry's TTL anchors at its rebuild time
    clk.now = 10.0
    assert c.get("k") == 6.0


def test_ttl_contains_and_peek_are_expiry_aware():
    clk = _FakeClock()
    c = PlanCache(max_entries=4, max_age_s=1.0, clock=clk)
    c.put("a", 1, nbytes=5)
    assert "a" in c and c.peek("a") == 1
    hits, misses = c.stats.hits, c.stats.misses
    clk.now = 2.0
    assert "a" not in c
    assert c.peek("a") is None
    # peek/contains never touch hit/miss counters
    assert (c.stats.hits, c.stats.misses) == (hits, misses)
    assert c.stats.expired >= 1


def test_no_ttl_entries_never_expire():
    clk = _FakeClock()
    c = PlanCache(max_entries=4, clock=clk)
    c.put("a", 1, nbytes=5)
    clk.now = 1e12
    assert c.get("a") == 1 and c.stats.expired == 0


# ---------------------------------------------------------------------------
# revalidation by delta (stream/ integration)
# ---------------------------------------------------------------------------
def test_delta_key_chains_and_separates():
    k = coo_content_key(_coo(0), tile=64)
    d1 = DeltaBatch.of(inserts=[(0, 1, 2.0)])
    d2 = DeltaBatch.of(inserts=[(0, 2, 2.0)])
    assert delta_key(k, d1) == delta_key(k, d1)
    assert delta_key(k, d1) != delta_key(k, d2)
    assert delta_key(k, d1) != k
    # chaining is order-sensitive: d1 then d2 != d2 then d1
    assert delta_key(delta_key(k, d1), d2) != delta_key(delta_key(k, d2), d1)


def test_revalidate_patches_and_rekeys_live_entry():
    c = PlanCache(max_entries=4)
    d = DeltaBatch.of(inserts=[(0, 1, 2.0)])
    c.put("k", 10, nbytes=8)
    new_key = c.revalidate("k", d, patch=lambda v: v + 1)
    assert new_key == delta_key("k", d)
    assert "k" not in c and c.peek(new_key) == 11
    assert c.stats.revalidated == 1
    assert len(c) == 1


def test_revalidate_absent_entry_degrades_to_miss():
    c = PlanCache(max_entries=4)
    d = DeltaBatch.of(removes=[(3, 4)])
    calls = []
    new_key = c.revalidate("never-cached", d, patch=lambda v: calls.append(v))
    assert new_key == delta_key("never-cached", d)
    assert calls == [] and len(c) == 0
    assert c.stats.revalidated == 0


def test_revalidate_without_patch_only_returns_key():
    c = PlanCache(max_entries=4)
    d = DeltaBatch.of(removes=[(3, 4)])
    c.put("k", 10, nbytes=8)
    new_key = c.revalidate("k", d)
    assert new_key == delta_key("k", d)
    # no patch callback: the entry stays under its old key, untouched
    assert c.peek("k") == 10 and c.peek(new_key) is None
    assert c.stats.revalidated == 0


def test_revalidate_expired_entry_degrades_to_miss():
    clk = _FakeClock()
    c = PlanCache(max_entries=4, max_age_s=1.0, clock=clk)
    c.put("k", 10, nbytes=8)
    clk.now = 5.0
    d = DeltaBatch.of(inserts=[(0, 1, 2.0)])
    new_key = c.revalidate("k", d, patch=lambda v: v + 1)
    assert new_key == delta_key("k", d)
    assert len(c) == 0 and c.stats.revalidated == 0


# ---------------------------------------------------------------------------
# byte accounting of real plans
# ---------------------------------------------------------------------------
def test_plan_nbytes_walks_real_graph_bundle():
    from repro.models.gnn import build_graph

    g = build_graph(_coo(0), tile=64, backend_cap=16)
    nb = plan_nbytes(g)
    # at least the plan's tile value array and its perm must be counted
    assert nb >= g.plan.vals.nbytes + np.asarray(g.plan.perm).nbytes


def test_plan_nbytes_dedupes_shared_arrays():
    arr = np.zeros(1000, np.float32)
    assert plan_nbytes({"a": arr, "b": arr}) == arr.nbytes


# ---------------------------------------------------------------------------
# thread safety (the async scheduler shares the cache with producers)
# ---------------------------------------------------------------------------
def test_cache_threadsafe_under_concurrent_mixed_load():
    """Hammer one cache from several threads mixing get_or_build,
    revalidate, anchor, and reads.  The contract (plan_cache.py docstring)
    is internal-consistency under concurrency: no lost byte accounting,
    no KeyError crashes, counters that add up."""
    import threading

    c = PlanCache(max_entries=64, max_bytes=1 << 20)
    errors = []
    barrier = threading.Barrier(4)

    def worker(tid):
        try:
            barrier.wait(timeout=10)
            for i in range(200):
                k = f"k{(tid + i) % 8}"
                c.get_or_build(k, lambda: np.zeros(16, np.float32))
                if i % 5 == 0:
                    d = DeltaBatch.of(inserts=[(0, tid, float(i + 1))])
                    c.revalidate(k, d, patch=lambda v: v)
                if i % 7 == 0:
                    c.anchor(f"k{tid}", f"anchored{tid}")
                c.get(k)
                c.peek(k)
                len(c), list(c.keys)
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert errors == []
    s = c.stats
    assert s.hits + s.misses > 0
    # byte accounting survived: recompute from the live entries
    assert s.entries == len(c.keys)
    assert s.bytes_in_use >= 0
