"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values (task spec requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.optim.adam import AdamConfig, init_adam

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    spec = ARCHS[arch]
    cfg = spec.cfg(reduced=True)
    params, _ = spec.init(jax.random.PRNGKey(0), reduced=True)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)}
    nfront = getattr(cfg, "n_frontend_tokens", 0)
    if nfront:
        batch["extra_embed"] = jnp.asarray(
            rng.standard_normal((B, nfront, cfg.d_model)), jnp.float32
        )
    if spec.kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32
        )

    opt = init_adam(params)
    step = spec.make_train_step(AdamConfig(lr=1e-3, warmup_steps=1, total_steps=10), reduced=True)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    assert int(new_opt["step"]) == 1
    # params actually changed and stayed finite
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert np.isfinite(delta) and delta > 0, arch
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize(
    "arch", [a for a, s in ARCHS.items() if s.kind in ("lm", "mamba_lm", "hybrid")]
)
def test_reduced_decode_consistency(arch):
    """Prefill+decode logits == direct forward logits (reduced configs)."""
    spec = ARCHS[arch]
    cfg = spec.cfg(reduced=True)
    params, _ = spec.init(jax.random.PRNGKey(0), reduced=True)
    rng = np.random.default_rng(1)
    B, S = 2, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    if spec.kind == "lm":
        from repro.models import layers as L
        from repro.models.transformer import decode_step, hidden_states, prefill

        _, cache = prefill(params, cfg, toks[:, : S - 1], max_len=S + 4)
        logits, _ = decode_step(
            params, cfg, toks[:, S - 1 :], cache, jnp.full((B, 1), S - 1, jnp.int32)
        )
        x, _, _ = hidden_states(params, cfg, toks)
        direct = L.unembed_logits(params["embed"], x[:, -1:], cfg.final_softcap, true_vocab=cfg.vocab)
    elif spec.kind == "mamba_lm":
        from repro.models import layers as L
        from repro.models.ssm import (init_mamba2_lm_state, mamba2_lm_decode,
                                      mamba2_lm_hidden)

        st = init_mamba2_lm_state(cfg, B)
        logits = None
        for t in range(S):
            logits, st = mamba2_lm_decode(params, cfg, toks[:, t : t + 1], st)
        x, _ = mamba2_lm_hidden(params, cfg, toks)
        direct = L.unembed_logits(params["embed"], x[:, -1:], true_vocab=cfg.vocab)
    else:
        from repro.models import layers as L
        from repro.models.hybrid import decode_step as hds, hidden_states as hhs, init_state

        st = init_state(cfg, B, S + 4)
        logits = None
        for t in range(S):
            logits, st = hds(params, cfg, toks[:, t : t + 1], st, jnp.full((B, 1), t, jnp.int32))
        x, _ = hhs(params, cfg, toks)
        direct = L.unembed_logits(params["embed"], x[:, -1:], true_vocab=cfg.vocab)

    lp = jax.nn.log_softmax(logits)
    ld = jax.nn.log_softmax(direct)
    # mask padded vocab (-inf rows) before compare
    err = float(jnp.abs(jnp.where(jnp.isfinite(lp), lp - ld, 0.0)).max())
    # MoE capacity drops can perturb slightly; dense archs are tight
    tol = 5e-2 if getattr(cfg, "moe", None) else 5e-3
    assert err < tol, (arch, err)


def test_registry_complete():
    assert len(ARCHS) == 10
    kinds = {s.kind for s in ARCHS.values()}
    assert kinds == {"lm", "mamba_lm", "hybrid", "encdec"}
    # shape-cell accounting: 32 runnable cells (spec: 40 - 8 long_500k skips)
    cells = sum(len(s.shapes) for s in ARCHS.values())
    assert cells == 32
    for s in ARCHS.values():
        if "long_500k" not in s.shapes:
            assert s.skip_notes, s.name
