"""Coverage-free accumulator-chained launches (DESIGN.md §2).

The bucketed executor used to launch each capacity segment as an
independent zero-initialized kernel and sum the partial outputs — every
segment therefore had to carry its own coverage-dummy tail so unvisited
PS strips were defined.  Segments now chain through ONE output
accumulator (``input_output_aliases``): segment 0 runs in legacy
zero-init mode and its coverage tail defines the whole output; segments
1+ seed each visited strip from the accumulator and pass unvisited
strips through.

Acceptance criteria covered here:

* coverage dummies exist exactly once per plan (segment 0 only),
* the chained forward is byte-identical to the per-segment-sum
  reference on integer inputs, for plain plans and through all four
  model kinds,
* grads (dvals / dZ) flow through the chain and match the reference
  autodiff,
* ``init="zeros"`` (the sharded-span mode: explicit zero accumulator,
  no coverage anywhere) matches too,
* sharded execution (tiles / features / 2-D meshes) of coverage-free
  plans stays on the oracle, and ``validate_plan`` stays green.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coo_from_dense, coo_to_scv_tiles
from repro.core.aggregate import aggregate_scv_plan
from repro.core.exec import PlanExecutor, ShardingDecision
from repro.core.scv import SCVBucketedPlan, bucket_tiles, plan_from_tiles_bucketed
from repro.core.validate import validate_plan
from repro.kernels.scv_spmm import ops as kops
from repro.kernels.scv_spmm import ref as kref
from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.simul.datasets import gcn_normalize, powerlaw_graph

KINDS = ["gcn", "sage", "gin", "gat"]


def _int_coo(rng, m, n, density):
    a = ((rng.random((m, n)) < density) * rng.integers(1, 5, (m, n))).astype(
        np.float32
    )
    return a


def _bucketed(rng, m=128, density=0.08, tile=16, caps=(8, 32, 128)):
    a = _int_coo(rng, m, m, density)
    coo = coo_from_dense(a)
    tiles = coo_to_scv_tiles(coo, tile, cap=max(caps))
    plan = plan_from_tiles_bucketed(tiles, caps)
    return a, coo, plan


def _dummy_counts(plan):
    """Coverage-dummy (zero-nnz) tile count per segment."""
    return [
        int((np.asarray(s.nnz_in_tile) == 0).sum()) for s in plan.segments
    ]


def test_coverage_dummies_first_segment_only(rng):
    _, coo, plan = _bucketed(rng)
    counts = _dummy_counts(plan)
    assert len(counts) >= 2, "want a real multi-segment ladder"
    assert all(c == 0 for c in counts[1:]), counts
    # and validate_plan accepts the coverage-free ladder
    rep = validate_plan(plan, coo=coo)
    assert rep.ok, rep


def test_chain_bit_identical_to_per_segment_sum(rng):
    _, _, plan = _bucketed(rng)
    z = jnp.asarray(rng.integers(-4, 5, (128, 24)).astype(np.float32))
    chained = np.asarray(
        kops.scv_spmm_plan(plan, z, interpret=True, feature_block=8)
    )
    # per-segment-sum baseline: zero-init every segment independently, add
    summed = np.zeros_like(chained)
    for seg in plan.segments:
        summed += np.asarray(kref.scv_spmm_reference_plan(seg, z))
    np.testing.assert_array_equal(chained, summed)


def test_init_zeros_matches_and_needs_no_coverage(rng):
    _, _, plan = _bucketed(rng)
    z = jnp.asarray(rng.integers(-4, 5, (128, 16)).astype(np.float32))
    oracle = np.asarray(kref.scv_spmm_reference_plan(plan, z))
    out = np.asarray(
        kops.scv_spmm_plan(
            plan, z, interpret=True, feature_block=8, init="zeros"
        )
    )
    np.testing.assert_array_equal(out, oracle)
    with pytest.raises(ValueError):
        kops.scv_spmm_plan(plan, z, interpret=True, init="sideways")


def test_chain_grads_match_reference(rng):
    _, _, plan = _bucketed(rng)
    z = jnp.asarray(rng.integers(-4, 5, (128, 16)).astype(np.float32))

    def loss_kernel(vals_list, z):
        segs = tuple(
            dataclasses.replace(s, vals=v)
            for s, v in zip(plan.segments, vals_list)
        )
        p = SCVBucketedPlan(segs)
        out = kops.scv_spmm_plan(p, z, interpret=True, feature_block=8)
        return jnp.sum(out * out)

    def loss_ref(vals_list, z):
        out = None
        for s, v in zip(plan.segments, vals_list):
            part = kref.scv_spmm_reference_plan(
                dataclasses.replace(s, vals=v), z
            )
            out = part if out is None else out + part
        return jnp.sum(out * out)

    vals_list = [s.vals for s in plan.segments]
    gv_k, gz_k = jax.grad(loss_kernel, argnums=(0, 1))(vals_list, z)
    gv_r, gz_r = jax.grad(loss_ref, argnums=(0, 1))(vals_list, z)
    np.testing.assert_allclose(np.asarray(gz_k), np.asarray(gz_r), atol=1e-4)
    for a, b in zip(gv_k, gv_r):
        if a.size:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-4
            )


@pytest.mark.parametrize("kind", KINDS)
def test_chain_forward_and_grads_all_kinds(kind, rng):
    adj = gcn_normalize(powerlaw_graph(96, 700, seed=3))
    g = build_graph(adj, tile=16, bucket_caps=(8, 32))
    assert all(c == 0 for c in _dummy_counts(g.plan)[1:])
    x = jnp.asarray(rng.standard_normal((96, 12)).astype(np.float32))

    def run(backend):
        cfg = GNNConfig(
            name=f"t-{kind}", kind=kind, d_in=12, d_hidden=16,
            n_classes=4, n_layers=2, backend=backend,
        )
        params, _ = init_gnn(jax.random.PRNGKey(0), cfg)

        def loss(p):
            y = gnn_forward(p, cfg, g, x)
            return jnp.sum(y * y)

        return loss(params), jax.grad(loss)(params)

    y_k, g_k = run("pallas_interpret")
    y_r, g_r = run("jnp")
    np.testing.assert_allclose(float(y_k), float(y_r), rtol=2e-4)
    for a, b in zip(jax.tree_util.tree_leaves(g_k),
                    jax.tree_util.tree_leaves(g_r)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3
        )


# ---------------------------------------------------------------------------
# 8 fake devices (subprocess: XLA flags must precede jax init): sharded
# spans chain with init="zeros" — no coverage, no per-segment sum tree
# ---------------------------------------------------------------------------
CHAIN_SHARD_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import (PlanExecutor, ShardingDecision, coo_to_scv_tiles,
                        plan_from_tiles_bucketed)
from repro.core.aggregate import aggregate_scv_plan
from repro.core.formats import COOMatrix
from repro.core.validate import validate_plan
from repro.simul.datasets import powerlaw_graph

res = {}
rng = np.random.default_rng(0)
adj = powerlaw_graph(700, 5000, seed=0)
adj = COOMatrix(adj.rows, adj.cols,
                rng.integers(-3, 4, adj.nnz).astype(np.float32), adj.shape)
tiles = coo_to_scv_tiles(adj, 32, cap=64)
bplan = plan_from_tiles_bucketed(tiles, caps=(8, 32, 64))
res["dummies"] = [int((np.asarray(s.nnz_in_tile) == 0).sum())
                  for s in bplan.segments]
z = jnp.asarray(rng.integers(-3, 4, (adj.shape[1], 16)).astype(np.float32))
single = np.asarray(aggregate_scv_plan(bplan, z, backend="jnp"))

ex = PlanExecutor()
for dec in (ShardingDecision("tiles", 4, 1),
            ShardingDecision("features", 1, 2),
            ShardingDecision("2d", 2, 2)):
    sp = ex.prepare(bplan, decision=dec)
    res[f"valid_{dec.kind}"] = bool(validate_plan(sp, coo=adj).ok)
    for backend in ("jnp", "pallas_interpret"):
        out = np.asarray(aggregate_scv_plan(sp, z, backend=backend))
        res[f"bit_{dec.kind}_{backend}"] = bool((out == single).all())
print(json.dumps(res))
'''


def test_sharded_coverage_free_on_oracle():
    import json
    import subprocess
    import sys

    res = subprocess.run(
        [sys.executable, "-c", CHAIN_SHARD_SCRIPT], capture_output=True,
        text=True, cwd=".", timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    r = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(c == 0 for c in r["dummies"][1:]), r
    for kind in ("tiles", "features", "2d"):
        assert r[f"valid_{kind}"], r
        assert r[f"bit_{kind}_jnp"], r
        assert r[f"bit_{kind}_pallas_interpret"], r
