"""Pallas SCV SpMM kernel: shape/dtype sweep vs the pure-jnp oracle
(interpret mode on CPU), VJP equivalence, coverage of empty block-rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coo_from_dense, coo_to_scv_tiles
from repro.core.aggregate import aggregate_scv_tiles, scv_device_arrays
from repro.kernels.scv_spmm import ops as kops
from repro.kernels.scv_spmm import ref as kref


def _case(seed, m, n, density, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = ((rng.random((m, n)) < density) * rng.standard_normal((m, n))).astype(
        np.float32
    )
    z = rng.standard_normal((n, 40)).astype(dtype)
    return a, z


SWEEP = [
    # (m, n, density, tile, f)
    (64, 64, 0.05, 8, 40),
    (100, 80, 0.02, 16, 40),
    (33, 57, 0.10, 8, 40),
    (128, 128, 0.001, 32, 40),
    (16, 300, 0.03, 16, 40),
    (300, 16, 0.03, 16, 40),
    (65, 65, 0.30, 8, 40),
]


@pytest.mark.parametrize("m,n,density,tile,f", SWEEP)
def test_kernel_matches_oracle(m, n, density, tile, f):
    a, z = _case(m * n, m, n, density)
    z = z[:, :f]
    tiles = coo_to_scv_tiles(coo_from_dense(a), tile)
    ref = a @ z
    out = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="pallas_interpret"))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
    out_j = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="jnp"))
    np.testing.assert_allclose(out_j, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("zdtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(zdtype):
    a, z = _case(7, 64, 64, 0.05)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 16)
    out = aggregate_scv_tiles(tiles, jnp.asarray(z, zdtype), backend="pallas_interpret")
    assert out.dtype == jnp.float32  # f32 accumulation
    ref = a @ z
    tol = 1e-4 if zdtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), ref, atol=tol, rtol=tol)


def test_empty_matrix():
    a = np.zeros((32, 32), np.float32)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8)
    z = np.ones((32, 8), np.float32)
    out = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="pallas_interpret"))
    assert out.shape == (32, 8) and np.all(out == 0)


def test_empty_block_rows_defined():
    """Rows 32..63 have no nonzeros; the kernel must still define them."""
    a = np.zeros((64, 64), np.float32)
    a[:16, :16] = np.eye(16)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8)
    z = np.random.default_rng(0).standard_normal((64, 24)).astype(np.float32)
    out = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="pallas_interpret"))
    np.testing.assert_allclose(out, a @ z, atol=1e-5)


def test_vjp_matches_reference():
    a, z = _case(11, 48, 48, 0.08)
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8)
    arr = scv_device_arrays(tiles)
    zj = jnp.asarray(z)

    def loss(zz, vv, backend):
        a2 = dict(arr)
        a2["vals"] = vv
        return (aggregate_scv_tiles(tiles, zz, backend=backend, arrays=a2) ** 2).sum()

    gz_p, gv_p = jax.grad(lambda zz, vv: loss(zz, vv, "pallas_interpret"), (0, 1))(
        zj, arr["vals"]
    )
    gz_r, gv_r = jax.grad(lambda zz, vv: loss(zz, vv, "jnp"), (0, 1))(zj, arr["vals"])
    np.testing.assert_allclose(np.asarray(gz_p), np.asarray(gz_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gv_p), np.asarray(gv_r), atol=1e-4)


def test_heavy_tile_splitting():
    """A tile with more entries than cap splits into a chain and still
    aggregates exactly."""
    rng = np.random.default_rng(5)
    a = np.zeros((32, 32), np.float32)
    a[:8, :8] = rng.standard_normal((8, 8))  # fully dense tile
    tiles = coo_to_scv_tiles(coo_from_dense(a), 8, cap=16)  # 64 entries > 16
    assert tiles.n_tiles > 1
    z = rng.standard_normal((32, 12)).astype(np.float32)
    out = np.asarray(aggregate_scv_tiles(tiles, jnp.asarray(z), backend="pallas_interpret"))
    np.testing.assert_allclose(out, a @ z, atol=1e-4)


def test_hybrid_backend_matches_oracle():
    """Beyond-paper hybrid (MXU dense tiles + SCV sparse tiles) is exact."""
    from repro.core.aggregate import aggregate_hybrid
    from repro.core.scv import split_hybrid

    rng = np.random.default_rng(9)
    a = ((rng.random((96, 96)) < 0.01) * rng.standard_normal((96, 96))).astype(
        np.float32
    )
    a[:32, 32:64] = rng.standard_normal((32, 32))  # one dense tile
    tiles = coo_to_scv_tiles(coo_from_dense(a), 32)
    sparse, dense = split_hybrid(tiles)
    assert dense.n_tiles >= 1 and sparse.nnz + int(dense.blocks.astype(bool).sum()) == tiles.nnz
    z = rng.standard_normal((96, 24)).astype(np.float32)
    out = np.asarray(aggregate_hybrid(tiles, jnp.asarray(z)))
    np.testing.assert_allclose(out, a @ z, atol=1e-4)


def test_hybrid_all_sparse_noop():
    rng = np.random.default_rng(10)
    a = ((rng.random((64, 64)) < 0.02) * 1.0).astype(np.float32)
    from repro.core.aggregate import aggregate_hybrid
    from repro.core.scv import split_hybrid

    tiles = coo_to_scv_tiles(coo_from_dense(a), 32)
    sparse, dense = split_hybrid(tiles)
    assert dense.n_tiles == 0
    z = rng.standard_normal((64, 8)).astype(np.float32)
    out = np.asarray(aggregate_hybrid(tiles, jnp.asarray(z)))
    np.testing.assert_allclose(out, a @ z, atol=1e-4)
