"""Tests for core/tracecheck.py (scvcheck leg 2).

The acceptance criterion of ISSUE 6: the trace-hazard harness reports
<= 1 retrace per padding bucket for all four model kinds — plus hazard
injections proving each detector actually fires.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tracecheck
from repro.models.gnn import GNNConfig, build_graph, init_gnn
from repro.simul.datasets import gcn_normalize, powerlaw_graph

KINDS = ("gcn", "sage", "gin", "gat")


def _graph(n, edges, seed, with_edges=False):
    coo = gcn_normalize(powerlaw_graph(n, edges, seed=seed))
    return build_graph(coo, tile=16, backend_cap=None, with_edges=with_edges,
                       bucket_caps=(8, 32))


def _features(n, d, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((n, d)), jnp.float32
    )


def _registry():
    models, examples = {}, {}
    for kind in KINDS:
        cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=16,
                        n_classes=4, n_layers=2, backend="jnp")
        params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
        models[kind] = (params, cfg)
        with_edges = kind == "gat"
        # two sizes = two padding buckets; a repeat of the first size must
        # NOT mint a third trace
        g64 = _graph(64, 300, seed=1, with_edges=with_edges)
        g96 = _graph(96, 500, seed=2, with_edges=with_edges)
        examples[kind] = [
            (g64, _features(64, 8, 1)),
            (g96, _features(96, 8, 2)),
            (g64, _features(64, 8, 3)),  # same bucket as example 0
        ]
    return models, examples


# ---------------------------------------------------------------------------
# the acceptance criterion
# ---------------------------------------------------------------------------
def test_all_four_kinds_one_trace_per_bucket():
    models, examples = _registry()
    rep = tracecheck.trace_check(models, examples)
    assert rep.ok, rep.summary()
    assert not rep.of_kind("retrace-bound")
    # every kind contributes exactly its two padding buckets
    by_model = {}
    for (name, _sig), n in rep.retraces:
        by_model.setdefault(name, []).append(n)
    assert set(by_model) == set(KINDS)
    for name, counts in by_model.items():
        assert len(counts) == 2, f"{name}: expected 2 buckets, got {len(counts)}"
        assert all(n <= 1 for n in counts), f"{name}: {counts}"


def test_retrace_counter_counts_traces_not_calls():
    calls = tracecheck.RetraceCounter(lambda x: x * 2)
    a = jnp.ones((4,), jnp.float32)
    calls(a), calls(a), calls(a)
    assert calls.traces == 1
    calls(jnp.ones((8,), jnp.float32))  # new shape, new trace
    assert calls.traces == 2


def test_bucket_signature_separates_shapes_and_aux():
    g64 = _graph(64, 300, seed=1)
    g64b = _graph(64, 300, seed=1)
    g96 = _graph(96, 500, seed=2)
    x = _features(64, 8)
    assert tracecheck.bucket_signature(g64, x) == tracecheck.bucket_signature(g64b, x)
    assert tracecheck.bucket_signature(g64, x) != tracecheck.bucket_signature(
        g96, _features(96, 8)
    )


# ---------------------------------------------------------------------------
# hazard injections — each detector fires
# ---------------------------------------------------------------------------
def test_float64_leak_detected():
    g = _graph(64, 300, seed=1)
    x64 = np.random.default_rng(0).standard_normal((64, 8))  # float64 host array
    hazards = tracecheck.check_leaf_dtypes((g, x64), where="inj")
    assert any(h.kind == "float64-leak" for h in hazards)


def test_clean_graph_has_no_leaf_hazards():
    g = _graph(64, 300, seed=1)
    assert tracecheck.check_leaf_dtypes((g, _features(64, 8))) == []
    assert tracecheck.check_static_aux(g) == []


def test_weak_type_detected():
    x = jnp.asarray(1.0) * jnp.ones((4,), jnp.float32)  # weak-typed result
    if not x.weak_type:
        pytest.skip("jax version promotes to strong type here")
    hazards = tracecheck.check_leaf_dtypes((x,), where="inj")
    assert any(h.kind == "weak-type" for h in hazards)


def test_unhashable_and_array_aux_detected():
    @jax.tree_util.register_pytree_node_class
    @dataclasses.dataclass
    class BadAux:
        x: jnp.ndarray
        meta: object  # carried as *static* aux — the anti-pattern

        def tree_flatten(self):
            return (self.x,), (self.meta,)

        @classmethod
        def tree_unflatten(cls, aux, children):
            return cls(children[0], aux[0])

    unhashable = BadAux(jnp.ones(3), meta=[1, 2, 3])  # list: unhashable
    hazards = tracecheck.check_static_aux(unhashable, where="inj")
    assert any(h.kind == "unhashable-aux" for h in hazards)

    identity_keyed = BadAux(jnp.ones(3), meta=np.arange(4))
    hazards = tracecheck.check_static_aux(identity_keyed, where="inj")
    assert any(h.kind == "array-aux" for h in hazards)


def test_eval_shape_flags_bad_outputs():
    def f64_forward(x):
        return x.astype(jnp.float64), x.astype(jnp.int32)

    hazards = tracecheck.eval_shape_hazards(
        f64_forward, jnp.ones((4,), jnp.float32), where="inj"
    )
    kinds = {h.kind for h in hazards}
    # x64 disabled: the f64 cast silently stays f32 (itself fine), but the
    # int output must be flagged either way
    assert "bad-output-dtype" in kinds
    if jax.config.jax_enable_x64:
        assert "float64-leak" in kinds


def test_eval_shape_reports_trace_error():
    def broken(x):
        raise RuntimeError("boom")

    hazards = tracecheck.eval_shape_hazards(broken, jnp.ones(3), where="inj")
    assert [h.kind for h in hazards] == ["trace-error"]
    assert "boom" in hazards[0].detail


def test_retrace_bound_hazard_fires_on_identity_keyed_forward():
    """A forward jitted per *call* (fresh counter misuse aside, the common
    real-world bug is identity-keyed static aux) must trip the bound."""
    models, examples = _registry()
    name = "gcn"
    params, cfg = models[name]
    exs = examples[name]

    # Rebuild the same-bucket graph fresh each call AND salt its static aux
    # with a unique object so jit keys miss: 2 calls -> 2 traces, but one
    # expected bucket.
    calls = 0

    def salted_forward(p, c, g, x):
        return jax.numpy.tanh(x) * (1.0 + 0 * calls)

    # simulate via direct per-bucket accounting: two identical-signature
    # calls that do NOT share a trace
    counter = tracecheck.RetraceCounter(
        lambda p, c, g, x: salted_forward(p, c, g, x),
        static_argnames=("c",),
    )
    g, x = exs[0]
    sig = tracecheck.bucket_signature(g, x)
    counter(params, cfg, g, x)
    counter.jitted.clear_cache()  # force the second trace
    counter(params, cfg, g, x)
    assert counter.traces == 2  # the raw ingredient trace_check aggregates

    rep = tracecheck.TraceReport(
        hazards=(
            tracecheck.TraceHazard(
                "retrace-bound", f"{name}:{sig[:40]}", "2 traces for one bucket"
            ),
        ),
        retraces=(((name, sig), 2),),
    )
    assert not rep.ok and rep.of_kind("retrace-bound")


def test_trace_report_summary_readable():
    models, examples = _registry()
    rep = tracecheck.trace_check(
        {"gcn": models["gcn"]}, {"gcn": examples["gcn"]}
    )
    s = rep.summary()
    assert "trace bucket" in s and "no trace hazards" in s
