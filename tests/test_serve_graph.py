"""Graph serving subsystem: block-diagonal composition, batched forward,
and the GraphServeEngine (plan cache + padding buckets + scatter-back)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import COOMatrix, block_diag_coo, coo_from_dense
from repro.models.gnn import (
    GNNConfig,
    build_batched_graph,
    build_graph,
    gnn_forward,
    gnn_forward_batched,
    init_gnn,
)
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
    _bucket_nodes,
    assemble_batched_graph,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph
from repro.stream import DeltaBatch


def _graphs(sizes, seed=0):
    return [
        gcn_normalize(powerlaw_graph(n, 4 * n, seed=seed + i))
        for i, n in enumerate(sizes)
    ]


def _features(rng, adjs, d):
    return [rng.standard_normal((a.shape[0], d)).astype(np.float32) for a in adjs]


# ---------------------------------------------------------------------------
# block_diag_coo
# ---------------------------------------------------------------------------
def test_block_diag_coo_roundtrip(rng):
    mats = [
        coo_from_dense((rng.random((m, n)) < 0.3) * rng.standard_normal((m, n)).astype(np.float32))
        for m, n in [(5, 7), (3, 3), (6, 2)]
    ]
    comp, row_off, col_off = block_diag_coo(mats)
    assert comp.shape == (14, 12)
    assert list(row_off) == [0, 5, 8, 14]
    assert list(col_off) == [0, 7, 10, 12]
    dense = comp.to_dense()
    for i, a in enumerate(mats):
        np.testing.assert_allclose(
            dense[row_off[i] : row_off[i + 1], col_off[i] : col_off[i + 1]],
            a.to_dense(),
        )
    # off-diagonal blocks are structurally empty
    assert comp.nnz == sum(a.nnz for a in mats)


def test_block_diag_coo_pad_shape():
    a = coo_from_dense(np.eye(3, dtype=np.float32))
    comp, row_off, _ = block_diag_coo([a, a], pad_shape=(10, 10))
    assert comp.shape == (10, 10)
    assert comp.nnz == 6 and list(row_off) == [0, 3, 6]
    with pytest.raises(ValueError):
        block_diag_coo([a, a], pad_shape=(4, 4))


def test_block_diag_coo_empty_list():
    comp, row_off, col_off = block_diag_coo([])
    assert comp.shape == (0, 0) and comp.nnz == 0
    assert len(row_off) == 1 and len(col_off) == 1


# ---------------------------------------------------------------------------
# batched forward == per-graph forward
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["gcn", "sage", "gin", "gat"])
def test_batched_forward_matches_per_graph(kind, rng):
    adjs = _graphs([70, 130, 50])
    xs = _features(rng, adjs, 16)
    cfg = GNNConfig(name=kind, kind=kind, d_in=16, d_hidden=16, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    ref = [
        np.asarray(
            gnn_forward(params, cfg, build_graph(a, tile=64, backend_cap=64), jnp.asarray(x))
        )
        for a, x in zip(adjs, xs)
    ]
    bg = build_batched_graph(adjs, tile=64, backend_cap=64, pad_nodes=512)
    outs = gnn_forward_batched(params, cfg, bg, xs)
    assert len(outs) == len(ref)
    for o, r in zip(outs, ref):
        assert o.shape == r.shape
        np.testing.assert_allclose(o, r, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("kind", ["gcn", "sage", "gin", "gat"])
def test_assembled_plan_matches_block_diag(kind, rng):
    """Engine's index-arithmetic assembly == reference block_diag build —
    including GAT, whose edge re-weighting exercises the per-member perm
    shift (entry_off) in assemble_batched_graph."""
    adjs = _graphs([60, 100, 40], seed=3)
    xs = _features(rng, adjs, 8)
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(1), cfg)
    plans = [build_graph(a, tile=64, backend_cap=64) for a in adjs]
    bg = assemble_batched_graph(plans, tile=64, pad_nodes=256)
    assert bg.graph.n_nodes == 256
    outs = gnn_forward_batched(params, cfg, bg, xs)
    ref = [
        np.asarray(
            gnn_forward(params, cfg, p, jnp.asarray(x))
        )
        for p, x in zip(plans, xs)
    ]
    for o, r in zip(outs, ref):
        np.testing.assert_allclose(o, r, atol=1e-5, rtol=1e-5)


def test_bucket_nodes_ladder():
    assert _bucket_nodes(100, (256, 512), 64) == 256
    assert _bucket_nodes(300, (256, 512), 64) == 512
    # past the ladder: next power of two, not a bespoke per-size pad
    assert _bucket_nodes(600, (256, 512), 64) == 1024
    assert _bucket_nodes(5000, (256, 512), 64) == 8192


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
def _engine(kind="gcn", **cfg_kw):
    cfg = GNNConfig(name=kind, kind=kind, d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    ecfg = GraphEngineConfig(tile=64, cap=64, **cfg_kw)
    return GraphServeEngine({kind: (params, cfg)}, ecfg), params, cfg


def test_engine_outputs_match_per_graph(rng):
    adjs = _graphs([70, 130, 50, 200], seed=5)
    xs = _features(rng, adjs, 8)
    eng, params, cfg = _engine()
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        ref = np.asarray(
            gnn_forward(
                params, cfg, build_graph(r.adj, tile=64, backend_cap=64), jnp.asarray(r.x)
            )
        )
        assert r.out.shape == (r.adj.shape[0], 4)
        np.testing.assert_allclose(r.out, ref, atol=1e-5, rtol=1e-5)


def test_engine_repeat_stream_hits_cache(rng):
    adjs = _graphs([60, 90], seed=7)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    for wave in range(3):
        for i, (a, x) in enumerate(zip(adjs, xs)):
            eng.submit(GraphRequest(rid=wave * 10 + i, adj=a, x=x, model="gcn"))
        eng.run()
    m = eng.metrics()
    # wave 1: 2 member misses + 1 composite miss; waves 2-3: composite hits
    # short-circuit everything
    assert m["plan_cache_misses"] == 3
    assert m["plan_cache_hits"] >= 2
    assert m["plan_cache_hit_rate"] > 0.3
    assert m["batches"] == 3
    # launches counts actual kernel launches: one per non-empty capacity
    # segment of the composite, times the model's layer count per wave
    assert m["launches"] % m["batches"] == 0
    assert m["launches"] // m["batches"] >= 2  # n_layers=2, >=1 segment


def test_engine_batches_bounded(rng):
    adjs = _graphs([50] * 5, seed=9)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine(max_batch_graphs=2)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    assert len(done) == 5
    assert eng.metrics()["batches"] == 3  # ceil(5/2)


def test_engine_node_budget_counts_aligned_footprint(rng):
    # 100 raw nodes -> 128 tile-aligned; raw accounting would pack all three
    # (300 <= 300), aligned accounting packs two (384 > 300)
    adjs = _graphs([100, 100, 100], seed=17)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine(max_batch_nodes=300)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    eng.run()
    assert eng.metrics()["batches"] == 2


def test_engine_launch_count_single_cap(rng):
    # single-cap plans: exactly one kernel launch per aggregation, and the
    # gcn forward aggregates once per layer -> launches = batches * n_layers
    adjs = _graphs([60, 90], seed=33)
    xs = _features(rng, adjs, 8)
    eng, _, cfg = _engine(bucket_caps=())
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    eng.run()
    m = eng.metrics()
    assert m["batches"] == 1
    assert m["launches"] == cfg.n_layers


def test_plan_launches_counts_nonempty_segments():
    from repro.serve.graph_engine import plan_launches

    adj = _graphs([120], seed=35)[0]
    g_single = build_graph(adj, tile=64, backend_cap=64)
    assert plan_launches(g_single.plan) == 1
    g_bucketed = build_graph(adj, tile=64, bucket_caps=(8, 32, 128))
    segs = g_bucketed.plan.segments
    expect = sum(1 for s in segs if int(np.asarray(s.tile_row).size) > 0)
    assert plan_launches(g_bucketed.plan) == expect
    assert 1 <= expect <= 3


def test_engine_config_rejects_nonpositive_limits():
    with pytest.raises(ValueError):
        GraphEngineConfig(max_batch_graphs=0)
    with pytest.raises(ValueError):
        GraphEngineConfig(max_batch_nodes=0)
    with pytest.raises(ValueError):
        GraphEngineConfig(tile=0)
    with pytest.raises(ValueError):
        GraphEngineConfig(cap=-1)
    # a budget past the bucket ladder would unbound jit recompiles
    with pytest.raises(ValueError, match="node bucket"):
        GraphEngineConfig(max_batch_nodes=8192)
    GraphEngineConfig(max_batch_nodes=8192, node_buckets=())  # explicit opt-out


def test_engine_rejects_wrong_feature_width(rng):
    eng, _, _ = _engine()
    adj = _graphs([30], seed=19)[0]
    with pytest.raises(ValueError, match="d_in"):
        eng.submit(
            GraphRequest(
                rid=0, adj=adj, x=np.zeros((30, 5), np.float32), model="gcn"
            )
        )


def test_engine_rejects_out_of_range_indices(rng):
    # an index past the declared node count would land in a NEIGHBOR's
    # block of the composite and corrupt a co-batched request
    eng, _, _ = _engine()
    bad = COOMatrix(
        np.array([0, 1], np.int32),
        np.array([0, 70], np.int32),  # 70 >= 60
        np.ones(2, np.float32),
        (60, 60),
    )
    with pytest.raises(ValueError, match="out of range"):
        eng.submit(
            GraphRequest(rid=0, adj=bad, x=np.zeros((60, 8), np.float32), model="gcn")
        )


def test_engine_rejects_negative_indices(rng):
    # negative indices wrap in numpy fancy indexing: a -1 row would write
    # into the LAST node's aggregation, silently
    eng, _, _ = _engine()
    bad = COOMatrix(
        np.array([-1, 1], np.int32),
        np.array([0, 2], np.int32),
        np.ones(2, np.float32),
        (60, 60),
    )
    with pytest.raises(ValueError, match="non-negative"):
        eng.submit(
            GraphRequest(rid=0, adj=bad, x=np.zeros((60, 8), np.float32), model="gcn")
        )


def test_engine_rejects_nonfinite_values(rng):
    eng, _, _ = _engine()
    bad = COOMatrix(
        np.array([0, 1], np.int32),
        np.array([0, 2], np.int32),
        np.array([1.0, np.nan], np.float32),
        (60, 60),
    )
    with pytest.raises(ValueError, match="finite"):
        eng.submit(
            GraphRequest(rid=0, adj=bad, x=np.zeros((60, 8), np.float32), model="gcn")
        )


def test_engine_debug_validate_serves_clean_traffic(rng):
    # debug mode runs the full core.validate invariant chain on every
    # freshly built composite; clean traffic must be unaffected
    eng, params, cfg = _engine(debug_validate=True)
    adjs = _graphs([30, 45], seed=33)
    xs = _features(rng, adjs, 8)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    assert len(done) == 2 and all(r.done for r in done)
    ref_eng, _, _ = _engine()
    for i, (a, x) in enumerate(zip(adjs, xs)):
        ref_eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    for r, ref in zip(done, ref_eng.run()):
        np.testing.assert_array_equal(r.out, ref.out)


def test_engine_debug_validate_catches_corrupt_plan(rng, monkeypatch):
    # corrupt the member-plan builder: debug mode must fail the wave with
    # a named invariant instead of serving wrong aggregations
    import dataclasses as _dc

    import repro.serve.graph_engine as ge
    from repro.core.validate import PlanInvariantError

    real_build = ge.build_graph

    def corrupt_build(*a, **k):
        g = real_build(*a, **k)
        seg0 = g.plan.segments[0]
        nnz = np.array(seg0.nnz_in_tile)
        nnz[0] = seg0.cap + 7  # cap invariant broken
        segs = (_dc.replace(seg0, nnz_in_tile=nnz),) + g.plan.segments[1:]
        return _dc.replace(g, plan=_dc.replace(g.plan, segments=segs))

    monkeypatch.setattr(ge, "build_graph", corrupt_build)
    eng, _, _ = _engine(debug_validate=True, max_retries=0)
    adj = _graphs([30], seed=34)[0]
    eng.submit(GraphRequest(rid=0, adj=adj, x=np.zeros((30, 8), np.float32),
                            model="gcn"))
    with pytest.raises(PlanInvariantError, match="cap"):
        eng.run()


def test_split_outputs_returns_copies(rng):
    # views would pin the bucket-sized composite for the life of each output
    adjs = _graphs([40, 40], seed=21)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine()
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    for r in done:
        assert r.out.base is None


def test_engine_node_budget_splits_batches(rng):
    adjs = _graphs([200, 200, 200], seed=11)
    xs = _features(rng, adjs, 8)
    eng, _, _ = _engine(max_batch_nodes=256)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    eng.run()
    assert eng.metrics()["batches"] == 3  # each graph alone busts the budget


def test_engine_rejects_bad_requests(rng):
    eng, _, _ = _engine()
    adj = _graphs([30], seed=13)[0]
    x = rng.standard_normal((30, 8)).astype(np.float32)
    with pytest.raises(KeyError):
        eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="nope"))
    with pytest.raises(ValueError):
        eng.submit(GraphRequest(rid=0, adj=adj, x=x[:10], model="gcn"))
    rect = COOMatrix(
        np.zeros(1, np.int32), np.zeros(1, np.int32), np.ones(1, np.float32), (3, 4)
    )
    with pytest.raises(ValueError):
        eng.submit(GraphRequest(rid=0, adj=rect, x=x[:3], model="gcn"))


def test_engine_failed_wave_requeues_requests(rng):
    # params built for gcn registered under a gat config: submit passes,
    # the forward raises — the wave must land back on the queue, not vanish
    cfg_bad = GNNConfig(name="gat", kind="gat", d_in=8, d_hidden=8, n_classes=4)
    cfg_gcn = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg_gcn)
    eng = GraphServeEngine({"gat": (params, cfg_bad)}, GraphEngineConfig(tile=64, cap=64))
    adjs = _graphs([40, 40], seed=23)
    xs = _features(rng, adjs, 8)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gat"))
    with pytest.raises(Exception):
        eng.run()
    assert sorted(r.rid for r in eng.queue) == [0, 1]
    assert not any(r.done for r in eng.queue)


def test_engine_poison_request_does_not_wedge(rng):
    # a request that fails every wave must eventually be ejected so a
    # retrying caller drains the queue instead of looping forever
    cfg_bad = GNNConfig(name="gat", kind="gat", d_in=8, d_hidden=8, n_classes=4)
    cfg_gcn = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg_gcn)
    eng = GraphServeEngine(
        {"gat": (params, cfg_bad), "gcn": (params, cfg_gcn)},
        GraphEngineConfig(tile=64, cap=64),
    )
    adjs = _graphs([40, 40], seed=27)
    xs = _features(rng, adjs, 8)
    eng.submit(GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gat"))  # poison
    eng.submit(GraphRequest(rid=1, adj=adjs[1], x=xs[1], model="gcn"))  # healthy
    for _ in range(10):
        if not eng.queue:
            break
        try:
            eng.run()
        except Exception:
            pass
    assert not eng.queue  # drained, no wedge
    assert [r.rid for r in eng.completed] == [1]
    assert [r.rid for r in eng.failed] == [0]
    assert eng.failed[0].error is not None and not eng.failed[0].done
    assert eng.metrics()["failed"] == 1


def test_engine_equivalence_pallas_interpret_backend(rng):
    """The assembled composite must also be correct under the Pallas kernel
    semantics (PS strip zeroing on block-row change, repeated-coordinate
    padding tiles) — the jnp reference masks padding differently and would
    not catch a strip-ordering regression."""
    adjs = _graphs([60, 100], seed=25)
    xs = _features(rng, adjs, 8)
    cfg = GNNConfig(
        name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4,
        backend="pallas_interpret",
    )
    params, _ = init_gnn(jax.random.PRNGKey(2), cfg)
    eng = GraphServeEngine({"gcn": (params, cfg)}, GraphEngineConfig(tile=64, cap=64))
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))
    done = eng.run()
    for r in done:
        ref = np.asarray(
            gnn_forward(
                params, cfg, build_graph(r.adj, tile=64, backend_cap=64),
                jnp.asarray(r.x),
            )
        )
        np.testing.assert_allclose(r.out, ref, atol=1e-5, rtol=1e-5)


def test_engine_interrupt_consumes_no_retries(rng, monkeypatch):
    # Ctrl-C mid-wave is not a request failure: the wave is restored
    # untouched and no healthy request drifts toward ejection
    import repro.serve.graph_engine as ge

    eng, _, _ = _engine()
    adjs = _graphs([40, 40], seed=29)
    xs = _features(rng, adjs, 8)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn"))

    def boom(*a, **kw):
        raise KeyboardInterrupt

    monkeypatch.setattr(ge, "gnn_forward_jit", boom)
    with pytest.raises(KeyboardInterrupt):
        eng.run()
    assert sorted(r.rid for r in eng.queue) == [0, 1]
    assert all(r.retries == 0 and not r.isolate for r in eng.queue)
    monkeypatch.undo()
    assert sorted(r.rid for r in eng.run()) == [0, 1]


def test_engine_partial_completions_survive_failed_run(rng):
    # waves completed before a failing wave must be retrievable even though
    # run() raised before returning
    cfg_bad = GNNConfig(name="gat", kind="gat", d_in=8, d_hidden=8, n_classes=4)
    cfg_gcn = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg_gcn)
    eng = GraphServeEngine(
        {"gat": (params, cfg_bad), "gcn": (params, cfg_gcn)},
        GraphEngineConfig(tile=64, cap=64),
    )
    adjs = _graphs([40, 40], seed=31)
    xs = _features(rng, adjs, 8)
    eng.submit(GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gcn"))  # healthy
    eng.submit(GraphRequest(rid=1, adj=adjs[1], x=xs[1], model="gat"))  # poison
    with pytest.raises(Exception):
        eng.run()
    assert [r.rid for r in eng.last_completed] == [0]
    assert eng.last_completed[0].out is not None


# ---------------------------------------------------------------------------
# delta-tracked graphs: update(), revalidation, staleness
# ---------------------------------------------------------------------------
def _value_update(adj, idx, val):
    coords = [(int(adj.rows[i]), int(adj.cols[i])) for i in idx]
    return DeltaBatch.of(inserts=[(r, c, val) for r, c in coords],
                         removes=coords)


def test_engine_update_unknown_graph_raises(rng):
    eng, _, _ = _engine()
    with pytest.raises(KeyError, match="unknown graph_id"):
        eng.update("nope", DeltaBatch.of(inserts=[(0, 1, 1.0)]))
    with pytest.raises(KeyError, match="unknown graph_id"):
        eng.tracked_adj("nope")


def test_engine_tracked_adj_follows_updates(rng):
    adj = _graphs([40], seed=11)[0]
    eng, _, _ = _engine()
    x = rng.standard_normal((adj.shape[0], 8)).astype(np.float32)
    eng.submit(GraphRequest(rid=0, graph_id="g", adj=adj, x=x, model="gcn"))
    assert eng.tracked_adj("g") is adj
    d = _value_update(adj, [0], 9.0)
    eng.update("g", d)
    cur = eng.tracked_adj("g")
    assert cur is not adj and float(cur.vals[0]) == 9.0


def test_engine_tracked_request_requires_registration(rng):
    eng, _, _ = _engine()
    x = np.zeros((30, 8), np.float32)
    with pytest.raises(KeyError, match="unknown graph_id"):
        eng.submit(GraphRequest(rid=0, x=x, model="gcn", graph_id="g0"))
    with pytest.raises(ValueError, match="needs adj"):
        eng.submit(GraphRequest(rid=0, x=x, model="gcn"))


def test_engine_update_admission_mirrors_check_delta(rng):
    # check_delta runs against the *tracked* adjacency before any state
    # changes: out-of-range ids, non-finite vals, absent removes,
    # already-present inserts all bounce
    eng, _, _ = _engine()
    adj = _graphs([30], seed=41)[0]
    x = np.zeros((30, 8), np.float32)
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()
    with pytest.raises(ValueError, match="out of range"):
        eng.update("g0", DeltaBatch.of(inserts=[(99, 0, 1.0)]))
    with pytest.raises(ValueError, match="finite"):
        eng.update("g0", DeltaBatch.of(inserts=[(0, 0, np.nan)]))
    have = set(zip(adj.rows.tolist(), adj.cols.tolist()))
    absent = next((r, c) for r in range(30) for c in range(30)
                  if (r, c) not in have)
    with pytest.raises(ValueError, match="absent edge"):
        eng.update("g0", DeltaBatch.of(removes=[absent]))
    r0, c0 = int(adj.rows[0]), int(adj.cols[0])
    with pytest.raises(ValueError, match="already-present"):
        eng.update("g0", DeltaBatch.of(inserts=[(r0, c0, 1.0)]))
    with pytest.raises(ValueError, match="duplicate insert"):
        eng.update("g0", DeltaBatch.of(
            inserts=[(absent[0], absent[1], 1.0), (absent[0], absent[1], 2.0)]
        ))
    # nothing landed: the tracked state is untouched
    assert eng.metrics()["graph_updates"] == 0


def test_engine_submit_update_submit_serves_post_delta(rng):
    # the staleness fix: after update(), a tracked request must be served
    # from the post-delta adjacency — never a stale cached plan
    adj = _graphs([50], seed=43)[0]
    x = rng.standard_normal((50, 8)).astype(np.float32)
    eng, params, cfg = _engine()
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    out_pre = eng.run()[0].out

    d = _value_update(adj, [0, 1, 2], 3.5)
    eng.update("g0", d)
    eng.submit(GraphRequest(rid=1, x=x, model="gcn", graph_id="g0"))
    out_post = eng.run()[0].out

    from repro.stream import apply_coo

    final = apply_coo(adj, d)
    bucket_caps = tuple(eng.cfg.bucket_caps) or None
    ref = np.asarray(gnn_forward(
        params, cfg,
        build_graph(final, tile=64,
                    backend_cap=None if bucket_caps else eng.cfg.cap,
                    bucket_caps=bucket_caps),
        jnp.asarray(x),
    ))
    np.testing.assert_allclose(out_post, ref, atol=1e-5, rtol=1e-5)
    assert np.abs(out_post - out_pre).max() > 0  # the delta is visible
    m = eng.metrics()
    assert m["plan_cache_revalidated"] == 1  # patched, not a full miss
    assert m["graph_updates"] == 1


def test_engine_update_between_submit_and_run(rng):
    # adjacency resolves at wave time: an update landing after submit but
    # before run() is reflected in the served output
    adj = _graphs([50], seed=47)[0]
    x = rng.standard_normal((50, 8)).astype(np.float32)
    eng, params, cfg = _engine()
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()
    d = _value_update(adj, [0, 1], 9.0)
    eng.submit(GraphRequest(rid=1, x=x, model="gcn", graph_id="g0"))
    eng.update("g0", d)  # lands while rid=1 is queued
    out = eng.run()[0].out

    from repro.stream import apply_coo

    bucket_caps = tuple(eng.cfg.bucket_caps) or None
    ref = np.asarray(gnn_forward(
        params, cfg,
        build_graph(apply_coo(adj, d), tile=64,
                    backend_cap=None if bucket_caps else eng.cfg.cap,
                    bucket_caps=bucket_caps),
        jnp.asarray(x),
    ))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_engine_periodic_reanchor_rejoins_content_keys(rng):
    # after anchor_every updates the tracked key must re-home to the
    # coo_content_key of the current adjacency, so an untracked client
    # submitting the identical post-delta graph hits the same entry
    adj = _graphs([50], seed=47)[0]
    x = rng.standard_normal((50, 8)).astype(np.float32)
    eng, params, cfg = _engine(anchor_every=3)
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()

    cur = adj
    for i in range(3):
        d = _value_update(cur, [i, i + 1], 2.0 + i)
        key = eng.update("g0", d)
        from repro.stream import apply_coo

        cur = apply_coo(cur, d)
    # third update crossed the anchor threshold: key == content key now
    assert key == eng._member_content_key(cur)
    m = eng.metrics()
    assert m["plan_cache_anchored"] == 1
    # anchored updates still count as revalidations (the Phase B gate)
    assert m["plan_cache_revalidated"] == 3 == m["graph_updates"]
    # the anchored entry is live under the content key: an untracked
    # submit of the same adjacency resolves without a member rebuild
    misses_before = m["plan_cache_misses"]
    eng.submit(GraphRequest(rid=1, adj=cur, x=x, model="gcn"))
    out = eng.run()[0].out
    # one composite miss is expected (new batch), but no member miss
    assert eng.metrics()["plan_cache_misses"] == misses_before + 1
    bucket_caps = tuple(eng.cfg.bucket_caps) or None
    ref = np.asarray(gnn_forward(
        params, cfg,
        build_graph(cur, tile=64,
                    backend_cap=None if bucket_caps else eng.cfg.cap,
                    bucket_caps=bucket_caps),
        jnp.asarray(x),
    ))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_engine_anchor_disabled_keeps_lineage_keys(rng):
    adj = _graphs([50], seed=48)[0]
    x = rng.standard_normal((50, 8)).astype(np.float32)
    eng, _, _ = _engine(anchor_every=0)
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()
    for i in range(4):
        key = eng.update("g0", _value_update(eng.tracked_adj("g0"), [i], 1.5))
    assert eng.metrics()["plan_cache_anchored"] == 0
    assert key != eng._member_content_key(eng.tracked_adj("g0"))


def test_engine_update_invalidates_composite_batches(rng):
    # composite keys combine member keys, so a delta on one tracked member
    # re-keys every batch it rides in — co-batched outputs stay fresh
    adjs = _graphs([40, 40], seed=53)
    xs = _features(rng, adjs, 8)
    eng, params, cfg = _engine()
    eng.submit(GraphRequest(rid=0, adj=adjs[0], x=xs[0], model="gcn",
                            graph_id="g0"))
    eng.submit(GraphRequest(rid=1, adj=adjs[1], x=xs[1], model="gcn"))
    eng.run()

    d = _value_update(adjs[0], [0, 1, 2, 3], 5.0)
    eng.update("g0", d)
    eng.submit(GraphRequest(rid=2, x=xs[0], model="gcn", graph_id="g0"))
    eng.submit(GraphRequest(rid=3, adj=adjs[1], x=xs[1], model="gcn"))
    done = {r.rid: r.out for r in eng.run()}

    from repro.stream import apply_coo

    bucket_caps = tuple(eng.cfg.bucket_caps) or None
    ref = np.asarray(gnn_forward(
        params, cfg,
        build_graph(apply_coo(adjs[0], d), tile=64,
                    backend_cap=None if bucket_caps else eng.cfg.cap,
                    bucket_caps=bucket_caps),
        jnp.asarray(xs[0]),
    ))
    np.testing.assert_allclose(done[2], ref, atol=1e-5, rtol=1e-5)


def test_engine_reregister_resets_tracked_state(rng):
    # a request carrying both adj and graph_id resets the tracked graph
    adj = _graphs([40], seed=59)[0]
    x = rng.standard_normal((40, 8)).astype(np.float32)
    eng, _, _ = _engine()
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()
    eng.update("g0", _value_update(adj, [0], 2.0))
    key_after_update = eng._graphs["g0"].key
    eng.submit(GraphRequest(rid=1, adj=adj, x=x, model="gcn", graph_id="g0"))
    assert eng._graphs["g0"].key != key_after_update  # back to content key
    out = eng.run()[0].out
    assert np.isfinite(out).all()


def test_engine_empty_delta_is_a_noop(rng):
    adj = _graphs([40], seed=61)[0]
    x = np.zeros((40, 8), np.float32)
    eng, _, _ = _engine()
    eng.submit(GraphRequest(rid=0, adj=adj, x=x, model="gcn", graph_id="g0"))
    eng.run()
    key = eng._graphs["g0"].key
    assert eng.update("g0", DeltaBatch.of()) == key
    assert eng.metrics()["graph_updates"] == 0


def test_engine_mixed_model_kinds_batch_separately(rng):
    cfg_a = GNNConfig(name="gcn", kind="gcn", d_in=8, d_hidden=8, n_classes=4)
    cfg_b = GNNConfig(name="gin", kind="gin", d_in=8, d_hidden=8, n_classes=4)
    pa, _ = init_gnn(jax.random.PRNGKey(0), cfg_a)
    pb, _ = init_gnn(jax.random.PRNGKey(1), cfg_b)
    eng = GraphServeEngine(
        {"gcn": (pa, cfg_a), "gin": (pb, cfg_b)},
        GraphEngineConfig(tile=64, cap=64),
    )
    adjs = _graphs([40, 40, 40, 40], seed=15)
    xs = _features(rng, adjs, 8)
    for i, (a, x) in enumerate(zip(adjs, xs)):
        eng.submit(GraphRequest(rid=i, adj=a, x=x, model="gcn" if i % 2 else "gin"))
    done = eng.run()
    assert len(done) == 4
    assert eng.metrics()["batches"] == 2  # one per kind
    for r in done:
        assert r.out.shape == (40, 4) and np.isfinite(r.out).all()
