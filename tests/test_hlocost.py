"""The trip-count-corrected HLO cost analyzer (launch/hlocost.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    for n in [1, 4, 9]:
        c = _compile(
            f,
            jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((n, 128, 128), jnp.float32),
        )
        t = analyze(c.as_text())
        assert t.flops == pytest.approx(2 * 128**3 * n, rel=0.01), n


def test_nested_scan():
    def g(x, ws):
        def outer(x, w2):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, w2)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    c = _compile(
        g,
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32),
    )
    t = analyze(c.as_text())
    assert t.flops == pytest.approx(2 * 64**3 * 15, rel=0.01)


def test_bytes_scale_with_trips():
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    outs = []
    for n in [2, 8]:
        c = _compile(
            f,
            jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((n, 128, 128), jnp.float32),
        )
        outs.append(analyze(c.as_text()).hbm_bytes)
    assert outs[1] > 2.5 * outs[0]  # roughly linear in trip count


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    c = _compile(
        f,
        jax.ShapeDtypeStruct((4, 32, 48), jnp.float32),
        jax.ShapeDtypeStruct((4, 48, 16), jnp.float32),
    )
    t = analyze(c.as_text())
    assert t.flops == pytest.approx(2 * 4 * 32 * 48 * 16, rel=0.01)
