"""Property round-trip: random COO -> plan -> bucketed -> sharded ->
reassembled passes the full ValidationReport and byte-matches the source
COO (ISSUE 6 satellite).

Runs in two modes: a hypothesis-driven property test when the package is
installed (``importorskip``-guarded — the container does not ship it),
and a seeded plain-random sweep that always runs so the property is
exercised either way.
"""
import numpy as np
import pytest

from repro.core import coo_to_scv_tiles, plan_from_tiles, plan_from_tiles_bucketed
from repro.core.exec import PlanExecutor, ShardingDecision
from repro.core.formats import COOMatrix
from repro.core.validate import validate_plan


def _random_coo(rng, n, density):
    """Square COO with unique coordinates and non-zero finite values."""
    k = max(0, min(int(density * n * n), n * n))
    flat = rng.choice(n * n, size=k, replace=False) if k else np.zeros(0, np.int64)
    vals = rng.standard_normal(k).astype(np.float32)
    vals[vals == 0] = 1.0  # structural zeros would vanish from the plan
    return COOMatrix(
        rows=(flat // n).astype(np.int32),
        cols=(flat % n).astype(np.int32),
        vals=vals,
        shape=(n, n),
    )


def _roundtrip(coo, tile, cap, caps):
    """plan -> bucketed -> sharded; each stage green + byte-match to coo."""
    tiles = coo_to_scv_tiles(coo, tile, cap=cap)
    plan = plan_from_tiles(tiles)
    rep = validate_plan(plan, coo=coo)
    assert rep.ok, f"plan stage:\n{rep.summary()}"

    bplan = plan_from_tiles_bucketed(tiles, caps=caps)
    rep = validate_plan(bplan, coo=coo)
    assert rep.ok, f"bucketed stage:\n{rep.summary()}"

    sp = PlanExecutor().prepare(bplan, decision=ShardingDecision("tiles", 1, 1))
    rep = validate_plan(sp, coo=coo)
    assert rep.ok, f"sharded stage:\n{rep.summary()}"


CASES = [
    # (n, density, tile, cap, caps)
    (1, 0.0, 16, 8, (4, 8)),       # empty 1x1
    (16, 1.0, 16, 256, (64, 256)),  # fully dense single tile
    (33, 0.05, 16, 32, (8, 32)),    # n not divisible by tile
    (64, 0.01, 16, 32, (4, 8, 32)),
    (100, 0.08, 32, 128, (16, 64, 128)),
    (70, 0.3, 16, 64, (8, 64)),
]


@pytest.mark.parametrize("n,density,tile,cap,caps", CASES)
def test_roundtrip_fixed_cases(n, density, tile, cap, caps):
    coo = _random_coo(np.random.default_rng(n), n, density)
    _roundtrip(coo, tile, cap, caps)


def test_roundtrip_random_sweep():
    """Plain-random stand-in for the hypothesis property (always runs)."""
    rng = np.random.default_rng(42)
    for _ in range(8):
        n = int(rng.integers(1, 100))
        density = float(rng.uniform(0, 0.3))
        tile = int(rng.choice([8, 16, 32]))
        cap = int(rng.choice([16, 64, 256]))
        lo = max(2, cap // 8)
        caps = (lo, cap)
        coo = _random_coo(rng, n, density)
        _roundtrip(coo, tile, cap, caps)


# ---------------------------------------------------------------------------
# delta round-trip (ISSUE 7 satellite): a random interleaved insert/remove
# sequence applied via stream.apply_delta is byte-identical to rebuilding
# from the final COO, and validate_plan stays green at plan / bucketed /
# sharded layers after EVERY step.
# ---------------------------------------------------------------------------
def _random_step(rng, coo, n):
    """One random delta against the current COO: a mix of inserts at
    absent coordinates, removes of stored edges, and value updates."""
    from repro.stream import DeltaBatch

    have = set(zip(coo.rows.tolist(), coo.cols.tolist()))
    inserts, removes = [], []
    for i in rng.choice(max(coo.nnz, 1), size=min(int(rng.integers(0, 5)), coo.nnz),
                        replace=False):
        r, c = int(coo.rows[i]), int(coo.cols[i])
        removes.append((r, c))
        if rng.random() < 0.5:  # value update: remove + re-insert
            inserts.append((r, c, float(rng.standard_normal() + 2)))
    tries = 0
    want = int(rng.integers(0, 5))
    while len(inserts) - sum(1 for e in inserts if (e[0], e[1]) in have) < want \
            and tries < 1000:
        r, c = int(rng.integers(n)), int(rng.integers(n))
        if (r, c) not in have and all((r, c) != e[:2] for e in inserts):
            inserts.append((r, c, float(rng.standard_normal() + 2)))
        tries += 1
    return DeltaBatch.of(inserts=inserts, removes=removes)


def test_delta_sequence_roundtrip():
    from repro.stream import apply_coo, apply_delta

    rng = np.random.default_rng(7)
    n, tile, cap = 129, 16, 32
    caps = (8, 32)
    coo = _random_coo(rng, n, 0.02)
    tiles = coo_to_scv_tiles(coo, tile, cap=cap)
    plan = plan_from_tiles(tiles)
    bplan = plan_from_tiles_bucketed(tiles, caps=caps)

    for step in range(6):
        d = _random_step(rng, coo, n)
        if len(d) == 0:
            continue
        coo = apply_coo(coo, d)
        plan = apply_delta(plan, d)
        bplan = apply_delta(bplan, d)

        # byte-identity to the from-scratch rebuild of the current COO
        ref_tiles = coo_to_scv_tiles(coo, tile, cap=cap)
        ref_plan = plan_from_tiles(ref_tiles)
        for f in ("tile_row", "tile_col", "rows", "cols", "vals",
                  "nnz_in_tile", "perm"):
            assert np.array_equal(
                np.asarray(getattr(plan, f)), np.asarray(getattr(ref_plan, f))
            ), (step, f)
        ref_bplan = plan_from_tiles_bucketed(ref_tiles, caps=caps)
        for s, rs in zip(bplan.segments, ref_bplan.segments):
            for f in ("tile_row", "tile_col", "rows", "cols", "vals",
                      "nnz_in_tile", "perm"):
                assert np.array_equal(
                    np.asarray(getattr(s, f)), np.asarray(getattr(rs, f))
                ), (step, s.cap, f)

        # the full invariant chain stays green at every layer, every step
        validate_plan(plan, coo=coo).raise_if_failed()
        validate_plan(bplan, coo=coo).raise_if_failed()
        sp = PlanExecutor().prepare(
            bplan, decision=ShardingDecision("tiles", 1, 1)
        )
        validate_plan(sp, coo=coo).raise_if_failed()


def test_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(
        n=st.integers(min_value=1, max_value=150),
        density=st.floats(min_value=0.0, max_value=0.4),
        tile=st.sampled_from([8, 16, 32]),
        cap=st.sampled_from([16, 64, 256]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def prop(n, density, tile, cap, seed):
        coo = _random_coo(np.random.default_rng(seed), n, density)
        _roundtrip(coo, tile, cap, (max(2, cap // 8), cap))

    prop()
