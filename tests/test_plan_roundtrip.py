"""Property round-trip: random COO -> plan -> bucketed -> sharded ->
reassembled passes the full ValidationReport and byte-matches the source
COO (ISSUE 6 satellite).

Runs in two modes: a hypothesis-driven property test when the package is
installed (``importorskip``-guarded — the container does not ship it),
and a seeded plain-random sweep that always runs so the property is
exercised either way.
"""
import numpy as np
import pytest

from repro.core import coo_to_scv_tiles, plan_from_tiles, plan_from_tiles_bucketed
from repro.core.exec import PlanExecutor, ShardingDecision
from repro.core.formats import COOMatrix
from repro.core.validate import validate_plan


def _random_coo(rng, n, density):
    """Square COO with unique coordinates and non-zero finite values."""
    k = max(0, min(int(density * n * n), n * n))
    flat = rng.choice(n * n, size=k, replace=False) if k else np.zeros(0, np.int64)
    vals = rng.standard_normal(k).astype(np.float32)
    vals[vals == 0] = 1.0  # structural zeros would vanish from the plan
    return COOMatrix(
        rows=(flat // n).astype(np.int32),
        cols=(flat % n).astype(np.int32),
        vals=vals,
        shape=(n, n),
    )


def _roundtrip(coo, tile, cap, caps):
    """plan -> bucketed -> sharded; each stage green + byte-match to coo."""
    tiles = coo_to_scv_tiles(coo, tile, cap=cap)
    plan = plan_from_tiles(tiles)
    rep = validate_plan(plan, coo=coo)
    assert rep.ok, f"plan stage:\n{rep.summary()}"

    bplan = plan_from_tiles_bucketed(tiles, caps=caps)
    rep = validate_plan(bplan, coo=coo)
    assert rep.ok, f"bucketed stage:\n{rep.summary()}"

    sp = PlanExecutor().prepare(bplan, decision=ShardingDecision("tiles", 1, 1))
    rep = validate_plan(sp, coo=coo)
    assert rep.ok, f"sharded stage:\n{rep.summary()}"


CASES = [
    # (n, density, tile, cap, caps)
    (1, 0.0, 16, 8, (4, 8)),       # empty 1x1
    (16, 1.0, 16, 256, (64, 256)),  # fully dense single tile
    (33, 0.05, 16, 32, (8, 32)),    # n not divisible by tile
    (64, 0.01, 16, 32, (4, 8, 32)),
    (100, 0.08, 32, 128, (16, 64, 128)),
    (70, 0.3, 16, 64, (8, 64)),
]


@pytest.mark.parametrize("n,density,tile,cap,caps", CASES)
def test_roundtrip_fixed_cases(n, density, tile, cap, caps):
    coo = _random_coo(np.random.default_rng(n), n, density)
    _roundtrip(coo, tile, cap, caps)


def test_roundtrip_random_sweep():
    """Plain-random stand-in for the hypothesis property (always runs)."""
    rng = np.random.default_rng(42)
    for _ in range(8):
        n = int(rng.integers(1, 100))
        density = float(rng.uniform(0, 0.3))
        tile = int(rng.choice([8, 16, 32]))
        cap = int(rng.choice([16, 64, 256]))
        lo = max(2, cap // 8)
        caps = (lo, cap)
        coo = _random_coo(rng, n, density)
        _roundtrip(coo, tile, cap, caps)


def test_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(
        n=st.integers(min_value=1, max_value=150),
        density=st.floats(min_value=0.0, max_value=0.4),
        tile=st.sampled_from([8, 16, 32]),
        cap=st.sampled_from([16, 64, 256]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def prop(n, density, tile, cap, seed):
        coo = _random_coo(np.random.default_rng(seed), n, density)
        _roundtrip(coo, tile, cap, (max(2, cap // 8), cap))

    prop()
