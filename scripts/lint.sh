#!/usr/bin/env bash
# Lint gate: generic style (ruff, when the image has it) + the
# repo-specific scvlint rules (tools/scvlint — np-in-traced-body, magic
# kernel constants, nondiff_argnums over plan leaves, jax-shim pin
# hygiene, fori_loop unroll).  New violations fail the run; pre-existing
# ones live in tools/scvlint/baseline.txt.
#
# Run directly (`scripts/lint.sh`) or via scripts/ci.sh, which gates on
# it before the pytest tier.
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
  ruff check src tools benchmarks
else
  # The container does not bake ruff in (and installing deps is out of
  # scope for CI); the repo-specific rules below still run.
  echo "lint.sh: ruff not installed — skipping generic style pass"
fi

python -m tools.scvlint src/ tools/ benchmarks/
