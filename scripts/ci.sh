#!/usr/bin/env bash
# Fast CI tier: the whole suite minus the multi-minute `slow`-marked
# modules — a seconds-scale default loop.  Pass extra pytest args through,
# e.g. `scripts/ci.sh -k serve`.  The full tier-1 command (ROADMAP.md)
# remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
