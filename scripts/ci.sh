#!/usr/bin/env bash
# Fast CI tier: the whole suite minus the multi-minute `slow`-marked
# modules — a seconds-scale default loop.  Pass extra pytest args through,
# e.g. `scripts/ci.sh -k serve`.  The full tier-1 command (ROADMAP.md)
# remains `PYTHONPATH=src python -m pytest -x -q`.
#
# Two PR gates always hold:
#   * the jitted-forward equivalence checks (whole GNN forward under one
#     jax.jit must match the unjitted path for all model kinds) — part of
#     the default suite; re-run explicitly only when "$@" filters might
#     have deselected them, and
#   * benchmarks/preprocess_bench.py (vectorized SCV tile construction
#     >= 5x the scalar loop on a 1M-edge graph; emits BENCH_preprocess.json),
#   * benchmarks/kernel_bench.py (vectorized/bucketed Pallas kernel body
#     >= 3x the scalar-loop kernel at 1M edges on a power-law graph,
#     interpret mode, bit-exact vs the jnp reference; emits
#     BENCH_kernel.json),
#   * benchmarks/dist_bench.py (executor-placed bucketed plan on a forced
#     8-host-device mesh: tile/feature/2-D sharding bit-exact vs the
#     single-device bucketed path, balanced spans, bounded overhead;
#     emits BENCH_dist.json),
#   * benchmarks/serve_bench.py (engine >= naive loop, cache hits, the
#     bucketed-vs-single-cap A/B plus the ladder-depth sweep that gates
#     the DEFAULT_LADDER default against the measured winner, and the
#     Poisson open-loop sync-vs-async A/B: async p99 <= sync p99 at equal
#     offered load, async holds >= 0.9x sync graphs/s at saturation,
#     exact-output parity vs the unbatched forward; emits
#     BENCH_serve.json with the open-loop percentiles),
#   * benchmarks/stream_bench.py (small-delta stream.apply_delta >= 10x a
#     full coo_to_scv_tiles rebuild at 1M edges, byte-identical to the
#     rebuild; engine updates land as plan-cache revalidations, never
#     full misses; emits BENCH_stream.json),
#   * benchmarks/autotune_bench.py (simulator-pruned config search never
#     loses to the measured default control on either regime, strictly
#     beats it on at least one, and re-resolves both from the on-disk
#     cache with zero new searches; emits BENCH_autotune.json).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
scripts/lint.sh
python -m pytest -q -m "not slow" "$@"
if [ "$#" -gt 0 ]; then
  python -m pytest -q tests/test_scv_plan.py -k "jit" --no-header
fi
python benchmarks/preprocess_bench.py
python benchmarks/kernel_bench.py
python benchmarks/dist_bench.py
python benchmarks/serve_bench.py
python benchmarks/stream_bench.py
python benchmarks/autotune_bench.py
