"""GNN inference across aggregation backends — the paper's workload.

Runs a 2-layer GCN (and GAT) over a synthetic power-law graph with the
CSR baseline and the SCV kernel backends, timing CPU wall-clock and
verifying numerical equivalence.

    PYTHONPATH=src python examples/gnn_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import (
    GNNConfig,
    build_graph,
    gnn_forward,
    gnn_forward_jit,
    init_gnn,
)
from repro.simul.datasets import gcn_normalize, load

# citeseer-scale: pallas interpret mode executes the kernel body per grid
# step in Python, so the demo graph stays small (the TPU path is compiled)
g_data = load("citeseer", max_edges=40_000)
graph = build_graph(g_data.adj, tile=128)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((g_data.adj.shape[0], 64)), jnp.float32)

for kind in ["gcn"]:
    cfg_jnp = GNNConfig(name=kind, kind=kind, d_in=64, d_hidden=64, n_classes=16,
                        backend="jnp")
    cfg_pls = GNNConfig(name=kind, kind=kind, d_in=64, d_hidden=64, n_classes=16,
                        backend="pallas_interpret")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg_jnp)
    # the Graph is a pytree ARGUMENT of the jitted forward (not a closure
    # constant): swap graphs of the same shape without retracing
    out_j = gnn_forward_jit(params, cfg_jnp, graph, x).block_until_ready()
    t0 = time.time()
    out_j = gnn_forward_jit(params, cfg_jnp, graph, x).block_until_ready()
    t_jnp = time.time() - t0
    out_p = gnn_forward(params, cfg_pls, graph, x)
    err = float(jnp.abs(out_j - out_p).max())
    print(f"{kind}: jnp {t_jnp*1e3:.1f} ms/inference, pallas-interpret matches to {err:.2e}")

# GAT on the jnp backend (per-edge attention re-weighting through SCV)
cfg_gat = GNNConfig(name="gat", kind="gat", d_in=64, d_hidden=64, n_classes=16,
                    backend="jnp")
params, _ = init_gnn(jax.random.PRNGKey(1), cfg_gat)
out = gnn_forward(params, cfg_gat, graph, x)
print(f"gat: output {out.shape}, finite={bool(jnp.isfinite(out).all())}")
print("OK")
