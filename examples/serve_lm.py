"""Batched serving example: submit a queue of requests against a reduced
LM and stream greedy continuations through the ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod

serve_mod.main([
    "--arch", "gemma2-27b", "--requests", "12", "--prompt-len", "16",
    "--max-new", "12", "--max-batch", "4",
])
