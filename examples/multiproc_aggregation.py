"""The paper's §V-G scalability experiment as a runnable example: split
the SCV-Z tile stream into equal-nnz spans (2..16 parts), aggregate each
span independently, merge partial sums, verify exactness, and report the
load balance the Z-curve achieves on a hub-heavy power-law graph.

    PYTHONPATH=src python examples/multiproc_aggregation.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import coo_to_scv_tiles, load_imbalance, shard_tiles, split_equal_nnz
from repro.core.aggregate import aggregate_scv_tiles
from repro.simul.datasets import gcn_normalize, powerlaw_graph

adj = gcn_normalize(powerlaw_graph(20_000, 120_000, seed=0))
tiles = coo_to_scv_tiles(adj, 64)
z = jnp.asarray(np.random.default_rng(0).standard_normal(
    (adj.shape[1], 32)).astype(np.float32))
full = np.asarray(aggregate_scv_tiles(tiles, z, backend="jnp"))

for parts in [2, 4, 8, 16]:
    part = split_equal_nnz(tiles, parts)
    stacked = shard_tiles(tiles, part)
    width = part.part_tiles.shape[1]
    acc = np.zeros_like(full)
    for p in range(parts):
        sl = slice(p * width, (p + 1) * width)
        sub = dataclasses.replace(
            tiles, tile_row=stacked.tile_row[sl], tile_col=stacked.tile_col[sl],
            rows=stacked.rows[sl], cols=stacked.cols[sl], vals=stacked.vals[sl],
            nnz_in_tile=stacked.nnz_in_tile[sl])
        acc += np.asarray(aggregate_scv_tiles(sub, z, backend="jnp"))
    err = np.abs(acc - full).max()
    print(f"P={parts:2d}: imbalance={load_imbalance(part):.3f} merge-exactness={err:.2e}")
print("OK")
