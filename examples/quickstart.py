"""Quickstart: the paper's technique in 30 lines.

Builds a GCN-normalized synthetic citation graph (Table-I citeseer
statistics), converts the adjacency to the SCV-Z format, and runs the
aggregation through the Pallas kernel (interpret mode on CPU), checking
against the dense oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import coo_to_scv, coo_to_scv_tiles, ZMORTON, aggregate
from repro.simul.datasets import gcn_normalize, load

g = load("citeseer", max_edges=50_000)  # synthetic, Table-I statistics
print(f"graph: {g.adj.shape[0]} nodes, {g.adj.nnz} edges "
      f"(density {g.adj.density:.2e}), scale={g.scale:.2f} vs Table I")

# 1. the paper's logical format (Fig. 1(d))
scv = coo_to_scv(g.adj, vector_height=512, order=ZMORTON)
print(f"SCV-Z: {scv.n_vectors} column vectors of height {scv.vector_height}, "
      f"{scv.index_bits_per_entry} index bits/entry (vs {int(np.ceil(np.log2(g.adj.shape[0])))} for COO)")

# 2. the TPU tile layout + Pallas kernel
tiles = coo_to_scv_tiles(g.adj, tile=64)
z = jnp.asarray(np.random.default_rng(0).standard_normal(
    (g.adj.shape[1], 64)).astype(np.float32))
out = aggregate(tiles, z, backend="pallas_interpret")

# 3. check against the dense oracle
ref = jnp.asarray(g.adj.to_dense()) @ z
print(f"aggregation max err vs dense oracle: {float(jnp.abs(out - ref).max()):.2e}")
print("OK")
