"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the gemma2 family at a ~100M scale (reduced-but-real config: 8 layers,
d_model 512) through the full substrate: data pipeline -> remat'd train
step -> Adam -> checkpoints -> deterministic resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import ARCHS
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="CPU demo default; on TPU run a few hundred")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M config of the gemma2 family, registered on the fly
    spec = ARCHS["gemma2-27b"]
    cfg100m = dataclasses.replace(
        spec.config, name="gemma2-100m", n_layers=12, d_model=640, n_heads=8,
        n_kv_heads=4, head_dim=80, d_ff=2560, vocab=32_768, window=256,
        dtype=jnp.float32,
    )
    small_spec = dataclasses.replace(spec, config=cfg100m, reduced=cfg100m)
    ARCHS["gemma2-100m"] = small_spec
    n = small_spec.param_count()
    print(f"training gemma2-100m: {n/1e6:.1f}M params, {args.steps} steps")

    losses = train_mod.main([
        "--arch", "gemma2-100m", "--steps", str(args.steps),
        "--batch", "4", "--seq", "128", "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
