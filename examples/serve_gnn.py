"""Graph serving end-to-end: the batched multi-graph SCV inference engine.

A stream of requests over a small pool of hot graphs (the serving-scale
regime: many users, few distinct graph topologies) is driven through
``GraphServeEngine``.  Watch three effects:

* the plan cache turns repeat graphs into hits (no §III-C preprocessing),
* batching fuses many small graphs into one block-diagonal aggregation
  launch per layer,
* padding buckets keep the jit shape set small across waves.

    PYTHONPATH=src python examples/serve_gnn.py
"""
import time

import jax
import numpy as np

from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph

rng = np.random.default_rng(0)
D_IN, N_CLASSES = 32, 8

# a pool of hot graphs (e.g. per-tenant subgraphs), reused across requests
pool = [
    gcn_normalize(powerlaw_graph(n, 4 * n, seed=i))
    for i, n in enumerate([60, 90, 120, 150, 200, 250])
]

cfg = GNNConfig(name="gcn", kind="gcn", d_in=D_IN, d_hidden=64,
                n_classes=N_CLASSES, backend="jnp")
params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
engine = GraphServeEngine(
    {"gcn": (params, cfg)},
    GraphEngineConfig(max_batch_graphs=8, max_batch_nodes=2048, tile=64, cap=64),
)

n_requests = 48
t0 = time.time()
for rid in range(n_requests):
    adj = pool[int(rng.integers(len(pool)))]
    x = rng.standard_normal((adj.shape[0], D_IN)).astype(np.float32)
    engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model="gcn"))
    if (rid + 1) % 16 == 0:  # a wave arrives; serve it
        engine.run()
elapsed = time.time() - t0

m = engine.metrics()
print(f"served {m['completed']} requests in {elapsed:.2f}s "
      f"({m['completed'] / elapsed:.1f} graphs/s) "
      f"using {m['launches']} batched launches")
print(f"plan cache: {m['plan_cache_hits']} hits / {m['plan_cache_misses']} misses "
      f"(hit rate {m['plan_cache_hit_rate']:.0%}), "
      f"{m['plan_cache_bytes'] / 1024:.0f} KiB resident, "
      f"{m['plan_build_seconds'] * 1e3:.1f} ms total spent building plans")

# spot-check one request against the unbatched reference
r = engine.completed[-1]
ref = gnn_forward(params, cfg, build_graph(r.adj, tile=64, backend_cap=64),
                  np.asarray(r.x))
err = float(np.abs(np.asarray(ref) - r.out).max())
print(f"batched output matches per-graph forward to {err:.2e}")
print("OK")
