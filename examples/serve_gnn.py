"""Graph serving end-to-end: the batched multi-graph SCV inference engine.

A stream of requests over a small pool of hot graphs (the serving-scale
regime: many users, few distinct graph topologies) is driven through
``GraphServeEngine``.  Watch three effects:

* the plan cache turns repeat graphs into hits (no §III-C preprocessing),
* batching fuses many small graphs into one block-diagonal aggregation
  launch per layer,
* padding buckets keep the jit shape set small across waves,
* live edge mutations land as plan-cache *revalidations* (patched via
  stream.apply_delta), not full rebuilds.

    PYTHONPATH=src python examples/serve_gnn.py
"""
import time

import jax
import numpy as np

from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph
from repro.stream import DeltaBatch

rng = np.random.default_rng(0)
D_IN, N_CLASSES = 32, 8

# a pool of hot graphs (e.g. per-tenant subgraphs), reused across requests
pool = [
    gcn_normalize(powerlaw_graph(n, 4 * n, seed=i))
    for i, n in enumerate([60, 90, 120, 150, 200, 250])
]

cfg = GNNConfig(name="gcn", kind="gcn", d_in=D_IN, d_hidden=64,
                n_classes=N_CLASSES, backend="jnp")
params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
engine = GraphServeEngine(
    {"gcn": (params, cfg)},
    GraphEngineConfig(max_batch_graphs=8, max_batch_nodes=2048, tile=64, cap=64),
)

n_requests = 48
t0 = time.time()
for rid in range(n_requests):
    adj = pool[int(rng.integers(len(pool)))]
    x = rng.standard_normal((adj.shape[0], D_IN)).astype(np.float32)
    engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model="gcn"))
    if (rid + 1) % 16 == 0:  # a wave arrives; serve it
        engine.run()
elapsed = time.time() - t0

m = engine.metrics()
print(f"served {m['completed']} requests in {elapsed:.2f}s "
      f"({m['completed'] / elapsed:.1f} graphs/s) "
      f"using {m['launches']} batched launches")
print(f"plan cache: {m['plan_cache_hits']} hits / {m['plan_cache_misses']} misses "
      f"(hit rate {m['plan_cache_hit_rate']:.0%}), "
      f"{m['plan_cache_bytes'] / 1024:.0f} KiB resident, "
      f"{m['plan_build_seconds'] * 1e3:.1f} ms total spent building plans")

# spot-check one request against the unbatched reference
r = engine.completed[-1]
ref = gnn_forward(params, cfg, build_graph(r.adj, tile=64, backend_cap=64),
                  np.asarray(r.x))
err = float(np.abs(np.asarray(ref) - r.out).max())
print(f"batched output matches per-graph forward to {err:.2e}")

# ---------------------------------------------------------------------------
# live mutation: a tracked graph evolves while it is being served.
# Register an adjacency under a graph_id once, then interleave queries
# (carrying only the id) with engine.update() deltas — each update patches
# the cached plan in place of a §III-C rebuild.
# ---------------------------------------------------------------------------
live = pool[0]
x_live = rng.standard_normal((live.shape[0], D_IN)).astype(np.float32)
engine.submit(GraphRequest(rid=1000, graph_id="live", adj=live, x=x_live,
                           model="gcn"))
engine.run()
before = engine.completed[-1].out.copy()

for step in range(4):
    # re-weight a few random stored edges (remove + re-insert = value update)
    idx = rng.choice(live.nnz, size=3, replace=False)
    delta = DeltaBatch.of(
        inserts=[(int(live.rows[i]), int(live.cols[i]),
                  float(live.vals[i]) * 0.5) for i in idx],
        removes=[(int(live.rows[i]), int(live.cols[i])) for i in idx],
    )
    engine.update("live", delta)
    engine.submit(GraphRequest(rid=1001 + step, graph_id="live", x=x_live,
                               model="gcn"))
    engine.run()
    live = engine.tracked_adj("live")

after = engine.completed[-1].out
m = engine.metrics()
ref = gnn_forward(params, cfg, build_graph(live, tile=64, backend_cap=64),
                  x_live)
live_err = float(np.abs(np.asarray(ref) - after).max())
assert not np.allclose(before, after), "mutations must change the output"
print(f"live graph: {m['graph_updates']} updates served as "
      f"{m['plan_cache_revalidated']} plan revalidations; "
      f"post-delta output matches a fresh rebuild to {live_err:.2e}")
print("OK")
