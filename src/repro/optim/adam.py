"""AdamW with fp32 master/moment state, global-norm clipping, cosine LR.

Pure-pytree implementation (no optax dependency).  Optimizer state mirrors
the param tree, so the same logical-axis sharding rules shard it (ZeRO-
style when params are fsdp-sharded over "data").
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_adam(params, master_weights: bool = False):
    """With ``master_weights`` the f32 master copy lives in the optimizer
    state and ``params`` may be bf16: the forward/backward (and the FSDP
    all-gathers!) move half the bytes; Adam updates the master and emits
    the rounded bf16 params (§Perf distributed-optimization trick)."""
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def schedule(cfg: AdamConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adam_update(cfg: AdamConfig, params, grads, state):
    """Returns (new_params, new_state, metrics).  If the state carries a
    "master" tree, updates apply to the f32 master and params are its
    (bf16) rounding."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(p, w, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if w.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * w.astype(jnp.float32)
        w_new = w.astype(jnp.float32) - lr * delta
        return w_new.astype(p.dtype), m, v, w_new

    out = jax.tree.map(upd, params, masters, grads, state["m"], state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(treedef, [t[3] for t in flat])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
