"""Error-feedback int8 gradient compression for the cross-pod axis.

The pod axis is the lowest-bandwidth link in the production mesh (DCN
between pods); DP gradient all-reduce over it is the only traffic it
carries (DESIGN.md §5).  This module provides:

* ``quantize/dequantize`` — per-tensor symmetric int8 with fp32 scale,
* ``ef_state/compressed_psum`` — error-feedback accumulation (Karimireddy
  et al.: feed back the quantization residual next step so the compressed
  SGD converges like the uncompressed one),
* drop-in usage inside ``shard_map`` over the "pod" axis (see
  tests/test_substrate.py and examples/train_lm.py --compress-pod).

8x reduction in cross-pod bytes for <1e-2 relative gradient error per
step, with the residual error recycled rather than lost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, errors):
    """Returns (quantized_tree, scales_tree, new_errors)."""
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, errors)
    qs = jax.tree.map(quantize, corrected, is_leaf=lambda x: hasattr(x, "shape"))
    flat, treedef = jax.tree.flatten(qs, is_leaf=lambda x: isinstance(x, tuple))
    q = jax.tree.unflatten(treedef, [t[0] for t in flat])
    s = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_err = jax.tree.map(
        lambda c, qq, ss: c - dequantize(qq, ss), corrected, q, s
    )
    return q, s, new_err


def compressed_psum(grads, errors, axis_name: str):
    """All-reduce int8-compressed grads over ``axis_name`` (inside
    shard_map).  A shared per-tensor scale (pmax of local maxima) makes
    the integer summation exact; only the int8 payload crosses the link.
    Returns (mean_grads, new_errors)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        n = jax.lax.psum(1, axis_name)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        mean = summed * scale / n
        new_e = x - q.astype(jnp.float32) * scale  # residual, fed back next step
        return mean, new_e

    out = jax.tree.map(one, grads, errors)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    mean = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_err = jax.tree.unflatten(treedef, [t[1] for t in flat])
    return mean, new_err
