"""Fault-tolerant checkpointing: atomic, versioned, resumable.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json   {step, n_arrays, tree structure, rng, extra}
        arrays.npz      flattened leaves (host-gathered)
        .complete       written last — a checkpoint without it is ignored

Writes go to ``step_X.tmp`` and are atomically renamed, so a crash mid-
write can never corrupt the latest checkpoint.  ``restore_latest`` walks
backwards over steps until it finds a complete one (surviving partial
writes from a dying host).  On real multi-host TPU this would write
per-host shards; on this single-process container we host-gather —
the format keeps a ``shard`` field so per-host files drop in.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, str(treedef)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_arrays": len(leaves),
        "treedef": treedef,
        "shard": 0,
        "n_shards": 1,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, ".complete"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, ".complete")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def restore(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (validates leaf count
    and shapes).  Returns (tree, extra)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(like_tree)
    assert manifest["n_arrays"] == len(leaves), (
        f"checkpoint has {manifest['n_arrays']} arrays, model expects {len(leaves)}"
    )
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"a{i}"]
        assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
        out.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
    return jax.tree.unflatten(jax.tree.structure(like_tree), out), manifest["extra"]


def restore_latest(ckpt_dir: str, like_tree):
    """Newest complete checkpoint, or None.  Tolerates partially-written
    (crashed) checkpoints by skipping incomplete dirs."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, like_tree)
            return step, tree, extra
        except Exception:  # corrupt despite marker: keep walking back
            continue
    return None


def prune(ckpt_dir: str, keep: int = 3):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
