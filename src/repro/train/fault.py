"""Fault-tolerance runtime pieces: heartbeats, straggler detection,
elastic re-meshing, deterministic restart.

On a real multi-pod deployment these hooks wrap the JAX distributed
runtime (jax.distributed + coordination service).  Everything here is
framework logic that is unit-testable on one host:

* ``Heartbeat`` — per-worker liveness with a wall-clock deadline; the
  launcher marks a worker dead after ``timeout_s`` and triggers an
  elastic re-mesh.
* ``StragglerDetector`` — per-step-time EWMA + z-score; a worker whose
  step time exceeds mean + k*std for ``patience`` consecutive steps is
  flagged so the launcher can demote/replace it (the scheduling analogue
  of the paper's equal-nnz balancing: don't let one slow unit gate the
  fleet).
* ``elastic_mesh_shapes`` — given surviving chip count, the largest
  (data, model) mesh we can rebuild while keeping the model axis intact;
  train state is re-loaded from the latest checkpoint (checkpoint.py) and
  lowering re-runs with identical code — meshes are *functions*, nothing
  is baked at import time (launch/mesh.py).
* ``DataSkipper`` — deterministic batch skipping so a restarted run sees
  exactly the batches it would have (same seed, skip to step).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict


@dataclasses.dataclass
class Heartbeat:
    timeout_s: float = 60.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: float | None = None):
        self._last[worker] = time.monotonic() if now is None else now

    def dead_workers(self, known: list[int], now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [
            w
            for w in known
            if now - self._last.get(w, -1e18) > self.timeout_s
        ]


@dataclasses.dataclass
class StragglerDetector:
    k_sigma: float = 3.0
    patience: int = 3
    decay: float = 0.9
    _mean: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    _var: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    _strikes: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    _seen: dict = dataclasses.field(default_factory=lambda: defaultdict(int))

    def observe(self, worker: int, step_time: float) -> bool:
        """Returns True when the worker is flagged as a straggler."""
        m, v = self._mean[worker], self._var[worker]
        self._seen[worker] += 1
        if self._seen[worker] < 3:  # warm-up
            self._mean[worker] = step_time if m == 0 else 0.5 * (m + step_time)
            return False
        sigma = max(v**0.5, 1e-6, 0.05 * m)
        if step_time > m + self.k_sigma * sigma:
            self._strikes[worker] += 1
        else:
            self._strikes[worker] = 0
            self._mean[worker] = self.decay * m + (1 - self.decay) * step_time
            self._var[worker] = self.decay * v + (1 - self.decay) * (step_time - m) ** 2
        return self._strikes[worker] >= self.patience


def elastic_mesh_shapes(n_chips: int, model_parallel: int = 16) -> tuple[int, int]:
    """Largest (data, model) shape with the model axis preserved.  Chips
    not forming a full data replica are parked (elastic scale-down).
    Scale-up is the same function with a larger n_chips."""
    data = max(1, n_chips // model_parallel)
    return data, model_parallel


@dataclasses.dataclass
class DataSkipper:
    """Deterministic resume: data order is a pure function of (seed, step),
    so skipping to `start_step` replays nothing and loses nothing."""

    seed: int
    batch_ids_seen: int = 0

    def skip_to(self, step: int, batches_per_step: int = 1):
        self.batch_ids_seen = step * batches_per_step

    def next_batch_id(self) -> int:
        i = self.batch_ids_seen
        self.batch_ids_seen += 1
        return i
