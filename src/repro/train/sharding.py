"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Params carry logical axis names (see models/layers.py).  ``PARAM_RULES``
maps each name to the preferred mesh axes; at resolution time an axis is
silently dropped (replicated) when the dimension is not divisible by the
mesh axis size or the mesh axis is already consumed by an earlier dim —
this is what lets e.g. kv_heads=4 coexist with a 16-way model axis.

Activations use ``constrain(x, logical_axes)`` which resolves against the
mesh installed by ``use_mesh`` (no-op when no mesh is active, so the same
model code runs tests on one CPU device).
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# params: fsdp over "data", tensor-parallel over "model"
PARAM_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "embed": ("data",),
    "embed2": None,
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "expert": ("model",),
    "mla_rank": None,
    "inner": ("model",),
    "conv": None,
    "mamba_heads": None,
    "layers": None,
    "sublayers": None,
    "seq": None,
    "gnn_in": ("data",),
    "gnn_out": ("model",),
}

# activations and serve-time caches/states
ACT_RULES: dict[str, tuple] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "mlp": ("model",),
    "inner": ("model",),
    "mamba_heads": ("model",),
    "seq": None,
    "seq_sharded": ("model",),
    # caches: when kv_heads can't shard the model axis (kv=4/8/12/40),
    # head_dim takes it instead (resolver's used-set keeps them exclusive)
    "head_dim": ("model",),
    "mla_rank": None,
    "state": None,
    "conv": None,
    "layers": None,
    "sublayers": None,
}

_local = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install mesh for constrain()/make_*_sharding and jax's context."""
    prev = getattr(_local, "mesh", None)
    _local.mesh = mesh
    try:
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            yield mesh
    finally:
        _local.mesh = prev


def active_mesh() -> Mesh | None:
    return getattr(_local, "mesh", None)


def _resolve(shape, axes, rules, mesh) -> P:
    """Logical axes -> PartitionSpec with divisibility/conflict fallback."""
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        entry = rules.get(name)
        if entry is None:
            parts.append(None)
            continue
        assign = []
        size = 1
        for ax in entry:
            if ax not in mesh.shape or ax in used:
                continue
            if dim % (size * mesh.shape[ax]) != 0:
                continue
            assign.append(ax)
            size *= mesh.shape[ax]
        if assign:
            used.update(assign)
            parts.append(tuple(assign) if len(assign) > 1 else assign[0])
        else:
            parts.append(None)
    return P(*parts)


def param_spec(shape, axes, mesh=None) -> P:
    mesh = mesh or active_mesh()
    return _resolve(shape, axes, PARAM_RULES, mesh)


def make_param_sharding(mesh: Mesh, params_shapes, specs):
    """NamedSharding tree for a params pytree.  ``params_shapes`` may be
    arrays or ShapeDtypeStructs; ``specs`` the logical-axes tree."""
    return jax.tree.map(
        lambda x, ax: NamedSharding(mesh, _resolve(x.shape, ax, PARAM_RULES, mesh)),
        params_shapes,
        specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def constrain(x, logical_axes):
    """with_sharding_constraint against the active mesh (no-op if none)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = _resolve(x.shape, logical_axes, ACT_RULES, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def attn_axes(n_heads: int):
    """Sharding axes for [B, S, H, D] attention activations: shard heads
    over model when divisible, else fall back to sharding the sequence
    (qwen's 40 heads / whisper's 12 heads on a 16-way model axis)."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.shape or n_heads % mesh.shape["model"] == 0:
        return ("batch", None, "heads", None)
    return ("batch", "seq_sharded", None, None)


def batch_sharding(mesh: Mesh, n_leading=1):
    """Sharding for input batches: leading axis over all data-like axes."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))


def unfsdp_params(params, axes_tree):
    """Drop the fsdp ("data") factor from every param's sharding while
    keeping tensor parallelism: a single explicit all-gather per step
    instead of one per microbatch (§Perf train iteration)."""
    mesh = active_mesh()
    if mesh is None:
        return params
    rules = {k: (tuple(a for a in v if a != "data") or None) if v else v
             for k, v in PARAM_RULES.items()}
    return jax.tree.map(
        lambda x, ax: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, _resolve(x.shape, ax, rules, mesh))
        ),
        params,
        axes_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def refsdp_params(tree, axes_tree):
    """Constrain a grad tree back to the full param sharding (undo the
    unfsdp gather for the accumulation buffer)."""
    mesh = active_mesh()
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda x, ax: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, _resolve(x.shape, ax, PARAM_RULES, mesh))
        ),
        tree,
        axes_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
