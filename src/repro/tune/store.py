"""On-disk cache of tuned configs, keyed by regime signature x machine.

A flat JSON file (atomic tmp+rename writes) so concurrent benches and a
serving process can share one store; misses are cheap (one dict lookup
after an O(nnz) histogram), hits skip both the simulator sweep and the
measured calibration.  Staleness is structural: the key embeds
:func:`repro.tune.signature.machine_fingerprint`, so a changed
``MachineConfig`` (or jax backend) never sees old entries.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Optional

from repro.tune.config import TunedConfig

STORE_VERSION = 1


class TuneStore:
    """Config cache: in-memory always, mirrored to ``path`` when given."""

    def __init__(self, path: Optional[str | pathlib.Path] = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.hits = 0
        self.misses = 0
        self._entries: dict[str, dict] = {}
        if self.path is not None and self.path.exists():
            try:
                data = json.loads(self.path.read_text())
                if data.get("version") == STORE_VERSION:
                    self._entries = dict(data.get("entries", {}))
            except (json.JSONDecodeError, OSError):
                self._entries = {}  # corrupt store == empty store

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[TunedConfig]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        cfg = TunedConfig.from_json(entry["config"])
        return cfg

    def put(self, key: str, config: TunedConfig, meta: Optional[dict] = None):
        self._entries[key] = {
            "config": config.to_json(),
            "meta": dict(meta or {}),
        }
        if self.path is not None:
            self._flush()

    def _flush(self):
        payload = json.dumps(
            {"version": STORE_VERSION, "entries": self._entries}, indent=2
        )
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(self.path.parent), prefix=self.path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(payload + "\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
