"""TunedConfig — the frozen plan-configuration record the autotuner emits.

This module and ``core/scv.py`` are the only two places allowed to define
tile/cap/chunk/ladder values (scvlint SCV002); everything downstream —
``models.gnn.build_graph``, ``core.scv.plan_from_tiles_bucketed``, the
serve engine — consumes a ``TunedConfig`` or the ``core.scv`` defaults.
"""
from __future__ import annotations

import dataclasses

from repro.core.scv import (
    DEFAULT_CAP,
    DEFAULT_CHUNK,
    DEFAULT_LADDER,
    DEFAULT_TILE,
    MXU_VPU_RATIO,
)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One point in the (T, C, dense-threshold-ratio, ladder) search space.

    ``bucket_caps`` is the ascending capacity ladder; an empty tuple means
    single-cap plans at ``cap``.  ``source`` records how the config was
    obtained (``default`` / ``simulated`` / ``calibrated`` / ``cache``) —
    metadata only, excluded from equality so a cache round-trip compares
    equal to the freshly tuned config.
    """

    tile: int = DEFAULT_TILE
    chunk: int = DEFAULT_CHUNK
    dense_threshold_ratio: float = MXU_VPU_RATIO
    bucket_caps: tuple[int, ...] = DEFAULT_LADDER
    cap: int = DEFAULT_CAP
    source: str = "default"

    def __post_init__(self):
        object.__setattr__(self, "bucket_caps", tuple(int(c) for c in self.bucket_caps))
        if self.tile <= 0 or self.tile & (self.tile - 1):
            raise ValueError(f"tile must be a positive power of two, got {self.tile}")
        if self.chunk <= 0:
            raise ValueError(f"chunk must be positive, got {self.chunk}")
        if not 0.0 < self.dense_threshold_ratio <= 1.0:
            raise ValueError(
                f"dense_threshold_ratio must be in (0, 1], got"
                f" {self.dense_threshold_ratio}"
            )
        caps = self.bucket_caps
        if caps and (list(caps) != sorted(set(caps)) or min(caps) <= 0):
            raise ValueError(f"bucket_caps must be ascending and positive: {caps}")
        if not caps and self.cap <= 0:
            raise ValueError(f"cap must be positive when no ladder, got {self.cap}")

    def __eq__(self, other):
        if not isinstance(other, TunedConfig):
            return NotImplemented
        return self.plan_key == other.plan_key

    def __hash__(self):
        return hash(self.plan_key)

    @property
    def plan_key(self) -> tuple:
        """The fields that change the built plan / kernel schedule —
        ``source`` excluded."""
        return (
            self.tile,
            self.chunk,
            round(self.dense_threshold_ratio, 6),
            self.bucket_caps,
            self.cap if not self.bucket_caps else 0,
        )

    @property
    def cap_signature(self) -> tuple[int, ...] | int:
        """What plan caches salt on: the ladder, or the single cap."""
        return self.bucket_caps if self.bucket_caps else self.cap

    def dense_tile_threshold(self) -> int:
        """nnz above which a T x T tile goes to the dense MXU path —
        the tuned analogue of :func:`core.scv.dense_tile_threshold`."""
        return int(self.tile * self.tile * self.dense_threshold_ratio)

    @classmethod
    def default(cls) -> "TunedConfig":
        return cls()

    def to_json(self) -> dict:
        return {
            "tile": self.tile,
            "chunk": self.chunk,
            "dense_threshold_ratio": self.dense_threshold_ratio,
            "bucket_caps": list(self.bucket_caps),
            "cap": self.cap,
            "source": self.source,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        return cls(
            tile=int(d["tile"]),
            chunk=int(d["chunk"]),
            dense_threshold_ratio=float(d["dense_threshold_ratio"]),
            bucket_caps=tuple(int(c) for c in d["bucket_caps"]),
            cap=int(d.get("cap", DEFAULT_CAP)),
            source=str(d.get("source", "cache")),
        )
