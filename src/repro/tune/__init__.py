"""Simulator-pruned autotuner for SCV plan configuration (DESIGN.md §8).

Public surface:

* :class:`TunedConfig` — frozen (T, C, ratio, ladder) record; the only
  sanctioned carrier of tile/cap/chunk values outside ``core/scv.py``.
* :class:`Autotuner` — two-stage search (analytic prune, measured
  calibration) with an on-disk :class:`TuneStore` cache.
* ``histogram_signature`` / ``machine_fingerprint`` / ``cache_key`` — the
  regime-keyed cache scheme.
"""
from repro.tune.autotuner import Autotuner, ScoredCandidate, TuneResult, spearman
from repro.tune.config import TunedConfig
from repro.tune.cost import (
    CostEstimate,
    plan_launched_slots,
    plan_slot_bytes,
    predict_cost,
)
from repro.tune.signature import (
    cache_key,
    histogram_signature,
    machine_fingerprint,
    quantize_histogram,
)
from repro.tune.store import TuneStore

__all__ = [
    "Autotuner",
    "CostEstimate",
    "ScoredCandidate",
    "TuneResult",
    "TuneStore",
    "TunedConfig",
    "cache_key",
    "histogram_signature",
    "machine_fingerprint",
    "plan_launched_slots",
    "plan_slot_bytes",
    "predict_cost",
    "quantize_histogram",
    "spearman",
]
