"""Two-stage autotuner: simulator-pruned search, measured calibration.

Stage 1 scores every candidate ``(T, C, dense-threshold-ratio, ladder)``
analytically — :func:`repro.tune.cost.predict_cost` (``run_scv_bucketed``
cycles + slot-priced traffic + per-launch overhead) plus two kernel-body
terms the plan-level model cannot see (chunk-step overhead/padding and the
MXU/VPU crossover of the dense-tile split).  One simulator run per
distinct tile is shared across every ladder at that tile, so the sweep is
O(tiles) simulator passes, not O(candidates).

Stage 2 builds real plans for the top-``k`` surviving ``(T, ladder)``
pairs — the hand-picked default always rides along as a control — and
times short measured aggregation runs; the measured winner becomes the
:class:`TunedConfig`, cached in a :class:`TuneStore` keyed by quantized
histogram signature x machine fingerprint (see ``signature.py`` for the
staleness rule).  With ``calibrate=False`` the stage-1 winner is returned
directly — the cheap mode the serve engine uses at admission time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import (
    MXU_VPU_RATIO,
    bucket_caps_for,
    coo_to_scv_tiles,
    plan_from_tiles_bucketed,
    tile_nnz_histogram,
)
from repro.simul.dataflows import run_scv_bucketed
from repro.simul.machine import MachineConfig

from repro.tune.config import TunedConfig
from repro.tune.cost import CLOCK_HZ, CostEstimate, predict_cost
from repro.tune.signature import cache_key, histogram_signature, machine_fingerprint
from repro.tune.store import TuneStore

#: Fixed per-chunk-step cost of the vectorized kernel body, in
#: entry-equivalents (grid bookkeeping + scatter/gather setup per step).
CHUNK_STEP_ENTRIES = 64

#: Candidate tiles.  Powers of two around the lane width; T > 256 makes
#: T^2 dense fallback blocks exceed VMEM budgets, T < 16 defeats the MXU.
TILE_CANDIDATES = (32, 64, 128)
CHUNK_CANDIDATES = (64, 128, 256)
RATIO_CANDIDATES = (MXU_VPU_RATIO / 2, MXU_VPU_RATIO, MXU_VPU_RATIO * 2)


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    config: TunedConfig
    estimate: CostEstimate
    score_s: float  # estimate.seconds + chunk + dense terms
    measured_s: Optional[float] = None

    def to_json(self) -> dict:
        return {
            "config": self.config.to_json(),
            "predicted_s": self.score_s,
            "measured_s": self.measured_s,
            "estimate": self.estimate.to_json(),
        }


@dataclasses.dataclass
class TuneResult:
    key: str
    config: TunedConfig
    cached: bool
    candidates: list = dataclasses.field(default_factory=list)
    calibrated: list = dataclasses.field(default_factory=list)
    rank_correlation: Optional[float] = None
    search_seconds: float = 0.0


def spearman(xs, ys) -> float:
    """Spearman rank correlation (average ranks on ties)."""
    if len(xs) < 2:
        return 1.0
    rx = _ranks(xs)
    ry = _ranks(ys)
    sx, sy = np.std(rx), np.std(ry)
    if sx == 0 or sy == 0:
        return 0.0
    return float(np.corrcoef(rx, ry)[0, 1])


def _ranks(xs) -> np.ndarray:
    xs = np.asarray(xs, dtype=np.float64)
    order = np.argsort(xs, kind="stable")
    ranks = np.empty(len(xs), dtype=np.float64)
    ranks[order] = np.arange(len(xs), dtype=np.float64)
    for v in np.unique(xs):
        mask = xs == v
        ranks[mask] = ranks[mask].mean()
    return ranks


def candidate_ladders(counts: np.ndarray, tile: int) -> tuple[tuple[int, ...], ...]:
    """Contiguous sub-ladders of the derived full ladder for ``tile``.

    ``bucket_caps_for`` gives the max-depth ladder; shallower contiguous
    slices trade dummy/padding slots against launch count (PR 8's measured
    A/B was exactly this family).  Chain-splitting at ``caps[-1]`` makes
    every slice valid regardless of the heaviest tile.
    """
    full = bucket_caps_for(counts, tile)
    out = []
    for i in range(len(full)):
        for j in range(i + 1, len(full) + 1):
            out.append(full[i:j])
    return tuple(dict.fromkeys(out))


class Autotuner:
    """Search + cache driver.  Thread a shared :class:`TuneStore` through
    several tuners (or processes) to share the on-disk cache."""

    def __init__(
        self,
        machine: Optional[MachineConfig] = None,
        store: Optional[TuneStore] = None,
        *,
        tiles: tuple[int, ...] = TILE_CANDIDATES,
        chunks: tuple[int, ...] = CHUNK_CANDIDATES,
        ratios: tuple[float, ...] = RATIO_CANDIDATES,
        top_k: int = 3,
        calibrate: bool = True,
        calib_reps: int = 2,
    ):
        self.machine = machine if machine is not None else MachineConfig()
        self.store = store if store is not None else TuneStore()
        self.tiles = tuple(tiles)
        self.chunks = tuple(chunks)
        self.ratios = tuple(ratios)
        self.top_k = int(top_k)
        self.calibrate = bool(calibrate)
        self.calib_reps = int(calib_reps)
        self.searches = 0
        self.cache_hits = 0
        self.last_result: Optional[TuneResult] = None

    # -- public entry ------------------------------------------------------
    def tune(self, adj: COOMatrix, n_features: int = 64) -> TunedConfig:
        """Resolve the config for ``adj``: cache hit, or two-stage search."""
        if adj.nnz == 0:
            return TunedConfig.default()
        counts_ref = tile_nnz_histogram(adj, TunedConfig.default().tile)
        key = cache_key(
            histogram_signature(counts_ref), machine_fingerprint(self.machine)
        )
        hit = self.store.get(key)
        if hit is not None:
            self.cache_hits += 1
            self.last_result = TuneResult(key=key, config=hit, cached=True)
            return hit
        t0 = time.perf_counter()
        self.searches += 1
        scored = self._stage1(adj, n_features)
        result = TuneResult(key=key, config=scored[0].config, cached=False)
        result.candidates = scored
        if self.calibrate:
            calibrated = self._stage2(adj, scored, n_features)
            result.calibrated = calibrated
            winner = min(calibrated, key=lambda c: c.measured_s)
            result.rank_correlation = spearman(
                [c.score_s for c in calibrated],
                [c.measured_s for c in calibrated],
            )
            result.config = dataclasses.replace(winner.config, source="calibrated")
        else:
            result.config = dataclasses.replace(scored[0].config, source="simulated")
        result.search_seconds = time.perf_counter() - t0
        self.store.put(
            key,
            result.config,
            meta={
                "n_candidates": len(scored),
                "n_calibrated": len(result.calibrated),
                "rank_correlation": result.rank_correlation,
                "search_seconds": result.search_seconds,
            },
        )
        self.last_result = result
        return result.config

    # -- stage 1: analytic prune ------------------------------------------
    def _stage1(self, adj: COOMatrix, n_features: int) -> list[ScoredCandidate]:
        scored = []
        default = TunedConfig.default()
        for tile in self.tiles:
            counts = tile_nnz_histogram(adj, tile)
            base = run_scv_bucketed(
                adj, n_features, self.machine, tile,
                caps=bucket_caps_for(counts, tile),
            )
            ladders = candidate_ladders(counts, tile)
            if tile == default.tile and default.bucket_caps not in ladders:
                ladders = ladders + (default.bucket_caps,)
            for caps in ladders:
                chunk = self._best_chunk(counts, tile, caps, n_features)
                ratio = self._best_ratio(counts, tile, n_features)
                cfg = TunedConfig(
                    tile=tile,
                    chunk=chunk,
                    dense_threshold_ratio=ratio,
                    bucket_caps=caps,
                )
                est = predict_cost(
                    adj, cfg, n_features, machine=self.machine, compute=base
                )
                score = (
                    est.seconds
                    + self._chunk_term(counts, tile, caps, chunk, n_features)
                    + self._dense_term(counts, tile, ratio, n_features)
                )
                scored.append(ScoredCandidate(cfg, est, score))
        scored.sort(key=lambda c: c.score_s)
        return scored

    def _entry_seconds(self, n_features: int) -> float:
        """One VPU entry-update in seconds: ceil(F / N_PE) cycles."""
        return -(-n_features // self.machine.n_pe) / CLOCK_HZ

    def _chunk_term(self, counts, tile, caps, chunk, n_features) -> float:
        """Chunk-step overhead + intra-chunk padding of the kernel body.

        A tile at cap ``c`` runs ``ceil(c / C)`` steps; each step costs a
        fixed ``CHUNK_STEP_ENTRIES`` bookkeeping charge and processes a
        full ``C``-wide chunk, so work is ``steps * (C +
        CHUNK_STEP_ENTRIES)`` entry-equivalents per tile.
        """
        per_cap = _segment_tile_counts(counts, caps)
        entries = 0.0
        for cap, n_tiles in per_cap.items():
            steps = -(-cap // chunk)
            entries += n_tiles * steps * (min(chunk, cap) + CHUNK_STEP_ENTRIES)
        return entries * self._entry_seconds(n_features) / self.machine.n_vpe

    def _best_chunk(self, counts, tile, caps, n_features) -> int:
        return min(
            self.chunks,
            key=lambda c: self._chunk_term(counts, tile, caps, c, n_features),
        )

    def _dense_term(self, counts, tile, ratio, n_features) -> float:
        """Signed cost delta of densifying tiles above ``T^2 * ratio``:
        a densified tile trades its nnz VPU entry-updates for a dense
        ``T^2 * MXU_VPU_RATIO`` entry-equivalent MXU matmul."""
        counts_arr = np.asarray(counts, dtype=np.int64)
        thresh = int(tile * tile * ratio)
        dense = counts_arr[counts_arr > thresh]
        if dense.size == 0:
            return 0.0
        mxu_equiv = tile * tile * MXU_VPU_RATIO
        delta_entries = float((mxu_equiv - dense).sum())
        return delta_entries * self._entry_seconds(n_features) / self.machine.n_vpe

    def _best_ratio(self, counts, tile, n_features) -> float:
        return min(
            self.ratios,
            key=lambda r: self._dense_term(counts, tile, r, n_features),
        )

    # -- stage 2: measured calibration ------------------------------------
    def _stage2(
        self, adj: COOMatrix, scored: list[ScoredCandidate], n_features: int
    ) -> list[ScoredCandidate]:
        import jax
        import jax.numpy as jnp

        from repro.core.aggregate import aggregate_scv_plan

        default = TunedConfig.default()
        survivors: list[ScoredCandidate] = []
        seen: set = set()
        for cand in scored:
            k = (cand.config.tile, cand.config.bucket_caps)
            if k not in seen:
                seen.add(k)
                survivors.append(cand)
            if len(survivors) >= self.top_k:
                break
        if (default.tile, default.bucket_caps) not in seen:
            # the control: the hand-picked default is always measured too
            ctl = next(
                (
                    c for c in scored
                    if (c.config.tile, c.config.bucket_caps)
                    == (default.tile, default.bucket_caps)
                ),
                ScoredCandidate(
                    default,
                    predict_cost(adj, default, n_features, machine=self.machine),
                    float("inf"),
                ),
            )
            survivors.append(ctl)

        rng = np.random.default_rng(0)
        z = jnp.asarray(
            rng.integers(-3, 4, size=(adj.shape[1], n_features)).astype(np.float32)
        )
        agg = jax.jit(lambda p, zz: aggregate_scv_plan(p, zz, backend="jnp"))
        out = []
        for cand in survivors:
            caps = cand.config.bucket_caps or (cand.config.cap,)
            tiles = coo_to_scv_tiles(adj, cand.config.tile, cap=caps[-1])
            plan = plan_from_tiles_bucketed(tiles, caps=caps)
            agg(plan, z).block_until_ready()  # compile + warm
            best = float("inf")
            for _ in range(self.calib_reps):
                t0 = time.perf_counter()
                agg(plan, z).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            out.append(dataclasses.replace(cand, measured_s=best))
        return out


def _segment_tile_counts(counts, caps) -> dict[int, int]:
    """Launched tiles per cap after chain-splitting ``counts`` at the top
    cap (no coverage dummies — callers add those where they matter)."""
    caps_arr = np.asarray(sorted(int(c) for c in caps), dtype=np.int64)
    counts_arr = np.asarray(counts, dtype=np.int64)
    counts_arr = counts_arr[counts_arr > 0]
    top = int(caps_arr[-1])
    out = {int(c): 0 for c in caps_arr}
    if counts_arr.size:
        out[top] += int((counts_arr // top).sum())
        rem = counts_arr % top
        rem = rem[rem > 0]
        if rem.size:
            idx = np.searchsorted(caps_arr, rem)
            for i, n in zip(*np.unique(idx, return_counts=True)):
                out[int(caps_arr[i])] += int(n)
    return out
