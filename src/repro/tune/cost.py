"""Stage-1 analytic cost model: simulator cycles + slot-priced traffic.

One byte model for two callers.  :func:`plan_slot_bytes` prices the device
plan triple (rows/cols/vals) at *launched* capacity slots — the number
:func:`core.scv.launched_slots` computes from a histogram and
``core.exec.placement_bytes(n_slots=...)`` consumes for placement — so the
autotuner and ``PlanExecutor.decide_sharding`` charge padding identically.
:func:`predict_cost` is what stage 1 of the tuner ranks candidates by:
``simul.dataflows.run_scv_bucketed`` compute cycles plus DRAM-bandwidth
time over the slot-priced traffic, plus a per-launch charge (one kernel
launch per ladder segment — the term that penalizes deep ladders, the
measured effect that flipped the PR 8 serving default to 2-deep).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import launched_slots
from repro.simul.dataflows import run_scv_bucketed
from repro.simul.machine import MachineConfig

from repro.tune.config import TunedConfig

#: Modeled clock of the simulated vector processor (paper §V: 1 GHz-class).
CLOCK_HZ = 1e9
#: Per-kernel-launch overhead charged per ladder segment.  Dispatch is a
#: host-side cost, so this is a fraction tuned to reproduce the PR 8
#: serve_bench A/B ordering (2-deep beating 3-deep on the sparse pool)
#: rather than a hardware constant.
LAUNCH_OVERHEAD_S = 2e-3


def plan_slot_bytes(n_slots: int, machine: MachineConfig | None = None) -> float:
    """Bytes of the shipped plan triple at ``n_slots`` capacity slots:
    rows + cols + vals, one element each per slot, padding included."""
    if machine is None:
        machine = MachineConfig()
    return 3.0 * float(n_slots) * machine.bytes_per_elem


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Stage-1 prediction for one candidate config on one graph."""

    seconds: float  # the ranking key
    compute_s: float
    traffic_s: float
    launch_s: float
    cycles: float
    traffic_bytes: float
    n_slots: int
    n_launches: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def predict_cost(
    adj: COOMatrix,
    config: TunedConfig,
    n_features: int,
    machine: MachineConfig | None = None,
    compute=None,
) -> CostEstimate:
    """Analytic seconds for aggregating ``adj`` under ``config``.

    ``compute`` optionally injects a precomputed ``run_scv_bucketed``
    result for this (graph, tile) — cycles depend only on the tile, so the
    tuner shares one simulator run across every candidate at that tile.
    """
    if machine is None:
        machine = MachineConfig()
    caps = tuple(config.bucket_caps) or (int(config.cap),)
    if compute is None:
        comp, traffic, slots = run_scv_bucketed(
            adj, n_features, machine, config.tile, caps=caps
        )
        traffic_bytes = float(traffic.total_bytes)
    else:
        # cycles and Z/PS traffic depend only on the tile; re-price the
        # plan triple (bytes_a) at this candidate's ladder
        from repro.core.scv import tile_nnz_histogram
        from repro.simul.dataflows import E

        comp, traffic, _ = compute
        slots = launched_slots(
            tile_nnz_histogram(adj, config.tile),
            config.tile,
            caps,
            n_row_blocks=-(-adj.shape[0] // config.tile),
        )
        f_pass = int(np.clip(
            machine.mem_ps_bytes // (E * config.tile), 8, n_features
        ))
        passes = -(-n_features // f_pass)
        bytes_a = plan_slot_bytes(slots, machine) * passes
        traffic_bytes = bytes_a + float(traffic.bytes_z) + float(traffic.bytes_ps)
    traffic_s = traffic_bytes * 8.0 / (machine.dram_gbps * 1e9)
    compute_s = float(comp.cycles) / CLOCK_HZ
    n_launches = len(caps)
    launch_s = n_launches * LAUNCH_OVERHEAD_S
    return CostEstimate(
        seconds=compute_s + traffic_s + launch_s,
        compute_s=compute_s,
        traffic_s=traffic_s,
        launch_s=launch_s,
        cycles=float(comp.cycles),
        traffic_bytes=traffic_bytes,
        n_slots=int(slots),
        n_launches=n_launches,
    )


def plan_launched_slots(plan) -> int:
    """Exact launched capacity slots of a built plan (``SCVPlan`` or
    ``SCVBucketedPlan``) — coverage dummies included, read from static aux
    only (no device sync)."""
    segments = getattr(plan, "segments", (plan,))
    return int(sum(int(s.n_tiles) * int(s.cap) for s in segments))
