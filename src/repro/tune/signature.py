"""Cache keys for tuned configs: graph-regime signature x machine fingerprint.

A tuned config is reusable across graphs that *bucket the same way*, not
across graphs that are byte-identical — so the key quantizes the tile-nnz
histogram instead of hashing the edge list:

* tile nnz values collapse into power-of-two bins (``floor(log2(nnz))``),
  the same resolution at which :func:`core.scv.bucket_caps_for` picks caps;
* each bin's tile count collapses to ``round(log2(count + 1))`` — a
  half-octave count change is regime drift, a ±1-entry perturbation is not.

The machine half is :meth:`simul.machine.MachineConfig.fingerprint` plus
the jax backend platform, so a config tuned under one machine model (or
backend) is never served under another: changing ``MachineConfig`` changes
the fingerprint, the composite key misses, and the tuner re-searches —
that *is* the staleness rule (DESIGN.md §8).
"""
from __future__ import annotations

import hashlib
import math

import numpy as np

from repro.core.scv import DEFAULT_TILE
from repro.simul.machine import MachineConfig


def quantize_histogram(counts: np.ndarray, tile: int) -> tuple:
    """Quantized (log2-nnz-bin, log2-count-level) pairs, sorted.

    Stable under small perturbations: moving one edge between tiles — or
    adding/removing a tile — shifts a bin count by 1, which only changes
    ``round(log2(count + 1))`` near power-of-two boundaries, and even then
    by one level in one bin.
    """
    counts_arr = np.asarray(counts, dtype=np.int64)
    counts_arr = counts_arr[counts_arr > 0]
    if counts_arr.size == 0:
        return ()
    bins = np.floor(np.log2(counts_arr)).astype(np.int64)
    out = []
    for b in np.unique(bins):
        n = int((bins == b).sum())
        out.append((int(b), int(round(math.log2(n + 1)))))
    return tuple(out)


def histogram_signature(counts: np.ndarray, tile: int = DEFAULT_TILE) -> str:
    """Short stable id of a graph regime at reference tile ``tile``."""
    q = quantize_histogram(counts, tile)
    payload = f"T{int(tile)};" + ";".join(f"{b}:{lvl}" for b, lvl in q)
    return hashlib.blake2b(payload.encode(), digest_size=8).hexdigest()


def machine_fingerprint(machine: MachineConfig | None = None) -> str:
    """Machine half of the cache key: model constants + jax backend."""
    if machine is None:
        machine = MachineConfig()
    import jax

    return f"{machine.fingerprint()}-{jax.default_backend()}"


def cache_key(signature: str, fingerprint: str) -> str:
    return f"{signature}@{fingerprint}"
