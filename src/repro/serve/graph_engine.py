"""Batched multi-graph SCV inference engine.

The GNN analogue of ``serve/engine.py``'s LM loop: requests carry a whole
graph (adjacency + node features + model kind) instead of a prompt, and a
"batch" is many small graphs fused into one block-diagonal adjacency so the
entire wave runs as **one** SCV aggregation launch per layer.

Three mechanisms make this a serving system rather than a loop:

1. **Plan cache** (``plan_cache.py``) — the §III-C host-side SCV build is
   content-addressed and LRU-cached at two levels: per-graph ``Graph``
   bundles (hot graphs skip preprocessing) and assembled composite batches
   (hot *batches* skip even the concatenation).

2. **Composite assembly from cached plans** — because every member plan is
   padded to the tile grid, a batch plan is pure index arithmetic: member
   tile coordinates are shifted by the member's block offset and the tile
   arrays concatenated.  No re-tiling, no re-sorting, no COO scan.  The
   block-diagonal structure guarantees the result equals per-graph
   aggregation stacked (``core.formats.block_diag_coo`` is the reference
   construction; ``tests/test_serve_graph.py`` checks both agree).

3. **Padding buckets** — composite node counts are rounded up to a fixed
   bucket ladder, so XLA sees a handful of distinct shapes instead of one
   per batch and jit recompilation is bounded.

The engine is synchronous and single-host (like ``ServeEngine``); the
launch/ layer owns meshes and process fan-out.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import SCVTiles
from repro.models.gnn import (
    BatchedGraph,
    GNNConfig,
    Graph,
    build_graph,
    gnn_forward_batched,
)
from repro.serve.plan_cache import PlanCache, combine_keys, coo_content_key


@dataclasses.dataclass
class GraphRequest:
    """One inference request: run ``model`` over (adj, x)."""

    rid: int
    adj: COOMatrix  # normalized adjacency (e.g. gcn_normalize output)
    x: np.ndarray  # f32[n_nodes, d_in]
    model: str = "default"
    out: Optional[np.ndarray] = None  # f32[n_nodes, n_classes] when done
    done: bool = False
    error: Optional[str] = None  # set when the request is ejected as failed
    retries: int = 0  # failed waves this request has been part of
    isolate: bool = False  # re-serve alone (failure isolation)


@dataclasses.dataclass
class GraphEngineConfig:
    max_batch_graphs: int = 16
    max_batch_nodes: int = 4096
    tile: int = 64
    cap: int = 64  # fixed per-tile entry capacity (static shapes across plans)
    node_buckets: tuple[int, ...] = (256, 512, 1024, 2048, 4096)
    cache_entries: int = 256
    cache_bytes: int = 256 << 20
    completed_history: int = 1024  # recent requests kept for inspection
    max_retries: int = 1  # failed waves a request survives before ejection

    def __post_init__(self):
        for field in ("max_batch_graphs", "max_batch_nodes", "tile", "cap"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be positive")
        if self.completed_history < 0:
            raise ValueError("completed_history must be >= 0")
        if self.node_buckets and self.max_batch_nodes > max(self.node_buckets):
            # batches admitted past the ladder would each get a bespoke pad
            # size — unbounded jit recompiles, the thing buckets exist to stop
            raise ValueError(
                f"max_batch_nodes={self.max_batch_nodes} exceeds the largest "
                f"node bucket ({max(self.node_buckets)}); extend node_buckets "
                f"(or set node_buckets=() for power-of-two padding)"
            )


# ---------------------------------------------------------------------------
# composite assembly from per-graph plans
# ---------------------------------------------------------------------------
def _bucket_nodes(n: int, buckets: tuple[int, ...], tile: int) -> int:
    """Smallest bucket >= n; past the ladder (an oversized single request —
    _next_batch always admits the head), round up to the next power of two
    so distinct jit shapes stay logarithmic in graph size rather than one
    per request."""
    for b in sorted(buckets):
        if b >= n:
            return -(-b // tile) * tile
    p = 1
    while p < n:
        p *= 2
    return -(-p // tile) * tile


def _empty_tile_arrays(cap: int) -> dict:
    return {
        "tile_row": np.zeros(0, np.int32),
        "tile_col": np.zeros(0, np.int32),
        "rows": np.zeros((0, cap), np.int32),
        "cols": np.zeros((0, cap), np.int32),
        "vals": np.zeros((0, cap), np.float32),
        "nnz_in_tile": np.zeros(0, np.int32),
    }


def _pad_tile_arrays(
    arrays: dict, row_fill: np.ndarray, col_fill: Optional[np.ndarray], cap: int
) -> dict:
    """Append zero-nnz tiles at the given (row, col) coordinates."""
    n_pad = int(row_fill.shape[0])
    if n_pad == 0:
        return arrays
    if col_fill is None:
        col_fill = np.zeros(n_pad, np.int32)
    return {
        "tile_row": np.concatenate([arrays["tile_row"], row_fill.astype(np.int32)]),
        "tile_col": np.concatenate([arrays["tile_col"], col_fill.astype(np.int32)]),
        "rows": np.concatenate([arrays["rows"], np.zeros((n_pad, cap), np.int32)]),
        "cols": np.concatenate([arrays["cols"], np.zeros((n_pad, cap), np.int32)]),
        "vals": np.concatenate([arrays["vals"], np.zeros((n_pad, cap), np.float32)]),
        "nnz_in_tile": np.concatenate(
            [arrays["nnz_in_tile"], np.zeros(n_pad, np.int32)]
        ),
    }


def assemble_batched_graph(
    plans: list[Graph], tile: int, pad_nodes: int
) -> BatchedGraph:
    """Fuse prepared per-graph plans into one block-diagonal plan.

    Each member plan already tiles its (tile-padded) own grid, so the
    composite is index arithmetic: member i's tile coordinates shift by
    ``starts[i] // tile`` and its COO rows/cols by ``starts[i]``.  Member
    coverage dummies stay valid (each composite block-row belongs to
    exactly one member, so PS block-row contiguity is preserved), and the
    bucket-padding rows at the tail get fresh zero-nnz coverage tiles so
    the Pallas kernel defines the whole output.
    """
    T = tile
    k = len(plans)
    caps = {g.tiles.cap for g in plans}
    if len(caps) > 1:
        raise ValueError(f"member plans disagree on cap: {sorted(caps)}")
    orders = {g.tiles.order for g in plans}
    if len(orders) > 1:
        raise ValueError(f"member plans disagree on order: {sorted(orders)}")
    cap = caps.pop() if caps else 8

    starts = np.zeros(k + 1, np.int64)
    for i, g in enumerate(plans):
        if g.tiles.tile != T:
            raise ValueError(f"member plan tiled at {g.tiles.tile}, engine at {T}")
        starts[i + 1] = starts[i] + -(-g.n_nodes // T) * T
    n_aligned = int(starts[-1])
    pad_nodes = -(-max(pad_nodes, n_aligned) // T) * T
    blk_off = starts // T

    # --- composite COO (device edge arrays, used by GAT attention) ---
    rows = np.concatenate(
        [np.asarray(g.rows, np.int64) + starts[i] for i, g in enumerate(plans)]
    ).astype(np.int32) if k else np.zeros(0, np.int32)
    cols = np.concatenate(
        [np.asarray(g.cols, np.int64) + starts[i] for i, g in enumerate(plans)]
    ).astype(np.int32) if k else np.zeros(0, np.int32)
    vals = np.concatenate(
        [np.asarray(g.vals) for g in plans]
    ) if k else np.zeros(0, np.float32)

    # --- composite device tile arrays (coverage dummies included) ---
    arrays = _empty_tile_arrays(cap)
    if k:
        for key in arrays:
            parts = []
            for i, g in enumerate(plans):
                a = np.asarray(g.tile_arrays[key])
                if key in ("tile_row", "tile_col"):
                    a = (a.astype(np.int64) + blk_off[i]).astype(np.int32)
                parts.append(a)
            arrays[key] = np.concatenate(parts)

    # fresh coverage for the bucket-padding block-rows at the tail: the
    # Pallas kernel zero-defines a PS strip only when it visits its row
    arrays = _pad_tile_arrays(
        arrays,
        row_fill=np.arange(n_aligned // T, pad_nodes // T, dtype=np.int32),
        col_fill=None,
        cap=cap,
    )

    # --- tile-count bucket: pad nt to the next power of two so jit sees a
    # bounded set of array shapes across batch compositions.  Padding tiles
    # carry nnz == 0 and repeat the *last* tile's coordinates: the Pallas
    # kernel then revisits an already-initialized PS strip (no re-zeroing —
    # appending a fresh block-row here would wipe real output), and the jnp
    # reference masks them via nnz_in_tile.
    nt = int(arrays["tile_row"].shape[0])
    nt_bucket = 8
    while nt_bucket < nt:
        nt_bucket *= 2
    if nt:
        padn = nt_bucket - nt
        arrays = _pad_tile_arrays(
            arrays,
            row_fill=np.full(padn, arrays["tile_row"][-1], np.int32),
            col_fill=np.full(padn, arrays["tile_col"][-1], np.int32),
            cap=cap,
        )

    # --- composite perm (edge -> tile-slot map, for GAT re-weighting) ---
    entry_off = np.zeros(k + 1, np.int64)
    for i, g in enumerate(plans):
        entry_off[i + 1] = entry_off[i] + int(np.asarray(g.rows).shape[0])
    perm_parts = []
    for i, g in enumerate(plans):
        p = np.asarray(g.perm)
        perm_parts.append(np.where(p >= 0, p + entry_off[i], -1))
    nt_cov = arrays["tile_row"].shape[0]
    perm = np.full((nt_cov, cap), -1, np.int64)
    if perm_parts:
        stacked = np.concatenate(perm_parts)
        perm[: stacked.shape[0]] = stacked

    # --- composite SCVTiles: METADATA ONLY (tile / cap / shape / order).
    # The forward path always routes through Graph.tile_arrays (_agg passes
    # arrays=), so duplicating the entry arrays here would only double
    # assembly cost and the bytes charged against the cache budget.
    meta = _empty_tile_arrays(cap)
    tiles = SCVTiles(
        tile_row=meta["tile_row"],
        tile_col=meta["tile_col"],
        rows=meta["rows"],
        cols=meta["cols"],
        vals=meta["vals"],
        nnz_in_tile=meta["nnz_in_tile"],
        tile=T,
        cap=cap,
        shape=(pad_nodes, pad_nodes),
        order=orders.pop() if orders else "zmorton",
        perm=None,
    )

    graph = Graph(
        n_nodes=pad_nodes,
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        vals=jnp.asarray(vals),
        tiles=tiles,
        tile_arrays={kk: jnp.asarray(v) for kk, v in arrays.items()},
        perm=jnp.asarray(perm),
    )
    return BatchedGraph(
        graph=graph,
        node_offsets=starts,
        node_counts=np.array([g.n_nodes for g in plans], np.int64),
        n_real_nodes=int(sum(g.n_nodes for g in plans)),
    )


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class GraphServeEngine:
    """Drives GNN models over batches of graph requests.

    ``models`` maps a model name to ``(params, GNNConfig)``; requests pick
    a model by name and are batched per model kind (mixed kinds cannot
    share a forward).
    """

    def __init__(
        self,
        models: dict[str, tuple],
        cfg: Optional[GraphEngineConfig] = None,
    ):
        self.models = models
        self.cfg = cfg = cfg if cfg is not None else GraphEngineConfig()
        self.plan_cache = PlanCache(
            max_entries=cfg.cache_entries, max_bytes=cfg.cache_bytes
        )
        self.queue: list[GraphRequest] = []
        # bounded: a serving process runs forever; retaining every request
        # (adjacency + features + outputs) would grow without limit
        self.completed: deque[GraphRequest] = deque(maxlen=cfg.completed_history)
        self.failed: deque[GraphRequest] = deque(maxlen=cfg.completed_history)
        self.n_completed = 0
        self.n_failed = 0
        self.last_completed: list[GraphRequest] = []  # from the latest run()
        self.n_batches = 0  # == forward launches (one per batch)
        self.serve_seconds = 0.0

    def submit(self, req: GraphRequest) -> None:
        if req.model not in self.models:
            raise KeyError(f"unknown model {req.model!r}; have {list(self.models)}")
        if req.adj.shape[0] != req.adj.shape[1]:
            raise ValueError(f"adjacency must be square, got {req.adj.shape}")
        if req.x.shape[0] != req.adj.shape[0]:
            raise ValueError(
                f"features rows {req.x.shape[0]} != nodes {req.adj.shape[0]}"
            )
        # reject malformed width here: inside run() it would crash mid-wave
        # and take the co-batched requests down with it
        _, mcfg = self.models[req.model]
        if req.x.ndim != 2 or req.x.shape[1] != mcfg.d_in:
            raise ValueError(
                f"features shape {req.x.shape} incompatible with model "
                f"{req.model!r} (d_in={mcfg.d_in})"
            )
        # out-of-range indices would shift into a NEIGHBOR's block of the
        # composite and silently corrupt co-batched outputs
        n = req.adj.shape[0]
        if req.adj.nnz and not (
            0 <= int(req.adj.rows.min())
            and int(req.adj.rows.max()) < n
            and 0 <= int(req.adj.cols.min())
            and int(req.adj.cols.max()) < n
        ):
            raise ValueError(f"adjacency indices out of range for shape {req.adj.shape}")
        self.queue.append(req)

    # -- batching ----------------------------------------------------------
    def _next_batch(self) -> list[GraphRequest]:
        """Greedy in-arrival-order pack: same model kind, bounded graph and
        node counts.  Always admits at least one request.

        The node budget counts each member's *tile-aligned* footprint — the
        size it actually occupies in the composite — so the total stays
        within the bucket ladder and never falls through to per-batch jit
        shapes."""
        T = self.cfg.tile
        head = self.queue[0]
        if head.isolate:  # failure isolation: re-serve a failed request alone
            self.queue = self.queue[1:]
            return [head]
        batch, nodes = [], 0
        remaining = []
        for r in self.queue:
            fits = (
                not r.isolate
                and r.model == head.model
                and len(batch) < self.cfg.max_batch_graphs
            )
            if fits:
                aligned = -(-r.adj.shape[0] // T) * T
                fits = not batch or nodes + aligned <= self.cfg.max_batch_nodes
            if fits:
                batch.append(r)
                nodes += aligned
            else:
                remaining.append(r)
        self.queue = remaining
        return batch

    # -- plans -------------------------------------------------------------
    def _batch_plan(self, batch: list[GraphRequest]) -> BatchedGraph:
        """Composite plan for a batch.  The composite key is derived from
        content hashes alone, so a hot batch is resolved before any member
        plan is touched — member plans are fetched/built only on a
        composite miss (inside the builder)."""
        T, cap = self.cfg.tile, self.cfg.cap
        member_keys = [coo_content_key(r.adj, tile=T, cap=cap) for r in batch]
        aligned = sum(-(-r.adj.shape[0] // T) * T for r in batch)
        bucket = _bucket_nodes(aligned, self.cfg.node_buckets, T)
        ckey = combine_keys(member_keys, salt=f"batch;bucket={bucket};tile={T};")

        def build() -> BatchedGraph:
            plans = [
                self.plan_cache.get_or_build(
                    k, lambda r=r: build_graph(r.adj, tile=T, backend_cap=cap)
                )
                for k, r in zip(member_keys, batch)
            ]
            return assemble_batched_graph(plans, T, bucket)

        return self.plan_cache.get_or_build(ckey, build)

    # -- serving -----------------------------------------------------------
    def run(self) -> list[GraphRequest]:
        """Serve every queued request; returns the newly completed ones.

        A wave that raises re-raises out of run() with its requests either
        requeued (isolated, up to ``max_retries``) or ejected to
        ``self.failed`` — a caller that catches the error and calls run()
        again always makes progress and eventually drains the queue.
        Requests completed before the failing wave are in
        ``self.last_completed`` (and ``self.completed``).  Interrupts
        (BaseExceptions that are not Exceptions, e.g. KeyboardInterrupt)
        restore the wave untouched: they are not request failures and
        consume no retries."""
        t0 = time.perf_counter()
        done = self.last_completed = []
        while self.queue:
            batch = self._next_batch()
            try:
                bg = self._batch_plan(batch)
                params, mcfg = self.models[batch[0].model]
                outs = gnn_forward_batched(params, mcfg, bg, [r.x for r in batch])
            except BaseException as e:
                if not isinstance(e, Exception):
                    self.queue = batch + self.queue
                    self.serve_seconds += time.perf_counter() - t0
                    raise
                # A failed wave must not lose its requests — but blind
                # requeueing would wedge the engine on a poison request.
                # Surviving members go back isolated (served alone next
                # run, so one bad member cannot keep failing a whole
                # wave); a request that exhausts max_retries is ejected
                # to ``failed`` with the error recorded.
                survivors = []
                for r in batch:
                    r.retries += 1
                    if r.retries > self.cfg.max_retries:
                        r.error = f"{type(e).__name__}: {e}"
                        self.failed.append(r)
                        self.n_failed += 1
                    else:
                        r.isolate = True
                        survivors.append(r)
                self.queue = survivors + self.queue
                self.serve_seconds += time.perf_counter() - t0
                raise
            self.n_batches += 1
            for r, o in zip(batch, outs):
                r.out = o
                r.done = True
                self.completed.append(r)
                self.n_completed += 1
                done.append(r)
        self.serve_seconds += time.perf_counter() - t0
        return done

    def metrics(self) -> dict:
        s = self.plan_cache.stats
        return {
            "batches": self.n_batches,
            "launches": self.n_batches,  # one forward launch per batch
            "completed": self.n_completed,
            "failed": self.n_failed,
            "serve_seconds": self.serve_seconds,
            "plan_cache_hits": s.hits,
            "plan_cache_misses": s.misses,
            "plan_cache_evictions": s.evictions,
            "plan_cache_bytes": s.bytes_in_use,
            "plan_cache_entries": s.entries,
            "plan_cache_hit_rate": s.hit_rate,
            "plan_build_seconds": s.build_seconds,
        }
