"""Batched multi-graph SCV inference engine.

The GNN analogue of ``serve/engine.py``'s LM loop: requests carry a whole
graph (adjacency + node features + model kind) instead of a prompt, and a
"batch" is many small graphs fused into one block-diagonal adjacency so the
entire wave runs as **one** SCV aggregation launch per layer.

Three mechanisms make this a serving system rather than a loop:

1. **Plan cache** (``plan_cache.py``) — the §III-C host-side SCV build is
   content-addressed and LRU-cached (with an optional TTL) at two levels:
   per-graph ``Graph`` bundles (hot graphs skip preprocessing) and
   assembled composite batches (hot *batches* skip even the
   concatenation).

2. **Composite assembly from cached plans** — because every member plan is
   padded to the tile grid, a batch plan is pure index arithmetic over the
   members' ``SCVPlan`` pytrees: member tile coordinates are shifted by
   the member's block offset and the plan leaves concatenated (vectorized
   numpy — no Python loop over tiles).  No re-tiling, no re-sorting, no
   COO scan.  The block-diagonal structure guarantees the result equals
   per-graph aggregation stacked (``core.formats.block_diag_coo`` is the
   reference construction; ``tests/test_serve_graph.py`` checks both
   agree).  The composite COO edge arrays + perm are built only when the
   batch's model kind needs them (GAT) — which puts the model-kind
   component into the composite cache key (see ``_batch_plan``).

3. **Padding buckets** — composite node counts are rounded up to a fixed
   bucket ladder, so XLA sees a handful of distinct shapes instead of one
   per batch and jit recompilation is bounded.  A wave then runs through
   the end-to-end jitted ``gnn_forward`` over the composite plan pytree —
   a cache hit hands jit a ready device pytree and the whole multi-layer
   forward is one XLA program.

4. **Multi-device routing** — composites whose padded node count or total
   nnz exceed the ``GraphEngineConfig`` thresholds are placed by a
   ``core.exec.PlanExecutor`` (tile-span / feature-axis / 2-D sharding
   from workload numbers and the device pool) and execute through the
   same jitted forward — a ``ShardedPlan`` is just another plan kind.
   The sharding decision is part of the composite cache key, so hot
   oversized batches reuse their sharded layout.

The engine is single-host-process (like ``ServeEngine``); the launch/
layer owns process fan-out.  Intake is owned by ``serve/scheduler.py``:
the synchronous ``run()`` drains it in degenerate single-consumer waves,
while ``start()`` hands it to the continuous-batching scheduler loop
(mid-flight wave coalescing, deadline-aware admission, serialized
``update()`` control messages) — see the scheduler module docstring and
serve/README.md "Async serving".
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.exec import ShardedPlan
from repro.core.formats import COOMatrix
from repro.core.scv import (
    DEFAULT_CAP,
    DEFAULT_LADDER,
    DEFAULT_TILE,
    SCVBucketedPlan,
    SCVPlan,
)
from repro.core.validate import check_coo, validate_plan
from repro.models.gnn import (
    BatchedGraph,
    GNNConfig,
    Graph,
    batch_features,
    build_graph,
    gnn_forward_jit,
    split_outputs,
)
from repro.serve.plan_cache import PlanCache, combine_keys, coo_content_key
from repro.serve.scheduler import (
    AdmissionRejected,
    EngineOverloaded,
    Scheduler,
    _Control,
)
from repro.stream import DeltaBatch, apply_coo, apply_delta, check_delta
from repro.tune.config import TunedConfig

__all__ = [
    "AdmissionRejected",
    "EngineOverloaded",
    "GraphEngineConfig",
    "GraphRequest",
    "GraphServeEngine",
    "assemble_batched_graph",
    "plan_launches",
]


@dataclasses.dataclass
class GraphRequest:
    """One inference request: run ``model`` over (adj, x).

    ``adj`` may be omitted when ``graph_id`` names a graph the engine
    already tracks (registered by an earlier request that carried both) —
    the wave then serves the tracked graph's *current* adjacency, i.e.
    the state after every ``update()`` applied so far.
    """

    rid: int
    adj: Optional[COOMatrix] = None  # normalized adjacency (e.g. gcn_normalize)
    x: Optional[np.ndarray] = None  # f32[n_nodes, d_in]
    model: str = "default"
    # stable identity for delta-tracked graphs: requests carrying a
    # graph_id (re)register the adjacency under it, and later requests may
    # omit adj to serve the tracked (delta-updated) state
    graph_id: Optional[str] = None
    # latency budget in seconds, relative to submit time.  Admission
    # control rejects the request up front when the deadline is infeasible
    # at the current queue depth, and wave formation sheds it if the
    # estimate later degrades past the budget.  None = serve whenever.
    deadline_s: Optional[float] = None
    out: Optional[np.ndarray] = None  # f32[n_nodes, n_classes] when done
    done: bool = False
    error: Optional[str] = None  # set when ejected as failed or shed
    retries: int = 0  # failed waves this request has been part of
    isolate: bool = False  # re-serve alone (failure isolation)
    t_submit: float = 0.0  # time.monotonic() at admission
    t_done: float = 0.0  # time.monotonic() at completion
    # set on every terminal transition (completed / failed / shed) —
    # async callers block on it via result()
    event: Optional[threading.Event] = None

    @property
    def latency_s(self) -> Optional[float]:
        return self.t_done - self.t_submit if self.done else None

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until this request reaches a terminal state; returns the
        output or raises ``RuntimeError`` with the failure/shed reason."""
        if self.event is not None and not self.event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done after {timeout}s")
        if self.error is not None:
            raise RuntimeError(f"request {self.rid}: {self.error}")
        if not self.done:
            raise RuntimeError(f"request {self.rid} is not done")
        return self.out


@dataclasses.dataclass
class GraphEngineConfig:
    max_batch_graphs: int = 16
    max_batch_nodes: int = 4096
    tile: int = DEFAULT_TILE
    cap: int = DEFAULT_CAP  # per-tile entry capacity when bucket_caps is off
    # nnz-bucketed plans: a fixed ascending capacity ladder shared by every
    # member plan (so composites fuse segment-by-segment and jit traces are
    # shared across batches).  ON by default — the serve_bench A/B
    # (BENCH_serve.json) gates bucketed >= single-cap throughput AND the
    # default ladder >= the measured ladder-depth winner.  With
    # accumulator-chained launches coverage dummies exist once per plan,
    # so ladder depth no longer pays a per-segment dummy set — the
    # remaining depth cost is one launch (one jnp pass on the serving
    # backend) per extra bucket; the 3-deep ladder won the interleaved
    # sweep on the sparse serving pool (ladder_ab in BENCH_serve.json;
    # 2/4-deep within ~5%).  Empty tuple selects the legacy single-cap plans
    # (``cap``); when the ladder is set it supersedes ``cap`` (heavy
    # tiles chain-split at ``bucket_caps[-1]``).
    bucket_caps: tuple[int, ...] = DEFAULT_LADDER
    # autotuned per-regime plan configuration (repro.tune): when on, each
    # distinct graph regime (quantized tile-nnz histogram x machine
    # fingerprint) resolves its own (tile, ladder) via the Autotuner
    # instead of the tile/cap/bucket_caps literals above, which then only
    # serve as the fallback for empty graphs.  Batches group by resolved
    # config (composite members must share tile and ladder), member and
    # composite cache keys carry the resolved layout, and ``metrics()``
    # reports every resolved config.  Resolution on a store hit costs one
    # O(nnz) histogram per request per wave; a miss runs the stage-1
    # simulator sweep (plus measured calibration when
    # ``autotune_calibrate`` is set — leave that to offline benches).
    autotune: bool = False
    autotune_store: Optional[str] = None  # TuneStore path (None = in-memory)
    autotune_calibrate: bool = False
    node_buckets: tuple[int, ...] = (256, 512, 1024, 2048, 4096)
    cache_entries: int = 256
    cache_bytes: int = 256 << 20
    plan_ttl_s: Optional[float] = None  # expire cached plans after this age
    completed_history: int = 1024  # recent requests kept for inspection
    max_retries: int = 1  # failed waves a request survives before ejection
    # multi-device routing (core.exec.PlanExecutor): a composite whose
    # padded node count exceeds shard_nodes_threshold OR whose total nnz
    # exceeds shard_nnz_threshold executes on the executor's sharded path.
    # None disables the corresponding trigger; both None = single-device
    # engine even when an executor is attached.
    shard_nodes_threshold: Optional[int] = None
    shard_nnz_threshold: Optional[int] = None
    # periodic re-anchoring of delta-tracked graphs: every N updates the
    # tracked entry is re-homed from its delta-chained lineage key to the
    # coo_content_key of the *current* adjacency (PlanCache.anchor), so an
    # untracked client submitting the same post-delta graph hits instead
    # of building a duplicate entry.  0 disables.
    anchor_every: int = 16
    # debug mode: run the full core.validate invariant chain on every
    # freshly *built* composite (cache hits were validated when built).
    # A malformed composite then fails loudly at the admission boundary
    # with a named invariant instead of producing wrong aggregations.
    # Costs a host-side pass over the plan leaves — leave off in
    # production, turn on when bisecting plan corruption.
    debug_validate: bool = False
    # --- async scheduler (serve/scheduler.py) ---------------------------
    # a forming wave absorbs compatible arrivals until it holds
    # target_wave_size graphs (None = max_batch_graphs) or this many
    # milliseconds have passed since its first member arrived; 0 disables
    # the absorb window (waves snapshot like the sync path)
    max_wave_delay_ms: float = 2.0
    target_wave_size: Optional[int] = None
    # bounded intake: submit() blocks (or raises EngineOverloaded with
    # block=False) when this many requests are queued — backpressure
    # instead of unbounded memory growth under overload
    intake_capacity: int = 4096
    # completed-request latencies retained for the metrics() percentiles
    latency_window: int = 4096
    # smoothing for the per-model wave service-time EMA that admission
    # control and deadline shedding estimate from
    service_ema_alpha: float = 0.2

    def __post_init__(self):
        for field in ("max_batch_graphs", "max_batch_nodes", "tile", "cap"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be positive")
        if self.bucket_caps:
            caps = tuple(int(c) for c in self.bucket_caps)
            if list(caps) != sorted(set(caps)) or caps[0] <= 0:
                raise ValueError(
                    f"bucket_caps must be ascending distinct positives, got {caps}"
                )
        for field in ("shard_nodes_threshold", "shard_nnz_threshold"):
            v = getattr(self, field)
            if v is not None and v <= 0:
                raise ValueError(f"{field} must be positive (or None)")
        if self.anchor_every < 0:
            raise ValueError("anchor_every must be >= 0 (0 disables)")
        if self.completed_history < 0:
            raise ValueError("completed_history must be >= 0")
        if self.node_buckets and self.max_batch_nodes > max(self.node_buckets):
            # batches admitted past the ladder would each get a bespoke pad
            # size — unbounded jit recompiles, the thing buckets exist to stop
            raise ValueError(
                f"max_batch_nodes={self.max_batch_nodes} exceeds the largest "
                f"node bucket ({max(self.node_buckets)}); extend node_buckets "
                f"(or set node_buckets=() for power-of-two padding)"
            )


# ---------------------------------------------------------------------------
# composite assembly from per-graph plans
# ---------------------------------------------------------------------------
def _bucket_nodes(n: int, buckets: tuple[int, ...], tile: int) -> int:
    """Smallest bucket >= n; past the ladder (an oversized single request —
    wave formation always admits the head), round up to the next power of two
    so distinct jit shapes stay logarithmic in graph size rather than one
    per request."""
    for b in sorted(buckets):
        if b >= n:
            return -(-b // tile) * tile
    p = 1
    while p < n:
        p *= 2
    return -(-p // tile) * tile


def _cat(parts, pad_blocks, dtype):
    # convert per block BEFORE concatenating: mixing int32 members with
    # default-float64 pads would promote the whole composite to f64
    blocks = [np.asarray(p, dtype) for p in parts]
    blocks += [np.asarray(b, dtype) for b in pad_blocks]
    return np.concatenate(blocks) if blocks else np.zeros(0, dtype)


def _assemble_segment(
    segs: list[SCVPlan],
    blk_off: np.ndarray,
    n_aligned: int,
    pad_nodes: int,
    T: int,
    cap: int,
    order: str,
    entry_off: Optional[np.ndarray],
    first_segment: bool = True,
) -> SCVPlan:
    """Fuse one capacity segment across members into the composite segment.

    Member tile coordinates shift by the member's block offset; then two
    pad blocks follow: fresh zero-nnz coverage tiles for the bucket-padding
    block-rows at the tail — only in the *first* segment (its launch
    zero-defines the whole output; later launches chain through the
    aliased accumulator, so member plans and composites alike carry
    coverage once per plan) — then tile-count padding up to the next
    power of two so jit sees a bounded set of array shapes.  The
    tile-count padding repeats the *last* tile's coordinates: the kernel
    then revisits an already-initialized PS strip (no re-zeroing —
    appending a fresh block-row would wipe real output), and the jnp
    reference masks the zero-nnz slots via nnz_in_tile.

    ``entry_off`` (per-member edge-array offsets) enables the composite
    perm: member perm entries shift into the concatenated edge space,
    ``-1`` padding slots stay ``-1``.
    """
    k = len(segs)
    nts = np.array([s.n_tiles for s in segs], np.int64)
    nt_members = int(nts.sum())
    # fresh tail coverage tiles (first segment only)
    n_cov = pad_nodes // T - n_aligned // T if first_segment else 0
    nt = nt_members + n_cov
    nt_bucket = 8
    while nt_bucket < nt:
        nt_bucket *= 2
    # repeat-last-coordinate padding tiles (an empty composite stays empty)
    n_fill = nt_bucket - nt if nt else 0

    shift = np.repeat(blk_off[:k], nts)  # per-tile block-diagonal offset
    cov_rows = np.arange(n_aligned // T, pad_nodes // T, dtype=np.int64)[:n_cov]
    tile_row = _cat([s.tile_row for s in segs], [cov_rows], np.int64)
    tile_row[:nt_members] += shift
    tile_col = _cat(
        [s.tile_col for s in segs], [np.zeros(n_cov, np.int64)], np.int64
    )
    tile_col[:nt_members] += shift
    last_r = tile_row[nt - 1] if nt else 0
    last_c = tile_col[nt - 1] if nt else 0
    tile_row = np.concatenate([tile_row, np.full(n_fill, last_r)]).astype(np.int32)
    tile_col = np.concatenate([tile_col, np.full(n_fill, last_c)]).astype(np.int32)

    n_pad = n_cov + n_fill
    rows2 = _cat([s.rows for s in segs], [np.zeros((n_pad, cap))], np.int32)
    cols2 = _cat([s.cols for s in segs], [np.zeros((n_pad, cap))], np.int32)
    vals2 = _cat([s.vals for s in segs], [np.zeros((n_pad, cap))], np.float32)
    nnz2 = _cat([s.nnz_in_tile for s in segs], [np.zeros(n_pad)], np.int32)

    perm_j = None
    if entry_off is not None:
        perm = np.full((nt + n_fill, cap), -1, np.int32)
        if k:
            pstack = np.concatenate([np.asarray(s.perm, np.int64) for s in segs])
            poff = np.repeat(entry_off[:k], nts)[:, None]
            perm[:nt_members] = np.where(
                pstack >= 0, pstack + poff, -1
            ).astype(np.int32)
        perm_j = jnp.asarray(perm)

    return SCVPlan(
        tile_row=jnp.asarray(tile_row),
        tile_col=jnp.asarray(tile_col),
        rows=jnp.asarray(rows2),
        cols=jnp.asarray(cols2),
        vals=jnp.asarray(vals2),
        nnz_in_tile=jnp.asarray(nnz2),
        perm=perm_j,
        tile=T,
        cap=cap,
        shape=(pad_nodes, pad_nodes),
        order=order,
    )


def assemble_batched_graph(
    plans: list[Graph], tile: int, pad_nodes: int, with_edges: bool = True
) -> BatchedGraph:
    """Fuse prepared per-graph plans into one block-diagonal plan.

    Each member plan already tiles its (tile-padded) own grid, so the
    composite is index arithmetic over the members' plan pytrees: member
    i's tile coordinates shift by ``starts[i] // tile`` and its COO
    rows/cols by ``starts[i]`` — all of it vectorized numpy (concatenate +
    broadcast adds), no per-tile Python loop.  Member coverage dummies
    stay valid (each composite block-row belongs to exactly one member, so
    PS block-row contiguity is preserved), and the bucket-padding rows at
    the tail get fresh zero-nnz coverage tiles so the Pallas kernel
    defines the whole output.

    Members carrying nnz-bucketed ``SCVBucketedPlan``s (all on the same
    capacity ladder) compose segment-by-segment — segment j of the
    composite is the fusion of every member's segment j — and the result
    is itself an ``SCVBucketedPlan``; single-cap members compose to a
    single ``SCVPlan`` exactly as before.

    ``with_edges`` controls the composite COO edge arrays + perm: only
    GAT's attention reads them, so non-GAT batches skip both the assembly
    cost and the cache bytes — at the price of a model-kind component in
    the composite cache key (the engine salts it; see ``_batch_plan``).
    """
    T = tile
    k = len(plans)
    bucketed = any(isinstance(g.plan, SCVBucketedPlan) for g in plans)
    if bucketed:
        ladders = {g.plan.caps if isinstance(g.plan, SCVBucketedPlan) else (g.plan.cap,)
                   for g in plans}
        if len(ladders) > 1:
            raise ValueError(
                f"member plans disagree on bucket ladder: {sorted(ladders)}"
            )
        ladder = ladders.pop()
    else:
        caps = {g.plan.cap for g in plans}
        if len(caps) > 1:
            raise ValueError(f"member plans disagree on cap: {sorted(caps)}")
        ladder = (caps.pop() if caps else 8,)
    orders = {g.plan.order for g in plans}
    if len(orders) > 1:
        raise ValueError(f"member plans disagree on order: {sorted(orders)}")
    order = orders.pop() if orders else "zmorton"

    starts = np.zeros(k + 1, np.int64)
    for i, g in enumerate(plans):
        if g.plan.tile != T:
            raise ValueError(f"member plan tiled at {g.plan.tile}, engine at {T}")
        starts[i + 1] = starts[i] + -(-g.n_nodes // T) * T
    n_aligned = int(starts[-1])
    pad_nodes = -(-max(pad_nodes, n_aligned) // T) * T
    blk_off = starts // T

    # --- composite COO edge arrays (GAT re-weighting only) ---
    entry_off = None
    erows = ecols = evals = None
    if with_edges:
        for g in plans:
            if g.rows is None or g.plan.perm is None:
                raise ValueError(
                    "with_edges=True needs member plans built with edges/perm"
                )
        edge_counts = np.array(
            [int(np.asarray(g.rows).shape[0]) for g in plans], np.int64
        )
        entry_off = np.concatenate([[0], np.cumsum(edge_counts)])
        if entry_off[-1] >= 2**31:  # composite perm is i32
            raise ValueError(
                f"composite entry count {entry_off[-1]} overflows the "
                "int32 perm leaf"
            )
        rows = _cat([g.rows for g in plans], [], np.int64)
        cols = _cat([g.cols for g in plans], [], np.int64)
        eshift = np.repeat(starts[:k], edge_counts)
        erows = jnp.asarray((rows + eshift).astype(np.int32))
        ecols = jnp.asarray((cols + eshift).astype(np.int32))
        evals = jnp.asarray(_cat([g.vals for g in plans], [], np.float32))

    def member_segments(g: Graph) -> tuple[SCVPlan, ...]:
        return g.plan.segments if isinstance(g.plan, SCVBucketedPlan) else (g.plan,)

    composed = [
        _assemble_segment(
            [member_segments(g)[j] for g in plans],
            blk_off, n_aligned, pad_nodes, T, cap, order, entry_off,
            first_segment=(j == 0),
        )
        for j, cap in enumerate(ladder)
    ]
    plan = SCVBucketedPlan(tuple(composed)) if bucketed else composed[0]
    graph = Graph(
        n_nodes=pad_nodes, plan=plan, rows=erows, cols=ecols, vals=evals
    )
    return BatchedGraph(
        graph=graph,
        node_offsets=starts,
        node_counts=np.array([g.n_nodes for g in plans], np.int64),
        n_real_nodes=int(sum(g.n_nodes for g in plans)),
    )


def plan_launches(plan) -> int:
    """Device kernel launches one aggregation over ``plan`` costs.

    A single-cap ``SCVPlan`` is one launch; a bucketed plan chains one
    launch per **non-empty** capacity segment through the aliased
    accumulator (empty segments are skipped at dispatch — see
    ``kernels/scv_spmm/ops.scv_spmm_plan``); a sharded plan runs its
    per-segment launches on every mesh instance
    (``tile_parts x feature_parts`` shard_map bodies).  The forward then
    multiplies by ``GNNConfig.n_layers`` — that factor is the caller's
    (every model kind aggregates exactly once per layer)."""
    if isinstance(plan, ShardedPlan):
        per_device = sum(
            1 for s in plan.segments if int(np.asarray(s.tile_row).size) > 0
        )
        return per_device * plan.decision.n_devices
    if isinstance(plan, SCVBucketedPlan):
        return sum(
            1 for s in plan.segments if int(np.asarray(s.tile_row).size) > 0
        )
    return 1 if int(np.asarray(plan.tile_row).size) > 0 else 0


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _TrackedGraph:
    """Current state of a delta-tracked graph: the adjacency after every
    applied delta, and the plan-cache key its plan lives under (the
    delta-chained lineage of the registration-time content key)."""

    adj: COOMatrix
    key: str
    updates_since_anchor: int = 0  # see GraphEngineConfig.anchor_every
    # resolved plan configuration (autotune): set at registration and
    # refreshed at re-anchor time — deltas between anchors may drift the
    # regime, so this is "as of last anchor", which is what metrics()
    # reports per tracked graph
    config: Optional["TunedConfig"] = None


class GraphServeEngine:
    """Drives GNN models over batches of graph requests.

    ``models`` maps a model name to ``(params, GNNConfig)``; requests pick
    a model by name and are batched per model kind (mixed kinds cannot
    share a forward).
    """

    def __init__(
        self,
        models: dict[str, tuple],
        cfg: Optional[GraphEngineConfig] = None,
        executor: Optional["PlanExecutor"] = None,
    ):
        self.models = models
        self.cfg = cfg = cfg if cfg is not None else GraphEngineConfig()
        if executor is None and (
            cfg.shard_nodes_threshold is not None
            or cfg.shard_nnz_threshold is not None
        ):
            from repro.core.exec import PlanExecutor

            executor = PlanExecutor()  # all local devices
        self.executor = executor
        self.plan_cache = PlanCache(
            max_entries=cfg.cache_entries,
            max_bytes=cfg.cache_bytes,
            max_age_s=cfg.plan_ttl_s,
        )
        # intake + wave formation live in the scheduler (the IntakeQueue is
        # the single owner of queued state — scvlint SCV007)
        self.scheduler = Scheduler(self)
        # bounded: a serving process runs forever; retaining every request
        # (adjacency + features + outputs) would grow without limit
        self.completed: deque[GraphRequest] = deque(maxlen=cfg.completed_history)
        self.failed: deque[GraphRequest] = deque(maxlen=cfg.completed_history)
        self.shed: deque[GraphRequest] = deque(maxlen=cfg.completed_history)
        self.n_completed = 0
        self.n_failed = 0
        self.n_rejected = 0  # AdmissionRejected at submit
        self.last_completed: list[GraphRequest] = []  # from the latest run()
        self.n_batches = 0  # composite waves served
        self.n_launches = 0  # actual pallas kernel launches (see plan_launches)
        self.n_sharded_batches = 0  # waves routed through the executor
        self.serve_seconds = 0.0
        # tuner resolution + resolved-config bookkeeping are shared between
        # the producer thread (submit/registration) and the wave consumer
        self._tune_lock = threading.Lock()
        # delta-tracked graphs (see update()): graph_id -> current state
        self._graphs: dict[str, _TrackedGraph] = {}
        self.n_graph_updates = 0
        # autotuned plan configuration: the engine-config literals become
        # one TunedConfig fallback; with cfg.autotune each regime resolves
        # its own through the tuner's signature-keyed store
        self._fallback_config = TunedConfig(
            tile=cfg.tile, bucket_caps=tuple(cfg.bucket_caps), cap=cfg.cap
        )
        self.tuner = None
        self._resolved_configs: dict[str, TunedConfig] = {}
        if cfg.autotune:
            from repro.tune import Autotuner, TuneStore

            self.tuner = Autotuner(
                store=TuneStore(cfg.autotune_store),
                calibrate=cfg.autotune_calibrate,
            )

    @property
    def queue(self) -> list[GraphRequest]:
        """Read-only snapshot of the queued requests.  Intake is owned by
        the scheduler's ``IntakeQueue`` (bounded, thread-safe); direct
        queue mutation in the serving layer is rejected by scvlint SCV007
        so every path goes through admission accounting."""
        return self.scheduler.queue.items()

    def _resolve_config(self, adj: COOMatrix) -> TunedConfig:
        """The plan configuration a wave uses for ``adj``: the tuner's
        per-regime resolution under ``cfg.autotune``, else the engine-
        config fallback.  Store hits cost one tile-nnz histogram.
        Serialized under ``_tune_lock``: submit-side registration and the
        wave consumer both resolve configs."""
        if self.tuner is None or adj.nnz == 0:
            return self._fallback_config
        with self._tune_lock:
            tcfg = self.tuner.tune(adj)
            self._resolved_configs[self.tuner.last_result.key] = tcfg
        return tcfg

    def _member_content_key(self, adj: COOMatrix) -> str:
        tcfg = self._resolve_config(adj)
        return coo_content_key(adj, tile=tcfg.tile, cap=tcfg.cap_signature)

    def _resolve_adj(self, req: GraphRequest) -> COOMatrix:
        """The adjacency a wave serves for ``req`` — the tracked graph's
        *current* (post-update) state when the request rides a graph_id,
        else the request's own.  Resolved at wave time, never at submit
        time, so an ``update()`` landing between submit and run is
        reflected in the served output."""
        if req.graph_id is not None:
            return self._graphs[req.graph_id].adj
        return req.adj

    def submit(
        self,
        req: GraphRequest,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> GraphRequest:
        """Validate and enqueue a request; returns it (callers block on
        ``req.result()`` in async mode).

        Admission control may raise: ``AdmissionRejected`` when the
        request carries a ``deadline_s`` that is infeasible at the current
        queue depth (per-model service-time EMA), and ``EngineOverloaded``
        when the bounded intake queue stays full (with ``block=False`` it
        fails fast; otherwise after ``timeout`` seconds — backpressure
        instead of unbounded queue growth)."""
        if req.model not in self.models:
            raise KeyError(f"unknown model {req.model!r}; have {list(self.models)}")
        if req.adj is not None:
            # admission hook (core.validate): squareness, nnz consistency,
            # negative / out-of-range indices, non-finite values.
            # Out-of-range indices would shift into a NEIGHBOR's block of
            # the composite and silently corrupt co-batched outputs.
            check_coo(req.adj, square=True)
            if req.graph_id is not None:
                # (re)register: carrying both adj and graph_id resets the
                # tracked state to this adjacency (content-keyed afresh)
                self._graphs[req.graph_id] = _TrackedGraph(
                    adj=req.adj,
                    key=self._member_content_key(req.adj),
                    config=self._resolve_config(req.adj),
                )
        elif req.graph_id is None:
            raise ValueError("request needs adj (or a tracked graph_id)")
        elif req.graph_id not in self._graphs:
            raise KeyError(
                f"unknown graph_id {req.graph_id!r}; submit once with adj= "
                "to register it"
            )
        adj = self._resolve_adj(req)
        if req.x is None:
            raise ValueError("request needs node features x")
        if req.x.shape[0] != adj.shape[0]:
            raise ValueError(
                f"features rows {req.x.shape[0]} != nodes {adj.shape[0]}"
            )
        # reject malformed width here: inside run() it would crash mid-wave
        # and take the co-batched requests down with it
        _, mcfg = self.models[req.model]
        if req.x.ndim != 2 or req.x.shape[1] != mcfg.d_in:
            raise ValueError(
                f"features shape {req.x.shape} incompatible with model "
                f"{req.model!r} (d_in={mcfg.d_in})"
            )
        req.t_submit = now = time.monotonic()
        if req.event is None:
            req.event = threading.Event()
        try:
            self.scheduler.admit(req, now)
        except AdmissionRejected:
            self.n_rejected += 1
            raise
        if not self.scheduler.queue.put(req, block=block, timeout=timeout):
            raise EngineOverloaded(
                f"intake queue full ({self.cfg.intake_capacity} requests)"
                + (f" after waiting {timeout}s" if timeout is not None else "")
            )
        return req

    def update(self, graph_id: str, delta: DeltaBatch) -> str:
        """Apply an edge delta to a tracked graph; returns its new plan key.

        With the async scheduler loop running, the delta is enqueued as a
        serialized **control message** and applied by the loop *between*
        waves — a mutation can never race a wave that is concurrently
        reading the tracked adjacency or revalidating the plan cache.
        This call blocks until the loop acknowledges, so the caller's
        happens-before is preserved: every request submitted after
        ``update()`` returns serves the post-delta graph.  Without the
        loop it applies inline (the historical synchronous behavior).

        Admission runs ``stream.check_delta`` against the tracked
        adjacency (out-of-range ids, non-finite values, removes of absent
        edges, duplicate/present inserts all rejected before any state
        changes).  The tracked adjacency advances by ``apply_coo`` and the
        plan cache **revalidates by delta**: a live cached plan is patched
        in place via ``stream.apply_delta`` and re-keyed under
        ``delta_key(old, delta)`` (counted in ``stats.revalidated``)
        instead of becoming a full rebuild miss.  Downstream composite and
        sharded cache entries are invalidated automatically: their keys
        combine the member keys, so the re-keyed member can never resolve
        a pre-delta composite — stale entries just age out of the LRU.
        """
        if self.scheduler.running:
            ctrl = _Control(apply=lambda: self._apply_update(graph_id, delta))
            self.scheduler.queue.put_control(ctrl)
            while not ctrl.done.wait(0.05):
                if not self.scheduler.running:
                    # the loop exited between enqueue and apply: drain the
                    # control inline (pop_controls is atomic, so the
                    # message is applied exactly once either way)
                    self.scheduler._apply_controls()
                    break
            if not ctrl.done.is_set():
                self.scheduler._apply_controls()
            if ctrl.error is not None:
                raise ctrl.error
            return ctrl.result
        return self._apply_update(graph_id, delta)

    def _apply_update(self, graph_id: str, delta: DeltaBatch) -> str:
        st = self._graphs.get(graph_id)
        if st is None:
            raise KeyError(
                f"unknown graph_id {graph_id!r}; submit once with adj= to "
                "register it"
            )
        check_delta(delta, coo=st.adj)
        if len(delta) == 0:
            return st.key
        st.adj = apply_coo(st.adj, delta, check=False)
        st.key = self.plan_cache.revalidate(
            st.key, delta, patch=lambda g: apply_delta(g, delta, check=False)
        )
        self.n_graph_updates += 1
        st.updates_since_anchor += 1
        if (
            self.cfg.anchor_every
            and st.updates_since_anchor >= self.cfg.anchor_every
        ):
            # re-home the lineage key to the current adjacency's content
            # key: bounds drift between tracked and content-addressed
            # clients (see PlanCache.anchor)
            st.key = self.plan_cache.anchor(
                st.key, self._member_content_key(st.adj)
            )
            st.config = self._resolve_config(st.adj)
            st.updates_since_anchor = 0
        return st.key

    def tracked_adj(self, graph_id: str) -> COOMatrix:
        """The current adjacency of a tracked graph (post any updates)."""
        st = self._graphs.get(graph_id)
        if st is None:
            raise KeyError(
                f"unknown graph_id {graph_id!r}; submit once with adj= to "
                "register it"
            )
        return st.adj

    # -- plans -------------------------------------------------------------
    def _shard_decision(self, adjs, bucket: int, mcfg):
        """Placement decision for a composite, or None for single-device.

        A composite goes multi-device when its padded node count or total
        nnz exceeds the configured thresholds.  The decision is a pure
        function of (workload numbers, executor pool), so equal batches
        always reach the same placement — which is what lets it live in
        the composite cache key."""
        if self.executor is None:
            return None
        nnz = sum(a.nnz for a in adjs)
        over = (
            self.cfg.shard_nodes_threshold is not None
            and bucket > self.cfg.shard_nodes_threshold
        ) or (
            self.cfg.shard_nnz_threshold is not None
            and nnz > self.cfg.shard_nnz_threshold
        )
        if not over:
            return None
        # the narrowest width any layer aggregates bounds useful Z-sharding
        n_feat = min(mcfg.d_in, mcfg.d_hidden, mcfg.n_classes)
        decision = self.executor.decide_for(nnz, n_feat, n_rows=bucket)
        return None if decision.kind == "replicated" else decision

    def _batch_plan(self, batch: list[GraphRequest]) -> BatchedGraph:
        """Composite plan for a batch.  The composite key is derived from
        content hashes alone, so a hot batch is resolved before any member
        plan is touched — member plans are fetched/built only on a
        composite miss (inside the builder).

        The composite COO edge arrays + perm are assembled lazily: only
        GAT reads them, so the salt carries an ``edges`` component — the
        model-*kind* (edge-needing or not), deliberately not the model
        name, so same-kind models still share composite plans.  Member
        plans always carry edges (one representation serves every kind)
        and stay kind-agnostic.

        The salt also carries the sharding decision (``shard=``): an
        over-threshold composite is cached *placed* (its plan already a
        ``ShardedPlan`` on the executor's mesh), so a hot oversized batch
        reuses its sharded layout with zero placement work — and the same
        members under a different executor/threshold config never alias.

        Delta-tracked members resolve (key, adjacency) from the tracked
        state *here*, at wave time: their member key is the delta-chained
        key ``update()`` maintains, so a post-update wave can never hit a
        pre-delta composite (the composite key combines member keys)."""
        adjs = [self._resolve_adj(r) for r in batch]
        # members were grouped by resolved config at wave formation
        # (Scheduler._pick_wave), so the head's resolution is the layout
        tcfg = self._resolve_config(adjs[0])
        T = tcfg.tile
        _, mcfg = self.models[batch[0].model]
        with_edges = mcfg.kind == "gat"
        # the capacity layout is plan aux: it belongs in both key levels
        # (a single-cap plan and a bucketed plan of the same graph are
        # different device objects)
        cap_sig = tcfg.cap_signature
        member_keys = [
            self._graphs[r.graph_id].key
            if r.graph_id is not None
            else coo_content_key(a, tile=T, cap=cap_sig)
            for r, a in zip(batch, adjs)
        ]
        aligned = sum(-(-a.shape[0] // T) * T for a in adjs)
        bucket = _bucket_nodes(aligned, self.cfg.node_buckets, T)
        decision = self._shard_decision(adjs, bucket, mcfg)
        ckey = combine_keys(
            member_keys,
            salt=f"batch;bucket={bucket};tile={T};caps={cap_sig};"
            f"edges={int(with_edges)};"
            f"shard={decision.signature if decision else 'none'};",
        )

        def build() -> BatchedGraph:
            plans = [
                self.plan_cache.get_or_build(
                    k, lambda a=a: build_graph(a, config=tcfg)
                )
                for k, a in zip(member_keys, adjs)
            ]
            bg = assemble_batched_graph(plans, T, bucket, with_edges=with_edges)
            if decision is not None:
                bg = dataclasses.replace(
                    bg,
                    graph=self.executor.prepare_graph(
                        bg.graph, decision=decision
                    ),
                )
            if self.cfg.debug_validate:
                validate_plan(bg).raise_if_failed()
            return bg

        return self.plan_cache.get_or_build(ckey, build)

    # -- serving -----------------------------------------------------------
    def run(self) -> list[GraphRequest]:
        """Serve every queued request synchronously; returns the newly
        completed ones.  The degenerate single-consumer case of the
        scheduler (waves form with no absorb window — exactly the
        historical snapshot loop).

        A wave that raises re-raises out of run() with its requests either
        requeued (isolated, up to ``max_retries``) or ejected to
        ``self.failed`` — a caller that catches the error and calls run()
        again always makes progress and eventually drains the queue.
        Requests completed before the failing wave are in
        ``self.last_completed`` (and ``self.completed``).  Interrupts
        (BaseExceptions that are not Exceptions, e.g. KeyboardInterrupt)
        restore the wave untouched: they are not request failures and
        consume no retries."""
        if self.scheduler.running:
            raise RuntimeError(
                "the async scheduler loop is running; use wait_idle() to "
                "block on completion or stop() before sync run()"
            )
        return self.scheduler.drain()

    def _dispatch_wave(self, wave: list[GraphRequest]):
        """Assemble a wave's composite and launch the jitted forward;
        returns ``(bg, out)`` with ``out`` **unmaterialized** — jax async
        dispatch returns once the work is enqueued, so the scheduler can
        overlap host-side assembly of the next wave (plan-cache lookups,
        composite concatenation) with this wave's device time."""
        bg = self._batch_plan(wave)
        params, mcfg = self.models[wave[0].model]
        out = gnn_forward_jit(
            params, mcfg, bg.graph, batch_features(bg, [r.x for r in wave])
        )
        return bg, out

    def _finish_wave(self, wave, bg, out) -> list[GraphRequest]:
        """Materialize a dispatched wave's outputs (blocks on the device),
        complete its requests, and account the wave."""
        outs = split_outputs(bg, out)  # np.asarray: the device sync point
        self.n_batches += 1
        if isinstance(bg.graph.plan, ShardedPlan):
            self.n_sharded_batches += 1
        _, mcfg = self.models[wave[0].model]
        # every model kind aggregates once per layer, so a wave costs
        # (launches per aggregation) x n_layers kernel launches
        self.n_launches += plan_launches(bg.graph.plan) * mcfg.n_layers
        now = time.monotonic()
        done = []
        for r, o in zip(wave, outs):
            r.out = o
            r.done = True
            r.t_done = now
            self.completed.append(r)
            self.n_completed += 1
            if r.t_submit:
                self.scheduler.record_latency(now - r.t_submit)
            if r.event is not None:
                r.event.set()
            done.append(r)
        return done

    # -- terminal transitions (called by the scheduler) --------------------
    def _shed_request(self, req: GraphRequest, msg: str) -> None:
        """Deadline shed: admitted under an estimate that later degraded."""
        req.error = msg
        self.shed.append(req)
        if req.event is not None:
            req.event.set()

    def _eject_failed(self, req: GraphRequest, msg: str) -> None:
        """Ejection after ``max_retries`` failed waves."""
        req.error = msg
        self.failed.append(req)
        self.n_failed += 1
        if req.event is not None:
            req.event.set()

    # -- async lifecycle ---------------------------------------------------
    def start(self) -> None:
        """Start the continuous-batching scheduler loop: waves coalesce
        mid-flight and overlap device compute (serve/scheduler.py)."""
        self.scheduler.start()

    def stop(self, timeout: Optional[float] = None, drain: bool = True) -> None:
        """Stop the scheduler loop (draining queued work first by
        default).  Re-raises an interrupt the loop stashed."""
        self.scheduler.stop(timeout=timeout, drain=drain)

    @property
    def running(self) -> bool:
        return self.scheduler.running

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until the intake queue is empty and no wave is in flight
        (async mode); returns False on timeout."""
        deadline = (
            time.monotonic() + timeout if timeout is not None else None
        )
        sched = self.scheduler
        while (
            sched.queue.depth()
            or sched.queue.has_controls()
            or sched._inflight
        ):
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.002)
        return True

    def metrics(self) -> dict:
        s = self.plan_cache.stats
        sched = self.scheduler
        lat = sched.latency_percentiles()
        return {
            "batches": self.n_batches,
            "sharded_batches": self.n_sharded_batches,
            # actual pallas kernel launches: a bucketed plan chains one
            # launch per non-empty capacity segment (x mesh shards when
            # sharded) and the forward aggregates once per layer — see
            # plan_launches()
            "launches": self.n_launches,
            "completed": self.n_completed,
            "failed": self.n_failed,
            "shed": sched.n_shed,
            "rejected": self.n_rejected,
            "waves": sched.n_waves,
            "wave_fill": sched.wave_fill,
            "queue_depth": sched.queue.depth(),
            "queue_depth_by_group": sched.queue_depth_by_group(),
            "latency_count": lat["count"],
            "latency_p50_s": lat["p50_s"],
            "latency_p99_s": lat["p99_s"],
            "latency_mean_s": lat["mean_s"],
            "service_ema_s": sched.service_emas(),
            "async_running": sched.running,
            "serve_seconds": self.serve_seconds,
            "plan_cache_hits": s.hits,
            "plan_cache_misses": s.misses,
            "plan_cache_evictions": s.evictions,
            "plan_cache_expired": s.expired,
            "plan_cache_revalidated": s.revalidated,
            "plan_cache_anchored": s.anchored,
            "graph_updates": self.n_graph_updates,
            "tracked_graphs": len(self._graphs),
            "plan_cache_bytes": s.bytes_in_use,
            "plan_cache_entries": s.entries,
            "plan_cache_hit_rate": s.hit_rate,
            "plan_build_seconds": s.build_seconds,
            # autotune: per-regime resolved configs (key = histogram
            # signature x machine fingerprint) and per tracked graph the
            # config as of its last registration/anchor
            "autotune_enabled": self.tuner is not None,
            "autotune_searches": self.tuner.searches if self.tuner else 0,
            "autotune_cache_hits": self.tuner.cache_hits if self.tuner else 0,
            "resolved_configs": {
                k: c.to_json() for k, c in self._resolved_configs.items()
            },
            "tracked_graph_configs": {
                gid: st.config.to_json()
                for gid, st in self._graphs.items()
                if st.config is not None
            },
        }
