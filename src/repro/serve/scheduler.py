"""Continuous-batching scheduler for the graph serving engine.

``GraphServeEngine.run()`` drains its queue in synchronous waves: snapshot
the queue, pack a batch, serve it, repeat.  That shape is fine for a
closed-loop benchmark but hides exactly the cost an open-loop workload
sees — a request arriving one tick after a snapshot waits out the whole
wave before it is even *considered*, and the host sits idle assembling
composites while the device sits idle waiting for them.  This module owns
everything between ``submit()`` and the forward launch:

* **IntakeQueue** — the single thread-safe owner of queued requests.  It
  is deliberately the only place in the serving layer that mutates queue
  state (scvlint SCV007 rejects direct ``self.queue`` mutation anywhere
  else in ``serve/``), because every mutation path must pass through the
  same admission accounting.  The queue is bounded
  (``GraphEngineConfig.intake_capacity``): a full queue blocks or rejects
  the producer — backpressure instead of unbounded memory growth.

* **Wave formation with mid-flight coalescing** — a wave is a set of
  compatible requests (same model, same resolved ``TunedConfig`` group,
  within the graph/node budgets — the same compatibility rule the sync
  path always used).  Unlike the sync snapshot, a *forming* wave keeps
  absorbing compatible arrivals until it reaches
  ``target_wave_size`` graphs or ``max_wave_delay_ms`` has elapsed since
  its first member arrived.  The absorb window overlaps the previous
  wave's device time: the scheduler dispatches wave *n* (jax async
  dispatch returns before the device finishes), assembles and dispatches
  wave *n+1* host-side, and only then materializes wave *n*'s outputs.

* **Deadline-aware admission control** — requests may carry a relative
  ``deadline_s`` budget.  The scheduler maintains a per-model service-time
  EMA (seconds per wave); ``submit()`` estimates completion from the
  current queue depth and rejects requests that cannot meet their deadline
  (``AdmissionRejected``), and wave formation sheds queued requests whose
  deadline has already become unmeetable (counted separately — a shed
  request was admitted under an estimate that later degraded).

* **Serialized control messages** — ``update(graph_id, delta)`` on a
  running engine is enqueued as a control message and applied by the
  scheduler loop *between* waves, so a delta can never race a wave that
  is concurrently reading the tracked adjacency or revalidating the plan
  cache.  ``update()`` blocks until the scheduler acknowledges, so the
  caller's happens-before is preserved: every request submitted after
  ``update()`` returns serves the post-delta graph.

The synchronous path survives as the degenerate case: ``engine.run()``
calls :meth:`Scheduler.drain`, which forms waves with a zero absorb
window — byte-identical behavior (and failure-isolation semantics) to
the old loop, so every existing parity test keeps passing.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import TYPE_CHECKING, Callable, Optional

import numpy as np

if TYPE_CHECKING:  # import cycle: graph_engine imports this module
    from repro.serve.graph_engine import GraphRequest, GraphServeEngine


class AdmissionRejected(RuntimeError):
    """Request rejected at submit: its deadline cannot be met at the
    current queue depth (estimated from the per-model service-time EMA)."""


class EngineOverloaded(RuntimeError):
    """Request rejected at submit: the bounded intake queue is full and
    the caller asked not to block (backpressure)."""


@dataclasses.dataclass
class _Control:
    """A serialized control message (currently: tracked-graph delta
    update).  ``apply`` runs in the scheduler loop between waves; the
    submitting thread blocks on ``done`` and reads ``result``/``error``."""

    apply: Callable[[], object]
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: object = None
    error: Optional[BaseException] = None


# ---------------------------------------------------------------------------
# intake queue
# ---------------------------------------------------------------------------
class IntakeQueue:
    """Bounded, thread-safe request intake — the single owner of queued
    serving state.

    Producers call :meth:`put` (blocking, timed, or failing fast when the
    queue is full); the single consumer (the scheduler loop, or the sync
    drain) reads a :meth:`snapshot` and commits the requests it took with
    :meth:`commit`.  Requeueing after a failed wave goes through
    :meth:`requeue`, which is exempt from the capacity bound — a failed
    wave's requests were already admitted once and must not be dropped by
    backpressure on their way back in.
    """

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("intake capacity must be positive")
        self.capacity = capacity
        self._items: list["GraphRequest"] = []
        self._controls: list[_Control] = []
        self._cond = threading.Condition()

    # -- producer side -----------------------------------------------------
    def put(
        self,
        req: "GraphRequest",
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> bool:
        """Enqueue; returns False (without enqueueing) if the queue stayed
        full for the whole wait — the caller turns that into
        ``EngineOverloaded``."""
        with self._cond:
            if len(self._items) >= self.capacity:
                if not block:
                    return False
                ok = self._cond.wait_for(
                    lambda: len(self._items) < self.capacity, timeout=timeout
                )
                if not ok:
                    return False
            self._items.append(req)
            self._cond.notify_all()
            return True

    def put_control(self, ctrl: _Control) -> None:
        """Control messages bypass the capacity bound: an update must not
        deadlock behind the very backlog it may be needed to unblock."""
        with self._cond:
            self._controls.append(ctrl)
            self._cond.notify_all()

    # -- consumer side -----------------------------------------------------
    def requeue(self, reqs: list["GraphRequest"]) -> None:
        """Push requests back at the *front* (failure isolation / interrupt
        restore); exempt from the capacity bound."""
        with self._cond:
            self._items[:0] = reqs
            self._cond.notify_all()

    def snapshot(self) -> tuple[list["GraphRequest"], int]:
        """Current items plus the length to pass back to :meth:`commit`."""
        with self._cond:
            return list(self._items), len(self._items)

    def commit(self, n_snapshot: int, remaining: list["GraphRequest"]) -> None:
        """Replace the first ``n_snapshot`` items with ``remaining`` (the
        ones the consumer did not take); items that arrived after the
        snapshot are preserved in order.  Single-consumer discipline makes
        this safe: only the scheduler removes items."""
        with self._cond:
            self._items[:n_snapshot] = remaining
            self._cond.notify_all()

    def pop_controls(self) -> list[_Control]:
        with self._cond:
            out, self._controls = self._controls, []
            return out

    def wait_for_work(self, timeout: Optional[float]) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._items or self._controls, timeout=timeout
            )

    # -- introspection -----------------------------------------------------
    def depth(self) -> int:
        with self._cond:
            return len(self._items)

    def has_controls(self) -> bool:
        with self._cond:
            return bool(self._controls)

    def __len__(self) -> int:
        return self.depth()

    def items(self) -> list["GraphRequest"]:
        with self._cond:
            return list(self._items)

    def notify_all(self) -> None:
        with self._cond:
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------
class Scheduler:
    """Owns wave formation, admission, and the async serving loop.

    One instance per engine.  All device work and all tracked-graph /
    plan-cache mutation happens on a single thread (the caller's thread in
    sync :meth:`drain` mode, the loop thread in async mode) — concurrency
    lives entirely in the intake queue and per-request completion events.
    """

    def __init__(self, engine: "GraphServeEngine"):
        self.engine = engine
        cfg = engine.cfg
        self.queue = IntakeQueue(cfg.intake_capacity)
        self.target_wave = min(
            cfg.target_wave_size or cfg.max_batch_graphs, cfg.max_batch_graphs
        )
        self.max_wave_delay_s = cfg.max_wave_delay_ms / 1e3
        self._ema_alpha = cfg.service_ema_alpha
        self._ema: dict[str, float] = {}  # model -> seconds per wave
        self._lat = deque(maxlen=cfg.latency_window)  # completed latencies
        self._stats_lock = threading.Lock()
        self.n_waves = 0
        self.n_shed = 0
        self._fill_sum = 0.0  # sum of per-wave fill ratios
        # async loop state
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._inflight = False  # a dispatched wave awaits materialization
        self.interrupt: Optional[BaseException] = None  # stashed KI from loop

    # -- admission ---------------------------------------------------------
    def service_estimate(self, model: str) -> Optional[float]:
        """EMA of wave service seconds for ``model`` (None before the
        first completed wave)."""
        with self._stats_lock:
            return self._ema.get(model)

    def _observe_service(self, model: str, seconds: float) -> None:
        with self._stats_lock:
            prev = self._ema.get(model)
            self._ema[model] = (
                seconds if prev is None
                else (1 - self._ema_alpha) * prev + self._ema_alpha * seconds
            )

    def service_emas(self) -> dict[str, float]:
        """Copy of the per-model wave service-time EMAs (seconds)."""
        with self._stats_lock:
            return dict(self._ema)

    def record_latency(self, seconds: float) -> None:
        with self._stats_lock:
            self._lat.append(seconds)

    def latency_percentiles(self) -> dict:
        with self._stats_lock:
            lat = np.asarray(self._lat, np.float64)
        if lat.size == 0:
            return {"count": 0, "p50_s": None, "p99_s": None, "mean_s": None}
        return {
            "count": int(lat.size),
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "mean_s": float(lat.mean()),
        }

    def admit(self, req: "GraphRequest", now: float) -> None:
        """Deadline feasibility check at submit time.

        Estimated completion = now + (waves ahead of this request,
        including the one it would join and any in-flight wave) x the
        model's service EMA.  Optimistic before the first observation
        (no EMA -> admit); an estimate that later degrades is handled by
        shedding at wave-formation time instead.
        """
        if req.deadline_s is None:
            return
        ema = self.service_estimate(req.model)
        if ema is None:
            return
        depth = self.queue.depth()
        waves_ahead = -(-(depth + 1) // self.engine.cfg.max_batch_graphs)
        if self._inflight:
            waves_ahead += 1
        est_done = now + waves_ahead * ema
        if est_done > now + req.deadline_s:
            raise AdmissionRejected(
                f"deadline {req.deadline_s * 1e3:.1f}ms infeasible: "
                f"{depth} queued ({waves_ahead} wave(s) ahead) at "
                f"~{ema * 1e3:.1f}ms/wave for model {req.model!r}"
            )

    # -- wave formation ----------------------------------------------------
    def _shed_expired(
        self, items: list["GraphRequest"], now: float
    ) -> list["GraphRequest"]:
        """Drop queued requests whose deadline can no longer be met (the
        queue-depth estimate at admission has degraded).  Shed requests
        complete with an error and land in ``engine.shed``."""
        keep = []
        for r in items:
            if r.deadline_s is None or r.isolate:
                keep.append(r)
                continue
            ema = self.service_estimate(r.model) or 0.0
            t_deadline = r.t_submit + r.deadline_s
            if now + ema > t_deadline:
                self.engine._shed_request(
                    r,
                    f"deadline shed: {(now - r.t_submit) * 1e3:.1f}ms queued "
                    f"of a {r.deadline_s * 1e3:.1f}ms budget "
                    f"(~{ema * 1e3:.1f}ms/wave)",
                )
                with self._stats_lock:
                    self.n_shed += 1
            else:
                keep.append(r)
        return keep

    def _pick_wave(
        self, items: list["GraphRequest"]
    ) -> tuple[list["GraphRequest"], list["GraphRequest"]]:
        """Greedy in-arrival-order pack over ``items`` — the sync path's
        historical rule, verbatim: same model kind, same resolved plan
        config (under autotune), bounded graph and node counts; an
        isolated head is served alone; the head is always admitted."""
        eng = self.engine
        head = items[0]
        if head.isolate:
            return [head], items[1:]
        head_cfg = eng._resolve_config(eng._resolve_adj(head))
        T = head_cfg.tile
        batch: list[GraphRequest] = []
        nodes = 0
        remaining = []
        for r in items:
            fits = (
                not r.isolate
                and r.model == head.model
                and len(batch) < eng.cfg.max_batch_graphs
            )
            if fits and eng.tuner is not None:
                fits = eng._resolve_config(eng._resolve_adj(r)) == head_cfg
            if fits:
                aligned = -(-eng._resolve_adj(r).shape[0] // T) * T
                fits = not batch or nodes + aligned <= eng.cfg.max_batch_nodes
            if fits:
                batch.append(r)
                nodes += aligned
            else:
                remaining.append(r)
        return batch, remaining

    def form_wave(self, absorb: bool) -> list["GraphRequest"]:
        """Take the next wave off the intake queue.

        With ``absorb=False`` (sync drain) this is exactly the historical
        snapshot pack.  With ``absorb=True`` a wave smaller than
        ``target_wave_size`` keeps the queue position open and absorbs
        compatible arrivals until ``max_wave_delay_ms`` has elapsed since
        formation started — continuous batching instead of snapshotting.
        """
        t_start = time.monotonic()
        items, n = self.queue.snapshot()
        if not items:
            return []
        items = self._shed_expired(items, t_start)
        if not items:
            self.queue.commit(n, [])
            return []
        wave, remaining = self._pick_wave(items)
        self.queue.commit(n, remaining)
        if not absorb or wave[0].isolate:
            self._record_fill(wave)
            return wave
        # mid-flight absorb: keep topping the wave up with compatible
        # arrivals until it is full or the delay budget is spent
        while len(wave) < self.target_wave:
            elapsed = time.monotonic() - t_start
            budget = self.max_wave_delay_s - elapsed
            if budget <= 0:
                break
            if not self.queue.wait_for_work(timeout=budget):
                break
            if self.queue.has_controls():
                break  # controls are serialized with waves: apply first
            items, n = self.queue.snapshot()
            if not items:
                continue
            grown, remaining = self._pick_wave(wave + items)
            if len(grown) <= len(wave):
                break  # head-compatible arrivals exhausted
            # _pick_wave keeps arrival order, so the existing wave is a
            # prefix of the grown wave; commit removes only the new picks
            # (identity, not ==: requests hold numpy leaves)
            taken = {id(r) for r in wave}
            self.queue.commit(n, [r for r in remaining if id(r) not in taken])
            wave = grown
        self._record_fill(wave)
        return wave

    def _record_fill(self, wave: list["GraphRequest"]) -> None:
        with self._stats_lock:
            self.n_waves += 1
            self._fill_sum += len(wave) / self.target_wave

    @property
    def wave_fill(self) -> float:
        """Mean wave fill ratio (graphs per wave / target_wave_size)."""
        with self._stats_lock:
            return self._fill_sum / self.n_waves if self.n_waves else 0.0

    # -- failure handling (shared by sync drain and async loop) ------------
    def _fail_wave(self, batch: list["GraphRequest"], e: Exception) -> None:
        """Failure isolation: survivors requeue isolated (served alone
        next wave, so one bad member cannot keep failing a whole wave);
        a request that exhausts ``max_retries`` is ejected to
        ``engine.failed`` with the error recorded."""
        eng = self.engine
        survivors = []
        for r in batch:
            r.retries += 1
            if r.retries > eng.cfg.max_retries:
                eng._eject_failed(r, f"{type(e).__name__}: {e}")
            else:
                r.isolate = True
                survivors.append(r)
        self.queue.requeue(survivors)

    # -- synchronous drain (engine.run()) ----------------------------------
    def drain(self) -> list["GraphRequest"]:
        """The degenerate single-consumer path behind ``engine.run()``:
        form waves with no absorb window and serve until the queue is
        empty.  Exception semantics are the historical ones — failures
        isolate/eject and re-raise, interrupts restore the wave untouched
        and consume no retries."""
        eng = self.engine
        t0 = time.perf_counter()
        done = eng.last_completed = []
        try:
            while self.queue.depth():
                wave = self.form_wave(absorb=False)
                if not wave:
                    continue  # everything shed
                try:
                    bg, out = eng._dispatch_wave(wave)
                    done.extend(eng._finish_wave(wave, bg, out))
                except BaseException as e:
                    if not isinstance(e, Exception):
                        # interrupts are not request failures: restore the
                        # wave untouched, consume no retries
                        self.queue.requeue(wave)
                        raise
                    self._fail_wave(wave, e)
                    raise
            return done
        finally:
            eng.serve_seconds += time.perf_counter() - t0

    # -- async loop --------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("scheduler loop already running")
        self.interrupt = None
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name="graph-serve-scheduler", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: Optional[float] = None, drain: bool = True) -> None:
        """Stop the loop.  With ``drain=True`` (default) the loop first
        serves everything already queued; pending work survives either way
        (the intake queue is engine state, not loop state).  Re-raises an
        interrupt (e.g. KeyboardInterrupt) the loop stashed."""
        self._drain_on_stop = drain
        self._running = False
        self.queue.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self.interrupt is not None:
            err, self.interrupt = self.interrupt, None
            raise err

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _apply_controls(self) -> None:
        for ctrl in self.queue.pop_controls():
            try:
                ctrl.result = ctrl.apply()
            except BaseException as e:
                ctrl.error = e
            finally:
                ctrl.done.set()

    def _loop(self) -> None:
        """The continuous-batching pipeline.

        Invariant: at most one dispatched-but-unmaterialized wave
        (``inflight``).  Each iteration applies pending controls, forms
        the next wave (its absorb window overlapping the in-flight wave's
        device time), dispatches it, and only then materializes the
        previous wave's outputs — host-side assembly of wave *n+1* runs
        while the device executes wave *n*.
        """
        eng = self.engine
        inflight: Optional[tuple] = None  # (wave, bg, out, t_wave_start)
        self._drain_on_stop = True
        while True:
            self._apply_controls()
            if not self._running:
                if not self._drain_on_stop:
                    break
                if not self.queue.depth() and inflight is None:
                    break
            t_wave = time.perf_counter()
            busy = self.queue.depth() > 0
            if busy:
                # raised *before* formation commits the queue take, so
                # wait_idle() never observes the window where a wave is
                # neither queued nor marked in flight
                self._inflight = True
            # no absorb window while draining to a stop — nothing new is
            # worth waiting for, just flush
            wave = self.form_wave(absorb=self._running) if busy else []
            dispatched = None
            if wave:
                try:
                    bg, out = eng._dispatch_wave(wave)
                    dispatched = (wave, bg, out, t_wave)
                except BaseException as e:
                    if not isinstance(e, Exception):
                        # interrupt: restore the wave untouched, stop the
                        # loop, surface the exception from stop()
                        self.queue.requeue(wave)
                        self.interrupt = e
                        self._running = False
                        self._drain_on_stop = False
                        dispatched = None
                    else:
                        self._fail_wave(wave, e)
            if inflight is not None:
                self._retire(inflight)
                inflight = None
            inflight = dispatched
            self._inflight = inflight is not None
            if inflight is None and not self.queue.depth():
                if not self._running:
                    continue  # loop once more to hit the exit check
                self.queue.wait_for_work(timeout=0.05)

    def _retire(self, inflight: tuple) -> None:
        """Materialize a dispatched wave's outputs (blocks on the device),
        complete its requests, and fold the wave's wall time into the
        service EMA.  Materialization errors are request failures too —
        on accelerators an async-dispatched error surfaces here."""
        wave, bg, out, t_wave = inflight
        eng = self.engine
        try:
            eng._finish_wave(wave, bg, out)
        except BaseException as e:
            if not isinstance(e, Exception):
                self.queue.requeue(wave)
                self.interrupt = e
                self._running = False
                self._drain_on_stop = False
                return
            self._fail_wave(wave, e)
            return
        finally:
            dt = time.perf_counter() - t_wave
            eng.serve_seconds += dt
        self._observe_service(wave[0].model, time.perf_counter() - t_wave)

    # -- introspection -----------------------------------------------------
    def queue_depth_by_group(self) -> dict[str, int]:
        """Queued requests per (model, padding-bucket) group — the
        coalescing granularity.  Buckets use the engine's fallback tile
        (per-request autotune resolution would make metrics() O(nnz))."""
        from repro.serve.graph_engine import _bucket_nodes

        eng = self.engine
        T = eng._fallback_config.tile
        out: dict[str, int] = {}
        for r in self.queue.items():
            adj = eng._resolve_adj(r)
            aligned = -(-adj.shape[0] // T) * T
            b = _bucket_nodes(aligned, eng.cfg.node_buckets, T)
            key = f"{r.model}:n{b}"
            out[key] = out.get(key, 0) + 1
        return out
