"""Plan cache: amortize SCV preprocessing across repeated graph queries.

The paper builds SCV host-side ("statically generated from the COO format",
§III-C) — a per-graph cost the serving path would otherwise repay on every
request.  This module caches the prepared plan (the ``Graph`` bundle from
``models/gnn.py``: SCV tiles + device arrays + permutation) keyed by a
content hash of the COO adjacency, so hot graphs skip preprocessing
entirely.

Design:

* **Content-hash keys** — ``coo_content_key`` hashes the raw (rows, cols,
  vals, shape) bytes plus the plan parameters (tile, cap), so two requests
  carrying the same adjacency — even built independently — share one plan,
  and plans built under different tilings never collide.  Composite
  (batched) plans derive their key from the member digests via
  ``combine_keys``: the *composed* arrays are never re-hashed (member
  adjacencies are still hashed once per wave to identify them).

* **LRU + byte budget** — entries are evicted least-recently-used when
  either the entry-count or the byte budget is exceeded.  Bytes are
  accounted from the device/host arrays actually held by the plan.

* **TTL / refresh** — with ``max_age_s`` set, an entry older than the TTL
  is treated as a miss on lookup (dropped and counted in
  ``stats.expired``), so ``get_or_build`` transparently rebuilds it — the
  refresh policy for serving processes whose graph contents drift under a
  stable content key is "expire and rebuild on next touch".  The clock is
  injected (defaults to ``time.monotonic``) so policies are testable
  without sleeping.

* **Counters** — hits / misses / evictions / expirations / bytes for the
  serving metrics endpoint and the benchmark's hit-rate report.

The cache is deliberately value-agnostic: ``get_or_build`` takes a builder
callback, so the engine caches single-graph plans and composite batch
plans (and, later, partitioned multi-device plans) through one code path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Iterable, Optional

import numpy as np

from repro.core.formats import COOMatrix


# ---------------------------------------------------------------------------
# content keys
# ---------------------------------------------------------------------------
def coo_content_key(adj: COOMatrix, *, tile: int, cap: Any = None) -> str:
    """Stable content hash of a COO adjacency + plan parameters.

    ``cap`` is the capacity signature: an int for single-cap plans, the
    ascending bucket ladder tuple for nnz-bucketed plans (the layout is
    plan aux, so it must key the cached device object)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(f"shape={adj.shape};tile={tile};cap={cap};".encode())
    for a in (adj.rows, adj.cols, adj.vals):
        arr = np.ascontiguousarray(a)
        # frame each array with dtype + length: raw bytes alone would let
        # byte-aliased arrays of different dtypes/lengths collide
        h.update(f"{arr.dtype.str}:{arr.shape[0]};".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def combine_keys(keys: Iterable[str], *, salt: str = "") -> str:
    """Key for a composite plan derived from already-keyed members.

    Hashing the member digests (plus a salt carrying batch parameters such
    as the padding bucket) is orders of magnitude cheaper than re-hashing
    the composed arrays, and equal batches — same members, same order,
    same bucket — collapse to one plan.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(salt.encode())
    for k in keys:
        h.update(k.encode())
    return h.hexdigest()


def delta_key(key: str, delta: Any) -> str:
    """Key of a cached plan after applying ``delta`` (a
    ``stream.DeltaBatch``): hash of the old key + the delta's framed byte
    signature.  Chaining digests is orders of magnitude cheaper than
    re-hashing a mutated million-edge adjacency, at the cost that the
    chained key differs from ``coo_content_key`` of the final adjacency
    computed cold — a graph is either tracked by deltas or keyed by
    content, never both (see serve/README.md).
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(b"delta;")
    h.update(key.encode())
    h.update(delta.signature())
    return h.hexdigest()


def plan_nbytes(plan: Any) -> int:
    """Best-effort byte footprint of a cached plan.

    Walks the object for numpy / jax arrays (dataclass fields, dicts,
    tuples/lists) and sums ``nbytes``.  Shared arrays are counted once
    (identity-deduped).
    """
    seen: set[int] = set()
    total = 0

    def visit(obj):
        nonlocal total
        if obj is None or isinstance(obj, (int, float, str, bool, bytes)):
            return
        oid = id(obj)
        if oid in seen:
            return
        seen.add(oid)
        nb = getattr(obj, "nbytes", None)
        if nb is not None and isinstance(nb, (int, np.integer)):
            total += int(nb)
            return
        if isinstance(obj, dict):
            for v in obj.values():
                visit(v)
        elif isinstance(obj, (list, tuple)):
            for v in obj:
                visit(v)
        elif dataclasses.is_dataclass(obj):
            for f in dataclasses.fields(obj):
                visit(getattr(obj, f.name))

    visit(plan)
    return total


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    expired: int = 0  # TTL drops (also counted as misses on lookup)
    revalidated: int = 0  # delta-patched entries re-keyed in place
    anchored: int = 0  # delta-chained keys re-homed to content keys
    bytes_in_use: int = 0
    entries: int = 0
    build_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


@dataclasses.dataclass
class _Entry:
    value: Any
    nbytes: int
    created: float = 0.0  # clock() at insertion (TTL anchor)


class PlanCache:
    """Content-addressed LRU cache of prepared aggregation plans.

    ``max_age_s`` (optional) bounds entry staleness: lookups drop entries
    older than the TTL and report a miss, so hot keys are rebuilt in place.
    ``clock`` is injectable for tests (monotonic seconds).

    Thread safety: every public method takes one reentrant lock, so
    concurrent lookups, revalidations, and anchors never observe a
    half-applied mutation (the async scheduler loop builds/revalidates
    while other threads read metrics or probe keys).  The lock is held
    across ``get_or_build``'s builder call — reentrancy is what lets a
    composite build nest its member builds — which serializes builders;
    that is the engine's single-consumer discipline anyway (only the
    scheduler thread builds plans).
    """

    def __init__(
        self,
        max_entries: int = 256,
        max_bytes: int = 512 * 1024 * 1024,
        max_age_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_entries <= 0:
            raise ValueError("max_entries must be positive")
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        if max_age_s is not None and max_age_s <= 0:
            raise ValueError("max_age_s must be positive (or None to disable)")
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.max_age_s = max_age_s
        self._clock = clock
        self._entries: OrderedDict[str, _Entry] = OrderedDict()
        self.stats = PlanCacheStats()
        self._build_depth = 0  # nested get_or_build (composite -> members)
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return self._live_entry(key) is not None

    @property
    def keys(self) -> list[str]:
        """Keys in LRU order (least-recently-used first)."""
        with self._lock:
            return list(self._entries)

    def _live_entry(self, key: str) -> Optional[_Entry]:
        """Entry for ``key`` if present and within TTL; expired entries are
        dropped (counted in ``stats.expired``) and reported absent."""
        e = self._entries.get(key)
        if e is None:
            return None
        if self.max_age_s is not None and self._clock() - e.created > self.max_age_s:
            self._entries.pop(key)
            self.stats.bytes_in_use -= e.nbytes
            self.stats.expired += 1
            self.stats.entries = len(self._entries)
            return None
        return e

    def get(self, key: str) -> Optional[Any]:
        """Look up a plan; counts a hit/miss and refreshes recency.
        An entry past ``max_age_s`` counts as a miss (and is dropped)."""
        with self._lock:
            e = self._live_entry(key)
            if e is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return e.value

    def peek(self, key: str) -> Optional[Any]:
        """Look up without touching recency or hit/miss counters
        (introspection); still drops entries past the TTL."""
        with self._lock:
            e = self._live_entry(key)
            return e.value if e is not None else None

    def put(self, key: str, value: Any, nbytes: Optional[int] = None) -> None:
        if nbytes is None:
            nbytes = plan_nbytes(value)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.stats.bytes_in_use -= old.nbytes
            if nbytes > self.max_bytes:
                # an entry that can never fit would evict the whole cache on
                # its way in and then be evicted itself — skip it instead
                self.stats.entries = len(self._entries)
                return
            self._entries[key] = _Entry(value, int(nbytes), created=self._clock())
            self.stats.bytes_in_use += int(nbytes)
            self._evict()
            self.stats.entries = len(self._entries)

    def get_or_build(
        self,
        key: str,
        builder: Callable[[], Any],
        nbytes: Optional[int] = None,
    ) -> Any:
        """Return the cached plan for ``key``, building (and caching) it on
        a miss.  Oversized plans (> max_bytes on their own) are still
        returned but not retained."""
        with self._lock:
            value = self.get(key)
            if value is not None:
                return value
            # build_seconds accumulates only at the outermost nesting level:
            # a composite builder calls get_or_build for its members, and
            # the outer elapsed time already contains theirs
            self._build_depth += 1
            t0 = time.perf_counter()
            try:
                value = builder()
            finally:
                dt = time.perf_counter() - t0
                self._build_depth -= 1
                if self._build_depth == 0:
                    self.stats.build_seconds += dt
            nb = plan_nbytes(value) if nbytes is None else int(nbytes)
            if nb <= self.max_bytes:
                self.put(key, value, nb)
            return value

    def revalidate(
        self,
        key: str,
        delta: Any,
        patch: Optional[Callable[[Any], Any]] = None,
    ) -> str:
        """Re-key the entry at ``key`` for a delta-mutated graph instead of
        letting the mutation become a full miss.

        Returns ``delta_key(key, delta)`` — the key the patched plan lives
        under.  If the entry is live and ``patch`` is given, the cached
        value is patched (``patch(value)``, typically
        ``stream.apply_delta``), stored under the new key, and counted in
        ``stats.revalidated``; the old key is dropped.  If the entry is
        absent (evicted/expired) the new key is still returned so the
        caller's next ``get_or_build`` rebuilds from the mutated source —
        revalidation degrades to a plain miss, never to a stale hit.
        """
        new_key = delta_key(key, delta)
        with self._lock:
            e = self._live_entry(key)
            if e is None or patch is None:
                return new_key
            self._entries.pop(key)
            self.stats.bytes_in_use -= e.nbytes
            self.stats.entries = len(self._entries)
            self.put(new_key, patch(e.value))
            self.stats.revalidated += 1
            return new_key

    def anchor(self, key: str, content_key: str) -> str:
        """Re-home a live entry from a delta-chained key to the content
        key of its *current* adjacency.

        ``revalidate`` chains digests (``delta_key``), so a long-lived
        tracked graph drifts away from ``coo_content_key`` of its actual
        adjacency — an untracked client submitting the identical graph
        would miss and build a duplicate entry.  Periodically re-homing
        the entry under the content key re-joins the two key spaces and
        bounds the drift window.  Counted in ``stats.anchored``; if the
        entry is dead (evicted/expired) the content key is still returned
        so the caller re-keys and the next build lands content-addressed.
        """
        with self._lock:
            e = self._live_entry(key)
            if e is None or content_key == key:
                return content_key
            self._entries.pop(key)
            self.stats.bytes_in_use -= e.nbytes
            self.put(content_key, e.value, e.nbytes)
            self.stats.anchored += 1
            return content_key

    def _evict(self) -> None:
        while self._entries and (
            len(self._entries) > self.max_entries
            or self.stats.bytes_in_use > self.max_bytes
        ):
            _, e = self._entries.popitem(last=False)
            self.stats.bytes_in_use -= e.nbytes
            self.stats.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.stats.bytes_in_use = 0
            self.stats.entries = 0
