"""Batched serving engine: continuous prefill + decode over a request
queue.

Small-but-real serving logic exercised by examples/serve_lm.py and the
integration tests: requests arrive with prompts, get batched up to
``max_batch``, prefilled together (padded to the bucket), then decoded
token-by-token with per-slot stopping.  On the production mesh the same
engine runs with the sharded decode_step (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # int32[prompt_len]
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 4
    max_len: int = 128
    greedy: bool = True


class ServeEngine:
    """Drives (prefill_fn, decode_fn) over batches of requests.

    prefill_fn(params, tokens[B,S]) -> (logits[B,1,V], state)
    decode_fn(params, state, token[B,1], pos[B,1]) -> (logits, state)
    """

    def __init__(self, params, prefill_fn, decode_fn, cfg: EngineConfig):
        self.params = params
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.cfg = cfg
        self.queue: list[Request] = []
        self.completed: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _pick(self, logits) -> np.ndarray:
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        raise NotImplementedError

    def run(self) -> list[Request]:
        while self.queue:
            batch = self.queue[: self.cfg.max_batch]
            self.queue = self.queue[self.cfg.max_batch :]
            plen = max(len(r.prompt) for r in batch)
            B = len(batch)
            toks = np.zeros((B, plen), np.int32)
            for i, r in enumerate(batch):
                toks[i, plen - len(r.prompt) :] = r.prompt  # left-pad
            logits, state = self.prefill_fn(self.params, jnp.asarray(toks))
            nxt = self._pick(logits)
            for i, r in enumerate(batch):
                r.out.append(int(nxt[i]))
            max_new = max(r.max_new for r in batch)
            for step in range(1, max_new):
                pos = jnp.full((B, 1), plen + step - 1, jnp.int32)
                logits, state = self.decode_fn(
                    self.params, state, jnp.asarray(nxt)[:, None], pos
                )
                nxt = self._pick(logits)
                for i, r in enumerate(batch):
                    if len(r.out) < r.max_new:
                        r.out.append(int(nxt[i]))
            for r in batch:
                r.done = True
                self.completed.append(r)
        return self.completed
