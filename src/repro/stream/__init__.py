"""stream/: delta plan maintenance for dynamic graphs.

Edge mutation as a first-class operation: :class:`DeltaBatch` +
:func:`apply_delta` patch SCV plans incrementally (Z-Morton tile splice,
ladder-crossing re-bucket only) instead of re-running the O(nnz)
``coo_to_scv_tiles`` build; ``serve.plan_cache.PlanCache.revalidate`` and
``serve.graph_engine.GraphServeEngine.update`` ride on it.
"""
from repro.stream.delta import (
    DeltaBatch,
    apply_coo,
    apply_delta,
    check_delta,
)

__all__ = [
    "DeltaBatch",
    "apply_coo",
    "apply_delta",
    "check_delta",
]
