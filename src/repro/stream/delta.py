"""Delta plan maintenance: incremental edge mutation for SCV plans.

SCV-GNN's advantage is a *preprocessed* plan — and its liability is that
any edge change used to throw the plan away (`coo_to_scv_tiles` is
O(nnz); ~0.5 s at 1M edges per BENCH_preprocess.json).  This module makes
mutation first-class: a :class:`DeltaBatch` of edge inserts/removes and
:func:`apply_delta`, which patches `SCVTiles` / `SCVPlan` /
`SCVBucketedPlan` / `Graph` by splicing only the Z-Morton tiles the delta
touches.

The contract that anchors correctness (tested in
``tests/test_plan_roundtrip.py`` / ``tests/test_stream.py``): the patched
object is **byte-identical** to a from-scratch rebuild on the final COO,

    apply_delta(build(adj), d)  ==  build(apply_coo(adj, d))

for every layer's builder, and passes the full ``core.validate``
invariant chain.  That works because the canonical final COO ordering is
chosen to minimize churn (**hole-filling**, see :class:`_IdPlan`):

* inserts take the removal holes in ascending hole order (a same-batch
  remove+insert of one coordinate — the value-update idiom — keeps its
  id exactly);
* leftover inserts append at the tail with fresh ids;
* leftover holes are back-filled by the *moved tail survivors* (the
  surviving entries past the new length, ascending) and the COO truncates.

So the only entries whose source id changes are the ≤ ``n_remove`` moved
tail survivors — a patch rewrites the tiles holding delta coordinates or
moved survivors and **no** O(nnz) pass over the perm arrays ever happens
(the property the update-vs-rebuild gate in ``benchmarks/stream_bench.py``
rests on).  When removals outnumber inserts the moved survivors must be
located: ``apply_delta(..., source=<pre-delta COO>)`` finds their tiles by
coordinate arithmetic (the ``Graph`` layer uses its own edge arrays);
without a source the perm leaves are scanned once, blockwise.

Only tiles whose (block_row, block_col) key matches a delta coordinate
(or holds a moved survivor) are re-spliced.  When no
tile's chain length changes (splices absorbed by capacity slack) the
chunk layout — array shapes, tile coordinates, schedule — is preserved
exactly, so downstream jit traces keyed on leaf shapes survive.  For
bucketed plans, a tile is re-bucketed **only** when its new chunk nnz
crosses a `caps` ladder boundary; segments the delta never touches keep
their device arrays by identity.

Requirements on the input (raising ``ValueError`` otherwise):

* plans must carry the ``perm`` leaf (it *is* the source-id bookkeeping
  the splice maintains);
* bucketed plans must have been chain-split at ``caps[-1]`` (the
  ``build_graph(bucket_caps=...)`` path) — chunk chains are reassembled
  across segments under the rule "all chunks but the last are full";
* zero-nnz tiles must form a trailing coverage tail (true of every
  built plan; serving *composites* interleave padding tiles and are not
  patchable — patch the members, reassemble the composite).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import SCVBucketedPlan, SCVPlan, SCVTiles


# ---------------------------------------------------------------------------
# the delta
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One batch of edge mutations: removes apply first, then inserts.

    "Remove then insert the same coordinate" is therefore the value-update
    idiom; a removal removes **every** stored entry at its coordinate
    (COO inputs with duplicate coordinates have them all matched).
    """

    ins_rows: np.ndarray  # int32[ki]
    ins_cols: np.ndarray  # int32[ki]
    ins_vals: np.ndarray  # f32[ki]
    rem_rows: np.ndarray  # int32[kr]
    rem_cols: np.ndarray  # int32[kr]

    @classmethod
    def of(cls, inserts=(), removes=()) -> "DeltaBatch":
        """Build from ``[(row, col, val), ...]`` / ``[(row, col), ...]``."""
        ins = list(inserts)
        rem = list(removes)
        return cls(
            ins_rows=np.array([e[0] for e in ins], np.int32),
            ins_cols=np.array([e[1] for e in ins], np.int32),
            ins_vals=np.array([e[2] for e in ins], np.float32),
            rem_rows=np.array([e[0] for e in rem], np.int32),
            rem_cols=np.array([e[1] for e in rem], np.int32),
        )

    @property
    def n_insert(self) -> int:
        return int(self.ins_rows.shape[0])

    @property
    def n_remove(self) -> int:
        return int(self.rem_rows.shape[0])

    def __len__(self) -> int:
        return self.n_insert + self.n_remove

    def signature(self) -> bytes:
        """Framed byte digest input for delta-chained cache keys
        (``serve.plan_cache.delta_key``): dtype + length framing per
        array, so byte-aliased deltas of different shapes never collide."""
        parts = [b"delta;"]
        for a in (self.ins_rows, self.ins_cols, self.ins_vals,
                  self.rem_rows, self.rem_cols):
            arr = np.ascontiguousarray(a)
            parts.append(f"{arr.dtype.str}:{arr.shape[0]};".encode())
            parts.append(arr.tobytes())
        return b"".join(parts)


# ---------------------------------------------------------------------------
# admission (mirrors core.validate.check_coo)
# ---------------------------------------------------------------------------
def check_delta(
    delta: DeltaBatch,
    shape: Optional[tuple[int, int]] = None,
    coo: Optional[COOMatrix] = None,
) -> None:
    """Reject malformed deltas with a clear ``ValueError``.

    Structural checks (always): 1-D arrays agreeing on length, in-range
    non-negative node ids when ``shape`` (or ``coo``) is given, finite
    insert values, no duplicate insert coordinates, no duplicate remove
    coordinates.  With ``coo`` given, presence is checked too: every
    remove must match a stored edge, and an insert of an already-present
    edge is rejected unless the same coordinate is also removed in this
    batch (the value-update idiom).  ``apply_delta`` re-checks presence
    locally either way, so plan-level callers may skip ``coo``.
    """
    for name in ("ins_rows", "ins_cols", "ins_vals", "rem_rows", "rem_cols"):
        a = getattr(delta, name)
        if np.ndim(a) != 1:
            raise ValueError(f"delta.{name} must be 1-D, got ndim={np.ndim(a)}")
    if not (delta.ins_rows.shape == delta.ins_cols.shape == delta.ins_vals.shape):
        raise ValueError(
            "delta insert arrays disagree on length: "
            f"rows={delta.ins_rows.shape[0]} cols={delta.ins_cols.shape[0]} "
            f"vals={delta.ins_vals.shape[0]}"
        )
    if delta.rem_rows.shape != delta.rem_cols.shape:
        raise ValueError(
            "delta remove arrays disagree on length: "
            f"rows={delta.rem_rows.shape[0]} cols={delta.rem_cols.shape[0]}"
        )
    if shape is None and coo is not None:
        shape = coo.shape
    if shape is not None:
        m, n = shape
        for what, rr, cc in (
            ("insert", delta.ins_rows, delta.ins_cols),
            ("remove", delta.rem_rows, delta.rem_cols),
        ):
            if len(rr) == 0:
                continue
            if int(rr.min()) < 0 or int(cc.min()) < 0:
                raise ValueError(f"delta {what} node ids must be non-negative")
            if int(rr.max()) >= m or int(cc.max()) >= n:
                raise ValueError(
                    f"delta {what} node ids out of range for shape {shape}: "
                    f"max row {int(rr.max())}, max col {int(cc.max())}"
                )
    if delta.n_insert and not np.all(np.isfinite(delta.ins_vals)):
        bad = np.flatnonzero(~np.isfinite(np.asarray(delta.ins_vals)))
        raise ValueError(
            f"delta insert values must be finite; {len(bad)} non-finite "
            f"(first at {int(bad[0])})"
        )
    # duplicate coordinates within each op list are always ambiguous
    span = max(int(shape[1]) if shape is not None else 0,
               _coord_span(delta))
    ikey = _keys(delta.ins_rows, delta.ins_cols, span)
    rkey = _keys(delta.rem_rows, delta.rem_cols, span)
    for what, k in (("insert", ikey), ("remove", rkey)):
        if len(k) != len(np.unique(k)):
            raise ValueError(
                f"duplicate {what} coordinates in delta (each edge may be "
                f"{what}d at most once per batch)"
            )
    if coo is not None:
        ckey = np.sort(_keys(coo.rows, coo.cols, span))
        missing = ~_present(ckey, rkey)
        if missing.any():
            i = int(np.flatnonzero(missing)[0])
            raise ValueError(
                f"delta removes absent edge ({int(delta.rem_rows[i])}, "
                f"{int(delta.rem_cols[i])}); removes must match stored edges"
            )
        clash = _present(ckey, ikey) & ~_present(np.sort(rkey), ikey)
        if clash.any():
            i = int(np.flatnonzero(clash)[0])
            raise ValueError(
                f"delta inserts already-present edge ({int(delta.ins_rows[i])},"
                f" {int(delta.ins_cols[i])}); remove it in the same batch to "
                "update its value"
            )


def _coord_span(delta: DeltaBatch) -> int:
    hi = 0
    for a in (delta.ins_cols, delta.rem_cols):
        if len(a):
            hi = max(hi, int(np.asarray(a).max()) + 1)
    return hi


def _keys(rows, cols, span: int) -> np.ndarray:
    return np.asarray(rows, np.int64) * max(span, 1) + np.asarray(cols, np.int64)


def _present(sorted_keys: np.ndarray, query: np.ndarray) -> np.ndarray:
    """Membership of ``query`` in ``sorted_keys`` (boolean per query)."""
    if len(sorted_keys) == 0 or len(query) == 0:
        return np.zeros(len(query), bool)
    idx = np.searchsorted(sorted_keys, query)
    idx = np.minimum(idx, len(sorted_keys) - 1)
    return sorted_keys[idx] == query


# ---------------------------------------------------------------------------
# the id plan: old entry position -> new entry position
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _IdPlan:
    """Bookkeeping for one delta's canonical final-COO ordering.

    The ordering is **hole-filling**: inserts take the removal holes in
    ascending hole order, leftover inserts append at the tail, leftover
    holes are back-filled by the *moved tail survivors* (the surviving
    entries past the new length ``L``, ascending), and the array truncates
    to ``L``.  The payoff over naive compaction: every survivor below
    ``L`` — in a small delta, essentially all of them — keeps its id, so a
    plan patch rewrites only the tiles holding delta coordinates or moved
    survivors and never takes an O(nnz) pass over the perm arrays.
    """

    nnz_old: int
    ki: int  # insert count
    removed: np.ndarray  # sorted old positions removed
    L: int  # final entry count
    targets: np.ndarray  # ascending new positions receiving the fill queue
    tail_surv: np.ndarray  # ascending old positions of moved survivors

    # fill queue = [insert 0..ki-1] + [tail survivors ascending]; queue[j]
    # lands at targets[j], so insert j's id is targets[j] and moved
    # survivor i's new id is targets[ki + i].


def _id_plan(removed: np.ndarray, nnz_old: int, ki: int) -> _IdPlan:
    kr = len(removed)
    L = nnz_old - kr + ki
    holes_below = removed[removed < L]
    extra = np.arange(nnz_old, L, dtype=np.int64)  # empty unless ki > kr
    targets = np.concatenate([holes_below, extra])
    q = np.arange(max(L, 0), nnz_old, dtype=np.int64)
    tail_surv = q[~_present(removed, q)]
    return _IdPlan(nnz_old, ki, removed, L, targets, tail_surv)


def _map_ids(ids: np.ndarray, p: _IdPlan) -> np.ndarray:
    """New ids for surviving old ids (identity except moved survivors)."""
    if p.tail_surv.size == 0 or ids.size == 0:
        return ids
    idx = np.searchsorted(p.tail_surv, ids)
    idxc = np.minimum(idx, len(p.tail_surv) - 1)
    moved = p.tail_surv[idxc] == ids
    out = ids.copy()
    out[moved] = p.targets[p.ki + idxc[moved]]
    return out


def _fill_array(a: np.ndarray, ins, p: _IdPlan) -> np.ndarray:
    """Apply the id plan to a per-entry array: keep the sub-``L`` prefix,
    scatter the fill queue (inserts then moved survivors) into targets."""
    out = np.empty(p.L, a.dtype)
    c = min(p.L, p.nnz_old)
    out[:c] = a[:c]
    out[p.targets] = np.concatenate(
        [np.asarray(ins, a.dtype), a[p.tail_surv]]
    )
    return out


# ---------------------------------------------------------------------------
# COO reference semantics (the parity anchor)
# ---------------------------------------------------------------------------
def apply_coo(coo: COOMatrix, delta: DeltaBatch, check: bool = True) -> COOMatrix:
    """Canonical final COO under the hole-filling ordering (see
    :class:`_IdPlan`).  Every ``apply_delta`` overload byte-matches its
    layer's builder applied to this result."""
    if check:
        check_delta(delta, coo=coo)
    span = coo.shape[1]
    ekey = _keys(coo.rows, coo.cols, span)
    rkey = np.sort(_keys(delta.rem_rows, delta.rem_cols, span))
    removed = np.flatnonzero(_present(rkey, ekey)).astype(np.int64)
    p = _id_plan(removed, coo.nnz, delta.n_insert)
    return COOMatrix(
        rows=_fill_array(coo.rows, delta.ins_rows, p),
        cols=_fill_array(coo.cols, delta.ins_cols, p),
        vals=_fill_array(coo.vals, delta.ins_vals, p),
        shape=coo.shape,
    )


# ---------------------------------------------------------------------------
# splice core (shared by every plan layer)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Entries:
    """Flat per-entry view of the affected tiles, in build order
    (ascending tile key; within a tile by (local col, local row, id))."""

    tk: np.ndarray  # int64 — tile key trow * nbc + tcol
    lrow: np.ndarray
    lcol: np.ndarray
    vals: np.ndarray
    ids: np.ndarray  # int64 source COO positions


def _delta_tile_keys(delta: DeltaBatch, T: int, nbc: int):
    itk = (delta.ins_rows.astype(np.int64) // T) * nbc + (
        delta.ins_cols.astype(np.int64) // T
    )
    rtk = (delta.rem_rows.astype(np.int64) // T) * nbc + (
        delta.rem_cols.astype(np.int64) // T
    )
    return itk, rtk, np.unique(np.concatenate([itk, rtk]))


def _find_removed(e: _Entries, delta: DeltaBatch, rtk, T: int) -> np.ndarray:
    """Sorted source ids of the entries the delta removes, from the
    gathered delta-tile entries; raises on removes that match nothing."""
    TT = T * T
    ekey = e.tk * TT + e.lcol * T + e.lrow  # globally non-decreasing
    rkey = rtk * TT + (delta.rem_cols.astype(np.int64) % T) * T + (
        delta.rem_rows.astype(np.int64) % T
    )
    hit = np.searchsorted(ekey, rkey, side="right") > np.searchsorted(
        ekey, rkey, side="left"
    )
    if not hit.all():
        i = int(np.flatnonzero(~hit)[0])
        raise ValueError(
            f"delta removes absent edge ({int(delta.rem_rows[i])}, "
            f"{int(delta.rem_cols[i])})"
        )
    return np.sort(e.ids[_present(np.sort(rkey), ekey)])


def _moved_tile_keys(
    p: _IdPlan, source, perm_views, T: int, nbc: int
) -> np.ndarray:
    """Tile keys holding the moved tail survivors.

    With ``source`` (the pre-delta COO edge arrays) this is pure
    coordinate arithmetic on ≤ ``n_remove`` positions.  Without it, the
    perm arrays are scanned blockwise (bounded scratch) for slots whose
    id is a moved survivor — ``perm_views`` is ``[(ck, perm_2d), ...]``.
    """
    if source is not None:
        rr = np.asarray(source.rows)[p.tail_surv].astype(np.int64)
        cc = np.asarray(source.cols)[p.tail_surv].astype(np.int64)
        return np.unique((rr // T) * nbc + (cc // T))
    vals = p.tail_surv  # sorted ascending
    keys = []
    block = 1 << 22
    for ck, perm in perm_views:
        flat = perm.reshape(-1)
        chunks = []
        for st in range(0, flat.size, block):
            blk = flat[st : st + block]
            idx = np.minimum(np.searchsorted(vals, blk), len(vals) - 1)
            hits = np.flatnonzero(vals[idx] == blk)
            if hits.size:
                chunks.append((hits + st) // perm.shape[1])
        if chunks:
            keys.append(ck[np.unique(np.concatenate(chunks))])
    return np.unique(np.concatenate(keys)) if keys else np.zeros(0, np.int64)


def _splice_entries(
    e: _Entries, delta: DeltaBatch, itk, p: _IdPlan, T: int
) -> _Entries:
    """Remove + insert + re-id + re-sort the affected tiles' entries.

    ``e`` must contain every entry the delta removes AND every moved tail
    survivor (their ids change).  Raises on inserts of a coordinate still
    present after the removes."""
    TT = T * T
    ekey = e.tk * TT + e.lcol * T + e.lrow  # globally non-decreasing
    removed = _present(p.removed, e.ids)

    skey = ekey[~removed]
    ikey = itk * TT + (delta.ins_cols.astype(np.int64) % T) * T + (
        delta.ins_rows.astype(np.int64) % T
    )
    clash = np.searchsorted(skey, ikey, side="right") > np.searchsorted(
        skey, ikey, side="left"
    )
    if clash.any():
        i = int(np.flatnonzero(clash)[0])
        raise ValueError(
            f"delta inserts already-present edge ({int(delta.ins_rows[i])}, "
            f"{int(delta.ins_cols[i])}); remove it in the same batch to "
            "update its value"
        )
    if len(ikey) != len(np.unique(ikey)):
        raise ValueError("duplicate insert coordinates in delta")

    # survivors keep their ids (moved tail survivors re-mapped), insert j
    # takes targets[j]; one lexsort restores the builder's (tile key,
    # local col, local row, source id) entry order
    tk = np.concatenate([e.tk[~removed], itk])
    lrow = np.concatenate(
        [e.lrow[~removed], (delta.ins_rows.astype(np.int64) % T).astype(e.lrow.dtype)]
    )
    lcol = np.concatenate(
        [e.lcol[~removed], (delta.ins_cols.astype(np.int64) % T).astype(e.lcol.dtype)]
    )
    vals = np.concatenate(
        [e.vals[~removed], delta.ins_vals.astype(e.vals.dtype)]
    )
    ids = np.concatenate(
        [_map_ids(e.ids[~removed], p), p.targets[: p.ki]]
    )
    o = np.lexsort((ids, lrow, lcol, tk))
    return _Entries(tk[o], lrow[o], lcol[o], vals[o], ids[o])


@dataclasses.dataclass
class _Chunks:
    """Chunked (padded [k, cap]) form of a set of entries — the builder's
    emission arithmetic applied to just the affected tiles."""

    ck: np.ndarray  # int64[k] tile key per chunk
    local: np.ndarray  # int64[k] chain index within tile
    nnz: np.ndarray  # int32[k]
    rows: np.ndarray  # [k, cap]
    cols: np.ndarray
    vals: np.ndarray
    perm: np.ndarray  # [k, cap] source ids, -1 pad

    def __len__(self) -> int:
        return len(self.ck)


def _chunk_entries(e: _Entries, cap: int, dtypes) -> _Chunks:
    """Re-emit entries as capacity-``cap`` chunks — identical arithmetic
    to ``coo_to_scv_tiles``: entry j of a tile lands in chain chunk
    ``j // cap``, slot ``j % cap``; zero / -1 padding."""
    rdt, cdt, vdt, ndt, pdt = dtypes
    ne = len(e.tk)
    if ne:
        tstart = np.flatnonzero(np.r_[True, e.tk[1:] != e.tk[:-1]])
    else:
        tstart = np.zeros(0, np.int64)
    utk = e.tk[tstart]
    tcounts = np.diff(np.append(tstart, ne)).astype(np.int64)
    n_ch = -(-tcounts // cap)
    k = int(n_ch.sum()) if len(n_ch) else 0
    first = np.cumsum(n_ch) - n_ch
    ck = np.repeat(utk, n_ch)
    local = np.arange(k, dtype=np.int64) - np.repeat(first, n_ch)
    nnz = (
        np.minimum(cap, np.repeat(tcounts, n_ch) - local * cap).astype(ndt)
        if k
        else np.zeros(0, ndt)
    )
    pos = np.arange(ne, dtype=np.int64) - np.repeat(tstart, tcounts)
    inv = np.repeat(np.arange(len(utk), dtype=np.int64), tcounts)
    dst = (first[inv] + pos // cap) * cap + pos % cap
    rows = np.zeros(k * cap, rdt)
    cols = np.zeros(k * cap, cdt)
    vals = np.zeros(k * cap, vdt)
    perm = np.full(k * cap, -1, pdt)
    rows[dst] = e.lrow
    cols[dst] = e.lcol
    vals[dst] = e.vals
    perm[dst] = e.ids
    return _Chunks(
        ck, local, nnz,
        rows.reshape(k, cap), cols.reshape(k, cap),
        vals.reshape(k, cap), perm.reshape(k, cap),
    )


def _chunk_locals(ck: np.ndarray) -> np.ndarray:
    """Chain index of each chunk within its (consecutive-equal-key) tile."""
    k = len(ck)
    if not k:
        return np.zeros(0, np.int64)
    run = np.flatnonzero(np.r_[True, ck[1:] != ck[:-1]])
    return np.arange(k, dtype=np.int64) - np.repeat(
        run, np.diff(np.append(run, k))
    )


def _affected_chunk_idx(ck: np.ndarray, aff_keys: np.ndarray) -> np.ndarray:
    """Indices of chunks whose tile key is in ``aff_keys`` (ck sorted)."""
    lo = np.searchsorted(ck, aff_keys, side="left")
    hi = np.searchsorted(ck, aff_keys, side="right")
    spans = [np.arange(a, b) for a, b in zip(lo, hi) if b > a]
    return np.concatenate(spans) if spans else np.zeros(0, np.int64)


def _gather_entries(ch_nnz, ch_ck, rows, cols, vals, perm, idx) -> _Entries:
    """Flatten the real slots of chunks ``idx`` in stored order."""
    a_nnz = ch_nnz[idx].astype(np.int64)
    keep = np.arange(rows.shape[1])[None, :] < a_nnz[:, None]
    return _Entries(
        tk=np.repeat(ch_ck[idx], a_nnz),
        lrow=rows[idx][keep].astype(np.int64),
        lcol=cols[idx][keep].astype(np.int64),
        vals=vals[idx][keep],
        ids=perm[idx][keep].astype(np.int64),
    )


def _merge_chunks(ck_u, local_u, ck_n, local_n) -> tuple[np.ndarray, np.ndarray]:
    """Output positions for the unaffected (u) and new (n) chunk lists
    under the global (tile key, chain index) schedule order.  Both inputs
    are sorted and share no tile key, so a two-way searchsorted merge is
    exact."""
    span = int(
        max(local_u.max() if len(local_u) else 0,
            local_n.max() if len(local_n) else 0)
    ) + 1
    ku = ck_u * span + local_u
    kn = ck_n * span + local_n
    pos_u = np.arange(len(ku), dtype=np.int64) + np.searchsorted(kn, ku)
    pos_n = np.arange(len(kn), dtype=np.int64) + np.searchsorted(ku, kn)
    return pos_u, pos_n


# ---------------------------------------------------------------------------
# SCVTiles patch
# ---------------------------------------------------------------------------
def _tiles_geometry(t) -> tuple[int, int, int]:
    T = int(t.tile)
    m, n = t.shape
    return T, -(-m // T), -(-n // T)  # T, n_block_rows, n_block_cols


def _apply_tiles(
    t: SCVTiles, delta: DeltaBatch, inplace: bool, source=None
) -> tuple[SCVTiles, _IdPlan]:
    if t.perm is None:
        raise ValueError(
            "apply_delta needs the perm bookkeeping; build tiles with "
            "coo_to_scv_tiles (perm enabled) first"
        )
    nnz = np.asarray(t.nnz_in_tile)
    if len(nnz) and int(nnz.min()) <= 0:
        raise ValueError(
            "apply_delta on SCVTiles requires build-form tiles (no zero-nnz "
            "tiles); patch plans, not composites, for coverage-dummy handling"
        )
    T, _, nbc = _tiles_geometry(t)
    cap = int(t.cap)
    ck = t.tile_row.astype(np.int64) * nbc + t.tile_col.astype(np.int64)
    if len(ck) > 1 and not np.all(ck[1:] >= ck[:-1]):
        raise ValueError("tiles are not in schedule (ascending tile key) order")
    itk, rtk, aff = _delta_tile_keys(delta, T, nbc)
    aff_idx = _affected_chunk_idx(ck, aff)
    n_entries = int(nnz.sum())

    e = _gather_entries(nnz, ck, t.rows, t.cols, t.vals, t.perm, aff_idx)
    p = _id_plan(_find_removed(e, delta, rtk, T), n_entries, delta.n_insert)
    if p.L >= 2**31:
        raise ValueError("patched entry count overflows int32 source ids")
    if p.tail_surv.size:
        # moved tail survivors change id: their tiles join the affected set
        moved = _moved_tile_keys(p, source, [(ck, t.perm)], T, nbc)
        aff = np.union1d(aff, moved)
        aff_idx = _affected_chunk_idx(ck, aff)
        e = _gather_entries(nnz, ck, t.rows, t.cols, t.vals, t.perm, aff_idx)
    merged = _splice_entries(e, delta, itk, p, T)
    new = _chunk_entries(
        merged, cap,
        (t.rows.dtype, t.cols.dtype, t.vals.dtype, nnz.dtype, t.perm.dtype),
    )

    local = _chunk_locals(ck)
    layout_equal = len(new) == len(aff_idx) and np.array_equal(
        new.ck, ck[aff_idx]
    ) and np.array_equal(new.local, local[aff_idx])

    if layout_equal:
        if inplace:
            tr, tc = t.tile_row, t.tile_col
            rows, cols, vals = t.rows, t.cols, t.vals
            nz, perm = t.nnz_in_tile, t.perm
        else:
            # functional: copy only the leaves the patch writes;
            # tile_row / tile_col are layout — unchanged here — and stay
            # shared by identity (same contract as untouched bucketed
            # segments).  Callers that hold aliases still see immutable
            # history; callers that own their tiles should pass
            # ``inplace=True`` — the zero-copy hot path.
            tr, tc = t.tile_row, t.tile_col
            rows, cols, vals = t.rows.copy(), t.cols.copy(), t.vals.copy()
            nz, perm = t.nnz_in_tile.copy(), t.perm.copy()
        rows[aff_idx] = new.rows
        cols[aff_idx] = new.cols
        vals[aff_idx] = new.vals
        nz[aff_idx] = new.nnz
        perm[aff_idx] = new.perm
        if inplace:
            return t, p
        return dataclasses.replace(
            t, tile_row=tr, tile_col=tc, rows=rows, cols=cols, vals=vals,
            nnz_in_tile=nz, perm=perm,
        ), p

    # chain lengths changed (tile birth/death or a crossed chunk boundary):
    # interleave the surviving chunks with the re-emitted ones
    un = np.ones(len(ck), bool)
    un[aff_idx] = False
    un_idx = np.flatnonzero(un)
    pos_u, pos_n = _merge_chunks(ck[un_idx], local[un_idx], new.ck, new.local)
    k2 = len(un_idx) + len(new)

    def out(shape, dtype, fill=0):
        return np.full(shape, fill, dtype) if fill else np.zeros(shape, dtype)

    tile_row = out(k2, t.tile_row.dtype)
    tile_col = out(k2, t.tile_col.dtype)
    rows = out((k2, cap), t.rows.dtype)
    cols = out((k2, cap), t.cols.dtype)
    vals = out((k2, cap), t.vals.dtype)
    nz = out(k2, nnz.dtype)
    perm = out((k2, cap), t.perm.dtype, fill=-1)
    tile_row[pos_u] = t.tile_row[un_idx]
    tile_col[pos_u] = t.tile_col[un_idx]
    rows[pos_u] = t.rows[un_idx]
    cols[pos_u] = t.cols[un_idx]
    vals[pos_u] = t.vals[un_idx]
    nz[pos_u] = nnz[un_idx]
    perm[pos_u] = t.perm[un_idx]  # survivors outside affected tiles keep ids
    tile_row[pos_n] = (new.ck // nbc).astype(t.tile_row.dtype)
    tile_col[pos_n] = (new.ck % nbc).astype(t.tile_col.dtype)
    rows[pos_n] = new.rows
    cols[pos_n] = new.cols
    vals[pos_n] = new.vals
    nz[pos_n] = new.nnz
    perm[pos_n] = new.perm
    return dataclasses.replace(
        t, tile_row=tile_row, tile_col=tile_col, rows=rows, cols=cols,
        vals=vals, nnz_in_tile=nz, perm=perm,
    ), p


# ---------------------------------------------------------------------------
# SCVPlan patch (coverage-dummy tail maintained)
# ---------------------------------------------------------------------------
def _real_prefix(nnz: np.ndarray) -> int:
    """Length of the real-tile prefix; built plans keep every zero-nnz
    coverage dummy in one trailing tail."""
    nt_real = int(np.count_nonzero(nnz))
    if nt_real and int(nnz[:nt_real].min()) <= 0:
        raise ValueError(
            "apply_delta needs a built plan (zero-nnz tiles must form a "
            "trailing coverage tail); serving composites are not patchable "
            "— patch the member plans and reassemble"
        )
    return nt_real

def _coverage_tail(tile_row_real: np.ndarray, nbr: int) -> np.ndarray:
    """Block-rows needing a coverage dummy, ascending — matching
    ``ensure_row_coverage``'s append order."""
    counts = np.bincount(tile_row_real.astype(np.int64), minlength=nbr)
    return np.flatnonzero(counts[:nbr] == 0)


def _apply_plan_arrays(
    tile_row, tile_col, rows, cols, vals, nnz, perm,
    T: int, cap: int, shape, order: str, delta: DeltaBatch, source=None,
):
    """Patch one plan's host arrays (dummy tail maintained).  Returns the
    new arrays plus the delta's :class:`_IdPlan`."""
    m, n = shape
    nbr = -(-m // T)
    nt_real = _real_prefix(nnz)
    view = SCVTiles(
        tile_row=tile_row[:nt_real], tile_col=tile_col[:nt_real],
        rows=rows[:nt_real], cols=cols[:nt_real], vals=vals[:nt_real],
        nnz_in_tile=nnz[:nt_real], tile=T, cap=cap, shape=tuple(shape),
        order=order, perm=perm[:nt_real],
    )
    patched, idp = _apply_tiles(view, delta, inplace=False, source=source)

    missing = _coverage_tail(patched.tile_row, nbr)
    kd = len(missing)
    return (
        np.concatenate([patched.tile_row, missing.astype(tile_row.dtype)]),
        np.concatenate([patched.tile_col, np.zeros(kd, tile_col.dtype)]),
        np.concatenate([patched.rows, np.zeros((kd, cap), rows.dtype)]),
        np.concatenate([patched.cols, np.zeros((kd, cap), cols.dtype)]),
        np.concatenate([patched.vals, np.zeros((kd, cap), vals.dtype)]),
        np.concatenate([patched.nnz_in_tile, np.zeros(kd, nnz.dtype)]),
        np.concatenate([patched.perm, np.full((kd, cap), -1, perm.dtype)]),
        idp,
    )


def _apply_plan(
    p: SCVPlan, delta: DeltaBatch, source=None
) -> tuple[SCVPlan, _IdPlan]:
    import jax.numpy as jnp

    if p.perm is None:
        raise ValueError(
            "apply_delta needs the plan's perm leaf; this plan was built "
            "without it (with_perm disabled)"
        )
    tr, tc, rs, cs, vs, nz, pm = (
        np.asarray(p.tile_row), np.asarray(p.tile_col), np.asarray(p.rows),
        np.asarray(p.cols), np.asarray(p.vals), np.asarray(p.nnz_in_tile),
        np.asarray(p.perm),
    )
    tr2, tc2, rs2, cs2, vs2, nz2, pm2, idp = _apply_plan_arrays(
        tr, tc, rs, cs, vs, nz, pm, p.tile, p.cap, p.shape, p.order, delta,
        source=source,
    )
    return (
        dataclasses.replace(
            p,
            tile_row=jnp.asarray(tr2), tile_col=jnp.asarray(tc2),
            rows=jnp.asarray(rs2), cols=jnp.asarray(cs2),
            vals=jnp.asarray(vs2), nnz_in_tile=jnp.asarray(nz2),
            perm=jnp.asarray(pm2),
        ),
        idp,
    )


# ---------------------------------------------------------------------------
# SCVBucketedPlan patch (ladder-crossing re-bucket only)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _SegView:
    """Host snapshot of one segment's real (non-dummy) chunks."""

    tile_row: np.ndarray
    tile_col: np.ndarray
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    nnz: np.ndarray
    perm: np.ndarray
    ck: np.ndarray  # int64 tile keys, ascending
    aff_idx: np.ndarray  # chunk indices belonging to affected tiles


def _seg_view(s: SCVPlan, nbc: int, aff_keys: np.ndarray) -> _SegView:
    if s.perm is None:
        raise ValueError(
            "apply_delta needs the plan's perm leaf; this plan was built "
            "without it (with_perm disabled)"
        )
    nz = np.asarray(s.nnz_in_tile)
    k = _real_prefix(nz)
    tr = np.asarray(s.tile_row)[:k]
    tc = np.asarray(s.tile_col)[:k]
    ck = tr.astype(np.int64) * nbc + tc.astype(np.int64)
    if len(ck) > 1 and not np.all(ck[1:] >= ck[:-1]):
        raise ValueError(
            "segment tiles are not in schedule (ascending tile key) order"
        )
    return _SegView(
        tile_row=tr, tile_col=tc,
        rows=np.asarray(s.rows)[:k], cols=np.asarray(s.cols)[:k],
        vals=np.asarray(s.vals)[:k], nnz=nz[:k],
        perm=np.asarray(s.perm)[:k], ck=ck,
        aff_idx=_affected_chunk_idx(ck, aff_keys),
    )


def _check_chain_split(views: list[_SegView], cap_build: int) -> None:
    """Affected tiles must obey the chain contract reconstruction relies
    on: at most one chunk per tile below the build capacity, and it is
    the chain's last.  (Plans built via ``build_graph(bucket_caps=...)``
    — chain-split at ``caps[-1]`` — always satisfy this.)

    Chunks are ordered descending-cap exactly as ``_gather_bucketed``
    reconstructs chains: full chunks live in the top segment, the partial
    tail wherever its nnz bucketed it — ascending order would misread a
    low-bucketed tail as a mid-chain partial chunk."""
    ckc = np.concatenate([v.ck[v.aff_idx] for v in reversed(views)])
    nzc = np.concatenate([v.nnz[v.aff_idx] for v in reversed(views)])
    if not len(ckc):
        return
    o = np.argsort(ckc, kind="stable")
    ckc, nzc = ckc[o], nzc[o]
    last = np.r_[ckc[1:] != ckc[:-1], True]
    bad = nzc[~last] != cap_build
    if bad.any():
        raise ValueError(
            "bucketed plan was not chain-split at caps[-1] "
            f"({cap_build}): an affected tile has a non-final chunk with "
            f"nnz={int(nzc[~last][bad][0])}; rebuild via "
            "build_graph(bucket_caps=...) before applying deltas"
        )


def _gather_bucketed(views: list[_SegView]) -> _Entries:
    """Reconstruct the affected tiles' entry chains: all full chunks live
    in the top segment in chain order, the (unique) partial chunk is the
    chain's last wherever its nnz bucketed it — so a descending-cap
    concatenation followed by a stable sort on tile key restores every
    chain exactly."""
    parts = [
        _gather_entries(v.nnz, v.ck, v.rows, v.cols, v.vals, v.perm, v.aff_idx)
        for v in reversed(views)
    ]
    e = _Entries(
        tk=np.concatenate([p.tk for p in parts]),
        lrow=np.concatenate([p.lrow for p in parts]),
        lcol=np.concatenate([p.lcol for p in parts]),
        vals=np.concatenate([p.vals for p in parts]),
        ids=np.concatenate([p.ids for p in parts]),
    )
    o = np.argsort(e.tk, kind="stable")
    return _Entries(e.tk[o], e.lrow[o], e.lcol[o], e.vals[o], e.ids[o])


def _apply_bucketed(
    bp: SCVBucketedPlan, delta: DeltaBatch, source=None
) -> tuple[SCVBucketedPlan, _IdPlan]:
    import jax.numpy as jnp

    caps = bp.caps
    cap_build = caps[-1]
    T = bp.tile
    m, n = bp.shape
    nbr, nbc = -(-m // T), -(-n // T)
    itk, rtk, aff = _delta_tile_keys(delta, T, nbc)
    views = [_seg_view(s, nbc, aff) for s in bp.segments]
    _check_chain_split(views, cap_build)
    e = _gather_bucketed(views)

    n_entries = int(sum(int(v.nnz.sum()) for v in views))
    p = _id_plan(_find_removed(e, delta, rtk, T), n_entries, delta.n_insert)
    if p.L >= 2**31:
        raise ValueError("patched entry count overflows the int32 perm leaf")
    if p.tail_surv.size:
        # moved tail survivors change id: their tiles join the affected set
        moved = _moved_tile_keys(
            p, source, [(v.ck, v.perm) for v in views], T, nbc
        )
        aff = np.union1d(aff, moved)
        for v in views:
            v.aff_idx = _affected_chunk_idx(v.ck, aff)
        _check_chain_split(views, cap_build)
        e = _gather_bucketed(views)
    merged = _splice_entries(e, delta, itk, p, T)
    v0 = views[-1]
    newc = _chunk_entries(
        merged, cap_build,
        (v0.rows.dtype, v0.cols.dtype, v0.vals.dtype, v0.nnz.dtype,
         v0.perm.dtype),
    )
    bucket_of = np.searchsorted(caps, newc.nnz)  # nnz == cap -> that bucket

    out_segments: list[SCVPlan] = []
    for b, (s, v) in enumerate(zip(bp.segments, views)):
        cap_b = caps[b]
        sel = bucket_of == b
        if not sel.any() and not len(v.aff_idx):
            # the delta never touches this segment's chunk set: its device
            # arrays survive by identity (jit traces, sharded spans, cache
            # bytes all untouched) — the hole-filling ordering guarantees
            # every id outside the affected tiles is unchanged
            out_segments.append(s)
            continue
        un = np.ones(len(v.ck), bool)
        un[v.aff_idx] = False
        un_idx = np.flatnonzero(un)
        # affected tiles lose *all* their chunks in every segment, so the
        # surviving chunks keep complete chains and their within-segment
        # chain indices stay valid merge keys
        local_u = _chunk_locals(v.ck)[un_idx]
        ck_n = newc.ck[sel]
        local_n = _chunk_locals(ck_n)
        pos_u, pos_n = _merge_chunks(v.ck[un_idx], local_u, ck_n, local_n)
        k2 = len(un_idx) + int(sel.sum())

        tile_row = np.zeros(k2, v.tile_row.dtype)
        tile_col = np.zeros(k2, v.tile_col.dtype)
        rows = np.zeros((k2, cap_b), v.rows.dtype)
        cols = np.zeros((k2, cap_b), v.cols.dtype)
        vals = np.zeros((k2, cap_b), v.vals.dtype)
        nz = np.zeros(k2, v.nnz.dtype)
        perm = np.full((k2, cap_b), -1, v.perm.dtype)
        tile_row[pos_u] = v.tile_row[un_idx]
        tile_col[pos_u] = v.tile_col[un_idx]
        rows[pos_u] = v.rows[un_idx]
        cols[pos_u] = v.cols[un_idx]
        vals[pos_u] = v.vals[un_idx]
        nz[pos_u] = v.nnz[un_idx]
        perm[pos_u] = v.perm[un_idx]  # ids outside affected tiles unchanged
        # new chunks were emitted at cap_build; the segment stores the
        # front-packed prefix at its own cap (bucket_tiles' fit rule)
        tile_row[pos_n] = (ck_n // nbc).astype(v.tile_row.dtype)
        tile_col[pos_n] = (ck_n % nbc).astype(v.tile_col.dtype)
        rows[pos_n] = newc.rows[sel][:, :cap_b]
        cols[pos_n] = newc.cols[sel][:, :cap_b]
        vals[pos_n] = newc.vals[sel][:, :cap_b]
        nz[pos_n] = newc.nnz[sel]
        perm[pos_n] = newc.perm[sel][:, :cap_b]

        # coverage-free chaining: only segment 0 owes a coverage-dummy
        # tail (the builder emits coverage once per plan; later segments
        # chain through the aliased accumulator)
        missing = _coverage_tail(tile_row, nbr) if b == 0 \
            else np.zeros(0, np.int64)
        kd = len(missing)
        out_segments.append(
            dataclasses.replace(
                s,
                tile_row=jnp.asarray(
                    np.concatenate([tile_row, missing.astype(tile_row.dtype)])
                ),
                tile_col=jnp.asarray(
                    np.concatenate([tile_col, np.zeros(kd, tile_col.dtype)])
                ),
                rows=jnp.asarray(
                    np.concatenate([rows, np.zeros((kd, cap_b), rows.dtype)])
                ),
                cols=jnp.asarray(
                    np.concatenate([cols, np.zeros((kd, cap_b), cols.dtype)])
                ),
                vals=jnp.asarray(
                    np.concatenate([vals, np.zeros((kd, cap_b), vals.dtype)])
                ),
                nnz_in_tile=jnp.asarray(
                    np.concatenate([nz, np.zeros(kd, nz.dtype)])
                ),
                perm=jnp.asarray(
                    np.concatenate([perm, np.full((kd, cap_b), -1, perm.dtype)])
                ),
            )
        )
    return SCVBucketedPlan(tuple(out_segments)), p


# ---------------------------------------------------------------------------
# Graph patch (plan + COO edge arrays)
# ---------------------------------------------------------------------------
def _apply_graph(g, delta: DeltaBatch):
    import jax.numpy as jnp

    # the Graph carries its own pre-delta edge arrays — use them as the
    # moved-survivor source so the plan patch never falls back to the
    # perm-scan
    source = g if g.rows is not None else None
    if isinstance(g.plan, SCVBucketedPlan):
        plan2, idp = _apply_bucketed(g.plan, delta, source=source)
    elif isinstance(g.plan, SCVPlan):
        plan2, idp = _apply_plan(g.plan, delta, source=source)
    else:
        raise TypeError(
            f"cannot patch a Graph holding {type(g.plan).__name__}; patch "
            "before device placement (re-shard the patched plan instead)"
        )
    rows = cols = vals = None
    if g.rows is not None:
        r = np.asarray(g.rows)
        c = np.asarray(g.cols)
        w = np.asarray(g.vals)
        rows = jnp.asarray(_fill_array(r, delta.ins_rows, idp))
        cols = jnp.asarray(_fill_array(c, delta.ins_cols, idp))
        vals = jnp.asarray(_fill_array(w, delta.ins_vals, idp))
    return dataclasses.replace(g, plan=plan2, rows=rows, cols=cols, vals=vals)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------
def apply_delta(
    obj: Any,
    delta: DeltaBatch,
    *,
    inplace: bool = False,
    check: bool = True,
    source: Any = None,
):
    """Patch ``obj`` (SCVTiles / SCVPlan / SCVBucketedPlan / models.gnn
    Graph) with ``delta``; byte-identical to rebuilding the layer from
    ``apply_coo(coo, delta)``.

    ``inplace=True`` (SCVTiles only) mutates the arrays when the chunk
    layout is unchanged — the zero-allocation hot path for streams of
    slack-absorbed updates; a layout change (tile birth/death, chain
    growth) still returns a fresh object.  The functional default copies
    only the leaves the patch writes (layout leaves are shared by
    identity) — use it when other references to the tiles must keep
    seeing pre-delta bytes.  Plan layers always return new pytrees but
    reuse untouched device leaves (bucketed segments the delta never
    touches keep their arrays by identity).

    ``source`` (optional, anything with ``.rows`` / ``.cols`` — e.g. the
    pre-delta ``COOMatrix``) lets a net-shrinking delta locate the moved
    tail survivors by coordinate arithmetic instead of scanning the perm
    arrays.  Graphs use their own edge arrays and ignore it.
    """
    plan_shape = getattr(obj, "shape", None)
    if plan_shape is None and hasattr(obj, "plan"):  # models.gnn.Graph
        plan_shape = obj.plan.shape
    if check:
        check_delta(delta, shape=plan_shape)
    if len(delta) == 0:
        return obj
    if isinstance(obj, SCVTiles):
        return _apply_tiles(obj, delta, inplace=inplace, source=source)[0]
    if inplace:
        raise ValueError(
            "inplace patching is only supported for SCVTiles (device plan "
            "leaves are immutable)"
        )
    if isinstance(obj, SCVBucketedPlan):
        return _apply_bucketed(obj, delta, source=source)[0]
    if isinstance(obj, SCVPlan):
        return _apply_plan(obj, delta, source=source)[0]
    if hasattr(obj, "plan") and hasattr(obj, "n_nodes"):
        return _apply_graph(obj, delta)
    raise TypeError(f"apply_delta cannot patch {type(obj).__name__}")
