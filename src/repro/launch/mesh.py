"""Production meshes.  A FUNCTION, not a module constant — importing this
module never touches jax device state, and elastic re-meshing
(train/fault.py) rebuilds meshes with different chip counts at runtime.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); multi_pod adds a leading
    2-pod axis (512 chips) that carries only DP gradient traffic."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(n_chips: int, model_parallel: int = 16, n_pods: int = 1):
    """Elastic variant: largest mesh over surviving chips (fault.py)."""
    per_pod = n_chips // n_pods
    data = max(1, per_pod // model_parallel)
    if n_pods > 1:
        return jax.make_mesh(
            (n_pods, data, model_parallel), ("pod", "data", "model")
        )
    return jax.make_mesh((data, model_parallel), ("data", "model"))
