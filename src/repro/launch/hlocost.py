"""Trip-count-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless
of its trip count (verified empirically — a 10-step scan reports the same
flops as a 1-step scan).  Every model here scans over layers, KV blocks,
SSD chunks and loss chunks, so we parse the post-optimization HLO
ourselves and multiply each computation's costs by the product of
enclosing loop trip counts (``backend_config={"known_trip_count":{"n":N}}``
on each while op, with a cond-constant fallback).

Extracted per device:
  * flops              — dot ops: 2 x prod(output) x contracted size
                         (+ convolutions, rare here); elementwise ignored
                         (sub-% for these models)
  * hbm_bytes          — Σ over non-fused top-level ops of (operand +
                         output buffer sizes): the post-fusion HLO's
                         memory-traffic model (each fusion reads operands
                         from HBM, writes its output)
  * collective_bytes   — per collective kind, output-shape bytes x trips
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    """First shape's dims in a type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_type: str
    rest: str  # operand list + attrs
    line: str


def parse_computations(hlo: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur = None
    for line in hlo.splitlines():
        ls = line.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", ls.strip())
        if header and (ls.strip().endswith("{")):
            cur = header.group(1)
            comps[cur] = []
            if ls.strip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if cur is None:
            continue
        if ls.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(ls)
        if m:
            comps[cur].append(Op(m.group(1), m.group(3), m.group(2), m.group(4), ls))
    return comps


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = None
    collective_counts: dict = None

    def __post_init__(self):
        if self.collective_bytes is None:
            self.collective_bytes = dict.fromkeys(COLLECTIVES, 0.0)
        if self.collective_counts is None:
            self.collective_counts = dict.fromkeys(COLLECTIVES, 0.0)


# ops whose operands/outputs are views, not HBM traffic
_VIEW_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id",
}


def analyze(hlo: str) -> CostTotals:
    comps = parse_computations(hlo)
    # shape table: op name -> output type string (names unique post-opt)
    shapes: dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.out_type
    # parameters: "%p = f32[..] parameter(0)" are ops too (covered above)

    trip_cache: dict[str, int] = {}

    def trip_count(op: Op) -> int:
        m = _TRIP_RE.search(op.line)
        if m:
            return int(m.group(1))
        # fallback: largest s32 constant in the condition computation
        mc = re.search(r"condition=%([\w.\-]+)", op.line)
        if mc and mc.group(1) in comps:
            best = 1
            for o in comps[mc.group(1)]:
                for c in re.findall(r"constant\((\d+)\)", o.line):
                    best = max(best, int(c))
            return best
        return 1

    # which computations are fusion bodies (their ops are not HBM traffic)
    fusion_bodies = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                for called in _CALLED_RE.findall(op.line):
                    fusion_bodies.add(called)

    totals = CostTotals()
    visited_stack = []

    def dot_flops(op: Op) -> float:
        out_dims = _shape_dims(op.out_type) or []
        out_n = 1
        for d in out_dims:
            out_n *= d
        lhs = _OPERAND_RE.search(op.rest)
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        k = 1
        if lhs and mcd and lhs.group(1) in shapes:
            ldims = _shape_dims(shapes[lhs.group(1)]) or []
            for idx in mcd.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    k *= ldims[int(idx)]
        return 2.0 * out_n * k

    def conv_flops(op: Op) -> float:
        # approximate: 2 x prod(output) x (kernel spatial x in_channels)
        out_dims = _shape_dims(op.out_type) or []
        out_n = 1
        for d in out_dims:
            out_n *= d
        ops_names = _OPERAND_RE.findall(op.rest)
        k = 1
        if len(ops_names) >= 2 and ops_names[1] in shapes:
            kd = _shape_dims(shapes[ops_names[1]]) or []
            for d in kd[:-1]:  # all but output-feature dim (layout-dependent approx)
                k *= d
        return 2.0 * out_n * k

    def _fusion_param_read_bytes(body: str, param_idx: int, full: int) -> int:
        """If fusion body only dynamic-slices from parameter i, the real
        read is the slice, not the whole buffer (scan weight slicing)."""
        if body not in comps:
            return full
        pname = None
        for o in comps[body]:
            if o.kind == "parameter" and o.rest.startswith(f"{param_idx})"):
                pname = o.name
        if pname is None:
            return full
        sliced = None
        dus_update = None
        for o in comps[body]:
            if f"%{pname}" in o.rest or f"%{pname}," in o.rest:
                if o.kind == "dynamic-slice":
                    sliced = _shape_bytes(o.out_type)
                elif o.kind == "dynamic-update-slice":
                    # in-place update: only the update slice is touched
                    names = _OPERAND_RE.findall(o.rest.split("),")[0])
                    if len(names) >= 2 and names[1] in shapes:
                        dus_update = _shape_bytes(shapes[names[1]])
                else:
                    return full  # some use reads the whole buffer
        if sliced is not None:
            return sliced
        if dus_update is not None:
            return dus_update
        return full

    def _fusion_out_bytes(op: Op) -> int:
        """If the fusion root is a dynamic-update-slice, only the update
        slice is written (in-place update of the big buffer)."""
        full = _shape_bytes(op.out_type)
        for body in _CALLED_RE.findall(op.line):
            if body not in comps or not comps[body]:
                continue
            root = comps[body][-1]
            if root.kind == "dynamic-update-slice":
                names = _OPERAND_RE.findall(root.rest.split("),")[0])
                if len(names) >= 2 and names[1] in shapes:
                    return _shape_bytes(shapes[names[1]])
        return full

    def op_hbm_bytes(op: Op) -> float:
        if op.kind in _VIEW_OPS:
            return 0.0
        if op.kind == "dynamic-slice":
            return 2.0 * _shape_bytes(op.out_type)
        bodies = _CALLED_RE.findall(op.line) if op.kind == "fusion" else []
        total = _fusion_out_bytes(op) if op.kind == "fusion" else _shape_bytes(op.out_type)
        arglist = op.rest.split("),")[0]
        for i, name in enumerate(_OPERAND_RE.findall(arglist)):
            if name not in shapes:
                continue
            full = _shape_bytes(shapes[name])
            if bodies:
                full = _fusion_param_read_bytes(bodies[0], i, full)
            total += full
        return total

    def walk(comp_name: str, mult: float, in_fusion: bool):
        if comp_name not in comps:
            return
        key = (comp_name, in_fusion)
        if key in visited_stack:  # defensive: no recursion in HLO, but be safe
            return
        visited_stack.append(key)
        for op in comps[comp_name]:
            kind = op.kind
            if kind == "dot":
                totals.flops += mult * dot_flops(op)
            elif kind == "convolution":
                totals.flops += mult * conv_flops(op)
            base = kind.replace("-start", "")
            if base in COLLECTIVES and not kind.endswith("-done"):
                nbytes = _shape_bytes(op.out_type)
                totals.collective_bytes[base] += mult * nbytes
                totals.collective_counts[base] += mult
            if not in_fusion and kind not in ("while", "conditional", "call"):
                totals.hbm_bytes += mult * op_hbm_bytes(op)
            if kind == "while":
                t = trip_count(op)
                for called in _CALLED_RE.findall(op.line):
                    walk(called, mult * t, in_fusion)
                # while's own tuple shuffling is cheap; skip op bytes
            elif kind == "fusion":
                for called in _CALLED_RE.findall(op.line):
                    walk(called, mult, True)
            elif kind in ("call", "conditional", "custom-call", "map", "reduce",
                          "sort", "scatter", "select-and-scatter", "reduce-window"):
                for called in _CALLED_RE.findall(op.line):
                    walk(called, mult, True if kind != "call" else in_fusion)
        visited_stack.pop()

    walk("__entry__", 1.0, False)
    return totals
