"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch <id> [--reduced] \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--resume]

On this CPU container you run the reduced configs (that is what
examples/train_lm.py does); on a TPU fleet the same file runs the full
configs on the production mesh (--mesh prod / prod-multipod).  The loop
wires together every substrate piece: sharded data pipeline, remat'd
train step, Adam, atomic checkpoints, deterministic resume, straggler
logging, and elastic re-mesh on shrink.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.common import SHAPES
from repro.data.pipeline import TokenPipelineConfig, audio_batch, token_batch, vlm_batch
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.optim.adam import AdamConfig, init_adam
from repro.train import checkpoint as ckpt
from repro.train import sharding as shd
from repro.train.fault import DataSkipper, StragglerDetector


def make_batch_fn(spec, cfg, batch, seq):
    vocab = getattr(cfg, "vocab")
    pcfg = TokenPipelineConfig(vocab=vocab, seq_len=seq, global_batch=batch)
    if spec.kind == "encdec":
        return lambda i: audio_batch(pcfg, i, n_frames=seq, d_model=cfg.d_model)
    nfront = getattr(cfg, "n_frontend_tokens", 0)
    if nfront:
        return lambda i: vlm_batch(pcfg, i, n_img=nfront, d_model=cfg.d_model)
    return lambda i: token_batch(pcfg, i)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="LR-schedule horizon (default: --steps); lets a "
                         "resumed run keep the original schedule")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "prod", "prod-multipod"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    spec = ARCHS[args.arch]
    cfg = spec.cfg(args.reduced)
    total = args.total_steps or args.steps
    adam_cfg = AdamConfig(lr=args.lr, total_steps=total,
                          warmup_steps=max(1, total // 20))

    params, axes = spec.init(jax.random.PRNGKey(0), reduced=args.reduced)
    opt_state = init_adam(params)
    train_step = spec.make_train_step(adam_cfg, reduced=args.reduced)

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
        pshard = shd.make_param_sharding(mesh, params, axes)
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(
            opt_state,
            {"m": pshard, "v": pshard,
             "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())},
        )

    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    batch_fn = make_batch_fn(spec, cfg, args.batch, args.seq)

    start_step = 0
    skipper = DataSkipper(seed=0)
    if args.resume and args.ckpt_dir:
        hit = ckpt.restore_latest(args.ckpt_dir, {"params": params, "opt": opt_state})
        if hit is not None:
            start_step, tree, extra = hit
            params, opt_state = tree["params"], tree["opt"]
            skipper.skip_to(start_step)
            print(f"resumed from step {start_step}")

    straggler = StragglerDetector()
    ctx = shd.use_mesh(mesh) if mesh is not None else _nullcontext()
    losses = []
    with ctx:
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, batch_fn(skipper.next_batch_id()))
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            if straggler.observe(0, dt):
                print(f"[fault] step {step}: local worker flagged as straggler ({dt:.2f}s)")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {loss:.4f} gnorm "
                    f"{float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} {dt:.2f}s"
                )
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(
                    args.ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                    extra={"arch": args.arch, "loss": loss},
                )
                ckpt.prune(args.ckpt_dir, keep=3)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
