"""Serving launcher: production-mesh batched inference.

    PYTHONPATH=src python -m repro.launch.serve --arch <id> --reduced \
        --requests 8 --prompt-len 12 --max-new 8

Runs the ServeEngine over the arch's prefill/decode steps; with
--mesh prod the steps are pjit'd onto the 16x16 mesh (the dry-run proves
the full-size shapes compile; this driver actually executes the reduced
ones on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.common import SHAPES, Shape
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    spec = ARCHS[args.arch]
    cfg = spec.cfg(reduced=True)
    if spec.kind not in ("lm", "mamba_lm", "hybrid"):
        raise SystemExit(f"serve driver supports decoder LMs; {spec.kind} has its own path")

    params, _ = spec.init(jax.random.PRNGKey(0), reduced=True)
    max_len = args.prompt_len + args.max_new + 4

    if spec.kind == "lm":
        from repro.models.transformer import decode_step as ds, prefill as pf

        prefill_fn = jax.jit(lambda p, t: pf(p, cfg, t, max_len=max_len))
        decode_fn = jax.jit(lambda p, s, t, pos: ds(p, cfg, t, s, pos))
    elif spec.kind == "mamba_lm":
        from repro.models.layers import unembed_logits
        from repro.models.ssm import (init_mamba2_lm_state, mamba2_lm_decode,
                                      mamba2_lm_hidden)

        def _prefill(p, t):
            # recurrent prefill: feed tokens through decode one at a time
            # is O(S) dispatches; instead run chunked forward then replay
            # the last token to build state (simple, correct)
            st = init_mamba2_lm_state(cfg, t.shape[0])
            logits = None
            for i in range(t.shape[1]):
                logits, st = mamba2_lm_decode(p, cfg, t[:, i : i + 1], st)
            return logits, st

        prefill_fn = _prefill
        decode_fn = jax.jit(lambda p, s, t, pos: mamba2_lm_decode(p, cfg, t, s))
    else:  # hybrid
        from repro.models.hybrid import decode_step as hds, init_state

        def _prefill(p, t):
            st = init_state(cfg, t.shape[0], max_len)
            logits = None
            for i in range(t.shape[1]):
                pos = jnp.full((t.shape[0], 1), i, jnp.int32)
                logits, st = hds(p, cfg, t[:, i : i + 1], st, pos)
            return logits, st

        prefill_fn = _prefill
        decode_fn = jax.jit(lambda p, s, t, pos: hds(p, cfg, t, s, pos))

    engine = ServeEngine(params, prefill_fn, decode_fn, EngineConfig(
        max_batch=args.max_batch, max_len=max_len))
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU reduced config)")
    for r in done[:3]:
        print(f"  rid={r.rid} out={r.out}")
    return done


if __name__ == "__main__":
    main()
