import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, extract memory/cost/collective statistics for the
roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be run as a fresh process (the XLA_FLAGS above lock in at first jax
import).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Collective bytes are parsed from the compiled HLO (operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
because cost_analysis does not report them.
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.common import SHAPES, abstract_opt_state
from repro.launch.mesh import make_production_mesh
from repro.train import sharding as shd

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:f|bf|s|u|pred|tuple|\()[^=]*?)?(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of each collective op (per device)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m or (m.group(3) == "-done"):
            continue
        kind = m.group(2)
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    out["counts"] = counts
    return out


def _spec_tree_to_shardings(mesh, shapes, axes_tree, rules):
    return jax.tree.map(
        lambda x, ax: jax.sharding.NamedSharding(mesh, shd._resolve(x.shape, ax, rules, mesh)),
        shapes,
        axes_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def _batch_shardings(mesh, batch):
    from jax.sharding import PartitionSpec as P

    def one(x):
        # shard the leading (batch) dim over as many data-like axes as the
        # size divides (long_500k has global_batch=1 -> fully replicated)
        assign, size = [], 1
        for a in ("pod", "data"):
            if a in mesh.shape and x.shape[0] % (size * mesh.shape[a]) == 0:
                assign.append(a)
                size *= mesh.shape[a]
        ax = tuple(assign) if len(assign) != 1 else assign[0]
        spec = P(*((ax if assign else None,) + (None,) * (len(x.shape) - 1)))
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree.map(one, batch, is_leaf=lambda x: hasattr(x, "shape"))


def dryrun_cell(arch_name: str, shape_name: str, multi_pod: bool = False,
                verbose: bool = True) -> dict:
    spec = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    if shape_name not in spec.shapes:
        return {"arch": arch_name, "shape": shape_name, "status": "skipped",
                "note": spec.skip_notes}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    params_shapes = jax.eval_shape(lambda: spec.init(jax.random.PRNGKey(0))[0])
    # logical-axes tree is static; the reduced init (same structure) is cheap
    _, axes_tree = spec.init(jax.random.PRNGKey(0), reduced=True)

    param_shardings = shd.make_param_sharding(mesh, params_shapes, axes_tree)
    batch = spec.input_specs(shape_name)
    batch_shardings = _batch_shardings(mesh, batch)

    with shd.use_mesh(mesh):
        if shape.kind == "train":
            if spec.master_weights:
                from repro.configs.common import bf16_params

                params_shapes = bf16_params(params_shapes)
            opt_shapes = abstract_opt_state(params_shapes, spec.master_weights)
            opt_shardings = {
                "m": param_shardings,
                "v": param_shardings,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            }
            if spec.master_weights:
                opt_shardings["master"] = param_shardings
            step = spec.make_train_step()
            scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            metrics_shardings = {"loss": scalar, "grad_norm": scalar, "lr": scalar}
            lowered = jax.jit(
                step,
                in_shardings=(param_shardings, opt_shardings, batch_shardings),
                out_shardings=(param_shardings, opt_shardings, metrics_shardings),
                donate_argnums=(0, 1),
            ).lower(params_shapes, opt_shapes, batch)
        elif shape.kind == "prefill":
            step = spec.make_prefill_step(shape)
            lowered = jax.jit(
                step, in_shardings=(param_shardings, batch_shardings)
            ).lower(params_shapes, batch)
        else:  # decode
            state_shapes, state_axes = spec.state_specs(shape_name)
            state_shardings = _spec_tree_to_shardings(
                mesh, state_shapes, state_axes, shd.ACT_RULES
            )
            step = spec.make_decode_step(shape)
            # logits inherit batch sharding; the new state MUST carry the
            # input state's shardings so donation aliases the (huge) cache
            logits_shape = jax.eval_shape(step, params_shapes, state_shapes, batch)[0]
            lowered = jax.jit(
                step,
                in_shardings=(param_shardings, state_shardings, batch_shardings),
                out_shardings=(
                    _batch_shardings(mesh, logits_shape), state_shardings
                ),
                donate_argnums=(1,),
            ).lower(params_shapes, state_shapes, batch)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    # xla's cost_analysis counts while bodies once; hlocost multiplies by
    # known trip counts (launch/hlocost.py) — use it for the roofline.
    from repro.launch import hlocost

    corrected = hlocost.analyze(hlo_text)
    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": corrected.flops,
        "bytes_per_device": corrected.hbm_bytes,
        "xla_flops_per_device_uncorrected": ca.get("flops", 0.0),
        "xla_bytes_per_device_uncorrected": ca.get("bytes accessed", 0.0),
        "collective_bytes_per_device": corrected.collective_bytes,
        "collective_counts": corrected.collective_counts,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "n_params": spec.param_count(),
        "n_active_params": spec.active_param_count(),
    }
    if verbose:
        print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a, spec in ARCHS.items():
            for s in spec.shapes:
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    results = []
    for a, s in cells:
        try:
            results.append(dryrun_cell(a, s, multi_pod=args.multi_pod))
        except Exception as e:  # a failing cell is a bug — surface it loudly
            results.append({"arch": a, "shape": s, "status": "FAILED",
                            "error": f"{type(e).__name__}: {e}"})
            print(f"FAILED {a} x {s}: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    print(f"\n{ok}/{len(results)} cells compiled OK")


if __name__ == "__main__":
    main()
