"""Graph serving launcher: the async GNN engine under open-loop load.

    PYTHONPATH=src python -m repro.launch.graph_serve \
        --mode async --rate 150 --requests 200 --deadline-ms 0

Stands the continuously-batched :class:`GraphServeEngine` (scheduler
loop, mid-flight wave coalescing, deadline-aware admission) behind a
**Poisson open-loop** request generator: arrivals follow an exponential
inter-arrival clock that does *not* wait for completions, so queueing
delay is measured instead of hidden — the closed-loop ``run()`` benches
report throughput but can never see the latency a bursty workload pays
(``--mode sync`` runs the same workload through a thread that drains
synchronous waves, the degenerate baseline).

The module is import-friendly on purpose: ``benchmarks/serve_bench.py``
drives :func:`run_open_loop` with both modes at equal offered load for
the CI latency gates, and this CLI is the human-facing surface over the
same driver.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Optional

import numpy as np

from repro.serve.graph_engine import (
    AdmissionRejected,
    EngineOverloaded,
    GraphRequest,
    GraphServeEngine,
)

#: Hot-graph pool sizes for the default workload — the sparse power-law
#: serving regime the capacity ladder targets (mirrors serve_bench).
DEFAULT_POOL_SIZES = (600, 900, 1200, 1500, 2000, 2500)


def default_pool(sizes=DEFAULT_POOL_SIZES):
    """Sparse power-law hot-graph pool with GCN-normalized adjacency."""
    from repro.simul.datasets import gcn_normalize, powerlaw_graph

    return [
        gcn_normalize(powerlaw_graph(n, 3 * n, seed=i))
        for i, n in enumerate(sizes)
    ]


def make_requests(
    rng: np.random.Generator,
    pool,
    n_requests: int,
    d_in: int,
    model: str = "gcn",
    deadline_s: Optional[float] = None,
) -> list[GraphRequest]:
    """A request stream drawn uniformly from the hot-graph pool."""
    reqs = []
    for rid in range(n_requests):
        adj = pool[int(rng.integers(len(pool)))]
        x = rng.standard_normal((adj.shape[0], d_in)).astype(np.float32)
        reqs.append(
            GraphRequest(
                rid=rid, adj=adj, x=x, model=model, deadline_s=deadline_s
            )
        )
    return reqs


def poisson_arrivals(
    rng: np.random.Generator, n: int, rate_hz: float
) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a Poisson process: i.i.d.
    exponential inter-arrival gaps at ``rate_hz`` requests/second."""
    if rate_hz <= 0:
        raise ValueError("arrival rate must be positive")
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n))


class SyncWaveServer:
    """The baseline serving shape: one thread draining the intake queue in
    synchronous waves (``engine.run()``) — no mid-flight coalescing, no
    dispatch/materialize overlap.  Producers still submit through the
    thread-safe intake, so the sync and async modes see the identical
    open-loop arrival process."""

    def __init__(self, engine: GraphServeEngine):
        self.engine = engine
        self._running = False
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name="graph-serve-sync-waves", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: Optional[float] = None) -> None:
        """Stop after draining everything queued (mirrors engine.stop())."""
        self._running = False
        self.engine.scheduler.queue.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def _loop(self) -> None:
        eng = self.engine
        while True:
            if eng.scheduler.queue.depth():
                try:
                    eng.run()
                except Exception:
                    continue  # failure isolation already requeued/ejected
            elif self._running:
                eng.scheduler.queue.wait_for_work(timeout=0.01)
            else:
                return


def run_open_loop(
    engine: GraphServeEngine,
    requests: list[GraphRequest],
    arrivals: np.ndarray,
    mode: str = "async",
    result_timeout_s: float = 120.0,
) -> dict:
    """Drive ``requests`` at their Poisson ``arrivals`` offsets and block
    until every admitted request reaches a terminal state.

    Open-loop discipline: the driver sleeps to each arrival time
    regardless of completions, so a slow server accumulates queue depth
    (and pays it in measured latency) instead of throttling the workload.
    Returns latency percentiles over completed requests, throughput over
    the span from first arrival to last completion, and shed/reject
    counts.
    """
    if mode not in ("async", "sync"):
        raise ValueError(f"mode must be 'async' or 'sync', got {mode!r}")
    server = None
    if mode == "async":
        engine.start()
    else:
        server = SyncWaveServer(engine)
        server.start()
    submitted: list[GraphRequest] = []
    n_rejected = n_overloaded = 0
    t0 = time.perf_counter()
    try:
        for req, t_arr in zip(requests, arrivals):
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
            try:
                engine.submit(req, block=False)
                submitted.append(req)
            except AdmissionRejected:
                n_rejected += 1
            except EngineOverloaded:
                n_overloaded += 1
        deadline = time.monotonic() + result_timeout_s
        for r in submitted:
            if not r.event.wait(max(0.0, deadline - time.monotonic())):
                raise TimeoutError(
                    f"request {r.rid} unfinished after {result_timeout_s}s"
                )
        elapsed = time.perf_counter() - t0
    finally:
        if mode == "async":
            engine.stop(timeout=30.0)
        else:
            server.stop(timeout=30.0)
    done = [r for r in submitted if r.done]
    shed = [r for r in submitted if not r.done]
    lats = np.array([r.latency_s for r in done], np.float64)
    return {
        "mode": mode,
        "offered": len(requests),
        "completed": len(done),
        "shed": len(shed),
        "rejected": n_rejected,
        "overloaded": n_overloaded,
        "elapsed_s": elapsed,
        "graphs_per_s": len(done) / elapsed if elapsed > 0 else 0.0,
        "p50_ms": float(np.percentile(lats, 50) * 1e3) if lats.size else None,
        "p99_ms": float(np.percentile(lats, 99) * 1e3) if lats.size else None,
        "mean_ms": float(lats.mean() * 1e3) if lats.size else None,
        "outputs": {r.rid: r.out for r in done},
    }


def build_default_engine(d_in: int = 32, **cfg_kw) -> GraphServeEngine:
    """A gcn engine over the default workload's model shape."""
    import jax

    from repro.models.gnn import GNNConfig, init_gnn
    from repro.serve.graph_engine import GraphEngineConfig

    cfg = GNNConfig(
        name="gcn", kind="gcn", d_in=d_in, d_hidden=64, n_classes=8,
        backend="jnp",
    )
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    kw = dict(
        max_batch_graphs=16, max_batch_nodes=8192,
        node_buckets=(2048, 4096, 8192),
    )
    kw.update(cfg_kw)
    return GraphServeEngine({"gcn": (params, cfg)}, GraphEngineConfig(**kw))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Async graph serving under Poisson open-loop load."
    )
    ap.add_argument("--mode", choices=["async", "sync"], default="async")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="offered load, requests/second")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request latency budget (0 = none)")
    ap.add_argument("--d-in", type=int, default=32)
    ap.add_argument("--max-wave-delay-ms", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    pool = default_pool()
    engine = build_default_engine(
        d_in=args.d_in, max_wave_delay_ms=args.max_wave_delay_ms
    )
    deadline = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    requests = make_requests(
        rng, pool, args.requests, args.d_in, deadline_s=deadline
    )
    arrivals = poisson_arrivals(rng, args.requests, args.rate)

    # warm the jit caches off the clock: a serving process is long-lived,
    # so steady-state latency (every bucket shape traced) is the regime
    warm = GraphServeEngine(engine.models, engine.cfg)
    for r in make_requests(rng, pool, 24, args.d_in):
        warm.submit(r)
    warm.run()

    stats = run_open_loop(engine, requests, arrivals, mode=args.mode)
    m = engine.metrics()
    print(
        f"{args.mode}: {stats['completed']}/{stats['offered']} completed at "
        f"{stats['graphs_per_s']:.1f} graphs/s (offered {args.rate:.1f}/s)"
    )
    print(
        f"latency p50 {stats['p50_ms']:.1f}ms  p99 {stats['p99_ms']:.1f}ms  "
        f"mean {stats['mean_ms']:.1f}ms"
    )
    print(
        f"waves {m['waves']}  fill {m['wave_fill']:.2f}  "
        f"launches {m['launches']}  shed {m['shed']}  "
        f"rejected {stats['rejected']}  overloaded {stats['overloaded']}"
    )
    return stats


if __name__ == "__main__":
    main()
