"""Deterministic synthetic data pipelines.

Token batches are a pure function of (seed, batch_id): restartable and
skippable with zero coordination (train/fault.py DataSkipper).  The
generator mimics a tokenized web corpus statistically (Zipfian unigram
draw) — enough to exercise the full training path end to end.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def token_batch(cfg: TokenPipelineConfig, batch_id: int) -> dict:
    """CPU-side batch synthesis (numpy; cheap and deterministic)."""
    rng = np.random.default_rng((cfg.seed << 20) ^ batch_id)
    # Zipf capped into vocab; guarantees full-range coverage over time
    z = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = (z - 1) % cfg.vocab
    return {"tokens": toks.astype(np.int32)}


def batch_iterator(cfg: TokenPipelineConfig, start_batch: int = 0):
    i = start_batch
    while True:
        yield token_batch(cfg, i)
        i += 1


def vlm_batch(cfg: TokenPipelineConfig, batch_id: int, n_img: int, d_model: int) -> dict:
    b = token_batch(cfg, batch_id)
    rng = np.random.default_rng((cfg.seed << 21) ^ batch_id)
    b["extra_embed"] = rng.standard_normal(
        (cfg.global_batch, n_img, d_model)
    ).astype(np.float32)
    return b


def audio_batch(cfg: TokenPipelineConfig, batch_id: int, n_frames: int, d_model: int) -> dict:
    b = token_batch(cfg, batch_id)
    rng = np.random.default_rng((cfg.seed << 22) ^ batch_id)
    b["frames"] = rng.standard_normal(
        (cfg.global_batch, n_frames, d_model)
    ).astype(np.float32)
    return b
