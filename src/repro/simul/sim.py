"""Top-level simulation API (paper §V-A methodology)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.core.formats import COOMatrix
from repro.simul import dataflows
from repro.simul.machine import ComputeResult, MachineConfig
from repro.simul.memory import DramConfig, MemoryResult, finish_memory


@dataclasses.dataclass
class SimResult:
    fmt: str
    compute: ComputeResult
    memory: MemoryResult

    @property
    def compute_cycles(self) -> float:  # Fig. 7 metric
        return self.compute.cycles

    @property
    def idle_cycles(self) -> float:  # Fig. 8 metric
        return self.compute.idle

    @property
    def traffic_bytes(self) -> float:  # Fig. 9 metric
        return self.memory.traffic.total_bytes

    @property
    def mat(self) -> float:  # Fig. 10 metric
        return self.memory.mat

    @property
    def total_cycles(self) -> float:  # Fig. 11 metric
        return self.compute.cycles + self.memory.stall_cycles


def simulate(
    adj: COOMatrix,
    f: int,
    fmt: str,
    cfg: MachineConfig | None = None,
    dram: DramConfig | None = None,
    **kw: Any,
) -> SimResult:
    cfg = cfg or MachineConfig()
    dram = dram or DramConfig()
    comp, traffic = dataflows.RUNNERS[fmt](adj, f, cfg, **kw)
    mem = finish_memory(traffic, cfg, dram)
    return SimResult(fmt, comp, mem)


def geomean(xs) -> float:
    xs = [x for x in xs if x > 0 and math.isfinite(x)]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")
