"""Per-format dataflows: entry order, compute schedule, and traffic.

Each ``run_<format>`` returns (ComputeResult, TrafficResult) for one
aggregation pass Â·Z with F feature columns, under the paper's shared-
memory budget (§V-A: 64 kB A / 64 kB Z / 256 kB PS).

Feature passes: a dataflow that pins a PS strip of R rows can only hold
F_pass = mem_ps / (4 R) feature columns at once; wider feature matrices
process in ceil(F / F_pass) passes, re-reading A and Z each pass — the
iso-memory discipline behind the paper's Fig. 12 height sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.formats import COOMatrix, coo_to_csb
from repro.core.scv import ROW_MAJOR, ZMORTON, coo_to_scv
from repro.simul.machine import (
    ComputeResult,
    MachineConfig,
    compute_bcsr_blocks,
    compute_csc_fixed_rows,
    compute_csr_row_barrier,
    compute_entry_stream,
    compute_multipass,
)
from repro.simul.memory import TrafficResult, directmapped_hits

E = 4  # bytes per value
IDX = 4  # bytes per 32-bit index


def _csr_order(a: COOMatrix) -> np.ndarray:
    return np.argsort(a.rows.astype(np.int64) * a.shape[1] + a.cols, kind="stable")


def _csc_order(a: COOMatrix) -> np.ndarray:
    return np.argsort(a.cols.astype(np.int64) * a.shape[0] + a.rows, kind="stable")


def run_csr(a: COOMatrix, f: int, cfg: MachineConfig):
    order = _csr_order(a)
    row_nnz = np.bincount(a.rows, minlength=a.shape[0])
    comp = compute_csr_row_barrier(row_nnz, f, cfg)
    # Z is gathered per entry; only mem_z worth of rows stay resident
    cols_stream = a.cols[order]
    z_rows_fit = max(1, cfg.mem_z_bytes // (E * f))
    hits = directmapped_hits(cols_stream, z_rows_fit)
    z_miss = cols_stream[~hits]
    bytes_a = a.nnz * (E + IDX) + (a.shape[0] + 1) * IDX
    bytes_z = float(len(z_miss)) * f * E
    bytes_ps = float((row_nnz > 0).sum()) * f * E  # each PS row written once
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, z_miss, f * E)
    return comp, traffic


def run_csc(a: COOMatrix, f: int, cfg: MachineConfig):
    order = _csc_order(a)
    rows_stream = a.rows[order]
    comp = compute_csc_fixed_rows(rows_stream, f, cfg)
    col_nnz = np.bincount(a.cols, minlength=a.shape[1])
    bytes_a = a.nnz * (E + IDX) + (a.shape[1] + 1) * IDX
    bytes_z = float((col_nnz > 0).sum()) * f * E  # each Z row read once
    # PS thrash: only mem_ps worth of rows resident; misses pay read+write
    ps_rows_fit = max(1, cfg.mem_ps_bytes // (E * f))
    hits = directmapped_hits(rows_stream, ps_rows_fit)
    ps_miss = rows_stream[~hits]
    bytes_ps = float(len(ps_miss)) * 2 * f * E
    # the irregular stream that reaches cache/DRAM is the PS stream
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, ps_miss, f * E)
    return comp, traffic


def run_scv(
    a: COOMatrix,
    f: int,
    cfg: MachineConfig,
    height: int = 512,
    order: str = ZMORTON,
):
    scv = coo_to_scv(a, height, order=order)
    counts = np.diff(scv.blk_ptr)
    rows_in_order = (
        np.repeat(scv.vec_row_blk.astype(np.int64), counts) * height + scv.blk_id
    )
    comp = compute_entry_stream(rows_in_order, f, cfg)
    f_pass = int(np.clip(cfg.mem_ps_bytes // (E * height), 8, f))
    passes = -(-f // f_pass)
    # A: value + within-vector offset (log2 B bits, byte-rounded) + blk_ptr
    idx_bytes = max(1, scv.index_bits_per_entry // 8)
    bytes_a = (scv.nnz * (E + idx_bytes) + scv.n_vectors * IDX) * passes
    # Z: one row slice per vector per pass (the SCV reuse guarantee)
    bytes_z = float(scv.n_vectors) * f_pass * E * passes
    # PS: distinct rows touched, written once per pass (f_pass columns each)
    touched = np.unique(rows_in_order)
    bytes_ps = float(len(touched)) * f * E  # once per pass x f_pass = f total
    z_stream = np.concatenate([scv.vec_col.astype(np.int64)] * passes) if passes > 1 else scv.vec_col.astype(np.int64)
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, z_stream, f_pass * E)
    return comp, traffic


def run_scv_bucketed(
    a: COOMatrix,
    f: int,
    cfg: MachineConfig,
    tile: int,
    caps=None,
):
    """:func:`run_scv` with the adjacency stream priced at the *launched*
    bucketed capacity slots instead of logical nnz.

    The device plan ships three 32-bit arrays (rows/cols/vals) per
    capacity slot, padding included — BENCH_dist measured the nnz-priced
    model 1.11-3.79x optimistic against placed plans — so ``bytes_a``
    becomes ``3 * slots * E`` per feature pass, with ``slots`` from
    :func:`core.scv.launched_slots` (chain-split at the top cap, remainder
    in the smallest fitting cap, first-segment coverage dummies).  Compute
    cycles and the Z/PS traffic terms are unchanged: padding slots are
    masked, they cost bytes, not MACs.  Returns ``(comp, traffic, slots)``.
    """
    from repro.core.scv import bucket_caps_for, launched_slots, tile_nnz_histogram

    counts = tile_nnz_histogram(a, tile)
    if caps is None:
        caps = bucket_caps_for(counts, tile)
    comp, traffic = run_scv(a, f, cfg, height=tile)
    n_row_blocks = -(-a.shape[0] // int(tile))
    slots = launched_slots(counts, tile, caps, n_row_blocks=n_row_blocks)
    f_pass = int(np.clip(cfg.mem_ps_bytes // (E * int(tile)), 8, f))
    passes = -(-f // f_pass)
    bytes_a = float(3 * slots * E) * passes
    traffic = TrafficResult(
        bytes_a, traffic.bytes_z, traffic.bytes_ps,
        traffic.z_row_stream, traffic.feature_bytes,
    )
    return comp, traffic, slots


def run_scv_width(
    a: COOMatrix,
    f: int,
    cfg: MachineConfig,
    height: int = 64,
    width: int = 1,
):
    """Fig. 13: SCV-like tiles of ``width`` columns (width 1 == SCV).  A
    single nonzero in a tile forces all ``width`` Z rows to be fetched."""
    csb = coo_to_csb(a, height, width)
    counts = np.diff(csb.blk_ptr)
    rows_in_order = (
        np.repeat(csb.blk_row.astype(np.int64), counts) * height + csb.row_id
    )
    comp = compute_entry_stream(rows_in_order, f, cfg)
    f_pass = int(np.clip(cfg.mem_ps_bytes // (E * height), 8, f))
    passes = -(-f // f_pass)
    idx_bytes = 2 * max(1, int(np.ceil(np.log2(max(height, width, 2)))) // 8 + 1)
    bytes_a = (csb.nnz * (E + idx_bytes) + csb.n_blocks * 3 * IDX) * passes
    bytes_z = float(csb.n_blocks) * width * f_pass * E * passes
    touched = np.unique(rows_in_order)
    bytes_ps = float(len(touched)) * f * E
    # stream at tile-column granularity: feature_bytes scales with width
    z_stream = np.repeat(csb.blk_col.astype(np.int64), 1)
    if passes > 1:
        z_stream = np.concatenate([z_stream] * passes)
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, z_stream, width * f_pass * E)
    return comp, traffic


def run_bcsr(a: COOMatrix, f: int, cfg: MachineConfig, block: int = 16):
    from repro.core.formats import coo_to_bcsr

    b = coo_to_bcsr(a, block)
    comp = compute_bcsr_blocks(b.n_blocks, block, f, cfg)
    f_pass = int(np.clip(cfg.mem_ps_bytes // (E * block), 8, f))
    passes = -(-f // f_pass)
    bytes_a = (float(b.n_blocks) * block * block * E + b.n_blocks * IDX) * passes
    bytes_z = float(b.n_blocks) * block * f_pass * E * passes
    brow = np.repeat(np.arange(len(b.row_ptr) - 1), np.diff(b.row_ptr))
    bytes_ps = float(len(np.unique(brow))) * block * f * E
    z_stream = b.col_id.astype(np.int64)
    if passes > 1:
        z_stream = np.concatenate([z_stream] * passes)
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, z_stream, block * f_pass * E)
    return comp, traffic


def run_multipass(a: COOMatrix, f: int, cfg: MachineConfig):
    """MP (§II-B.4): Z is streamed sequentially once; entries process in
    the pass whose cached Z span covers their column."""
    order = _csc_order(a)
    rows_stream = a.rows[order]
    cols_stream = a.cols[order]
    cols_per_pass = max(1, cfg.cache_bytes // (E * f))
    passes = max(1, -(-a.shape[1] // cols_per_pass))
    comp = compute_multipass(rows_stream, passes, a.nnz, f, cfg)
    bytes_a = float(a.nnz) * (E + IDX) * passes
    col_nnz = np.bincount(a.cols, minlength=a.shape[1])
    bytes_z = float((col_nnz > 0).sum()) * f * E  # sequential, once overall
    entry_pass = cols_stream // cols_per_pass
    rp = np.unique(rows_stream.astype(np.int64) * passes + entry_pass)
    bytes_ps = float(len(rp)) * 2 * f * E
    z_stream = np.sort(np.unique(cols_stream)).astype(np.int64)  # sequential
    traffic = TrafficResult(bytes_a, bytes_z, bytes_ps, z_stream, f * E)
    return comp, traffic


RUNNERS = {
    "csr": run_csr,
    "csc": run_csc,
    "scv": lambda a, f, cfg, **kw: run_scv(a, f, cfg, order=ROW_MAJOR, **kw),
    "scv_z": lambda a, f, cfg, **kw: run_scv(a, f, cfg, order=ZMORTON, **kw),
    "bcsr": run_bcsr,
    "mp": run_multipass,
}
