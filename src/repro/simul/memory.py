"""Memory-hierarchy model: shared local memory -> cache -> DRAM (paper §V-A).

Level 1 — *shared local memory* (64 kB A / 64 kB Z / 256 kB PS): residency
is decided analytically per dataflow, because choosing what stays resident
is exactly what the sparse formats differ in.  Misses become traffic to the
cache (Fig. 9's metric).

Level 2 — *cache* (2 MB): simulated direct-mapped at Z-row granularity on
the Z miss stream (A is a stream — bypassed; PS strips are streaming
write-backs — write-around).  This level is where SCV-Z's Z-Morton order
pays off: consecutive vector groups re-touch nearby Z rows.

Level 3 — *DRAM*: row-buffer model (mini-Ramulator): per cache-miss Z row,
the first line activates a DRAM row, subsequent sequential lines hit it;
random re-activations pay the miss penalty.  MAT = mean access time over
the simulated stream, as in §V-D.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.simul.machine import MachineConfig


@dataclasses.dataclass(frozen=True)
class DramConfig:
    row_bytes: int = 2048
    n_banks: int = 8
    t_cache_hit: float = 12.0  # cycles, on-chip cache service
    t_rb_hit: float = 24.0  # DRAM access, row buffer open
    t_rb_miss: float = 64.0  # precharge + activate + CAS


@dataclasses.dataclass
class TrafficResult:
    bytes_a: float
    bytes_z: float
    bytes_ps: float
    z_row_stream: np.ndarray  # row-granular Z accesses that missed shared mem
    feature_bytes: int  # bytes of one Z/PS row slice in this dataflow

    @property
    def total_bytes(self) -> float:
        return self.bytes_a + self.bytes_z + self.bytes_ps


@dataclasses.dataclass
class MemoryResult:
    traffic: TrafficResult  # processor -> cache (Fig. 9)
    cache_misses: int
    cache_accesses: int
    dram_bytes: float
    mat: float  # mean access time, cycles (Fig. 10)
    stall_cycles: float  # VPE-stall contribution (Fig. 11)


def directmapped_hits(stream: np.ndarray, n_sets: int) -> np.ndarray:
    """Vectorized direct-mapped simulation at row granularity.

    A row access hits iff the previous access to its set carried the same
    tag.  Implemented with a stable sort by (set, time).
    """
    if len(stream) == 0:
        return np.zeros(0, dtype=bool)
    n_sets = max(1, int(n_sets))
    sets = stream % n_sets
    order = np.argsort(sets, kind="stable")
    s_sorted = sets[order]
    v_sorted = stream[order]
    hit_sorted = np.zeros(len(stream), dtype=bool)
    same_set = s_sorted[1:] == s_sorted[:-1]
    hit_sorted[1:] = same_set & (v_sorted[1:] == v_sorted[:-1])
    hits = np.zeros(len(stream), dtype=bool)
    hits[order] = hit_sorted
    return hits


def dram_mat(
    miss_rows: np.ndarray, feature_bytes: int, dram: DramConfig
) -> tuple[float, float]:
    """(mean access time in cycles, total access count) for the DRAM-level
    stream of missed Z rows.  Each Z row spans ceil(feature_bytes/row_bytes)
    DRAM rows; sequential lines within an open row hit the row buffer."""
    if len(miss_rows) == 0:
        return dram.t_rb_hit, 0.0
    lines = max(1, feature_bytes // 64)
    rows_spanned = max(1, -(-feature_bytes // dram.row_bytes))
    # DRAM row id of the first line of each accessed Z row
    dram_rows = (miss_rows.astype(np.int64) * feature_bytes) // dram.row_bytes
    banks = dram_rows % dram.n_banks
    order = np.argsort(banks, kind="stable")
    b_s, r_s = banks[order], dram_rows[order]
    new_row = np.ones(len(miss_rows), dtype=bool)
    new_row[1:] = (b_s[1:] != b_s[:-1]) | (r_s[1:] != r_s[:-1])
    activations = float(new_row.sum()) * rows_spanned
    accesses = float(len(miss_rows)) * lines
    hits = max(0.0, accesses - activations)
    mat = (hits * dram.t_rb_hit + activations * dram.t_rb_miss) / max(accesses, 1.0)
    return mat, accesses


def finish_memory(
    traffic: TrafficResult, cfg: MachineConfig, dram: DramConfig
) -> MemoryResult:
    """Run the cache + DRAM levels on a dataflow's Z miss stream."""
    fb = max(4, traffic.feature_bytes)
    n_sets = cfg.cache_bytes // fb
    hits = directmapped_hits(traffic.z_row_stream, n_sets)
    n_acc = len(traffic.z_row_stream)
    n_miss = int((~hits).sum())
    miss_rows = traffic.z_row_stream[~hits]
    mat, dram_accesses = dram_mat(miss_rows, fb, dram)
    dram_bytes = float(n_miss) * fb + traffic.bytes_a + traffic.bytes_ps
    # VPE stalls: every shared-memory miss stalls its VPE (§V-E): cache
    # hits cost t_cache_hit, misses cost the measured MAT per line.
    lines = max(1, fb // 64)
    stall = (
        (n_acc - n_miss) * dram.t_cache_hit
        + n_miss * mat * lines
        + (traffic.bytes_a + traffic.bytes_ps) / 64.0 * dram.t_cache_hit
    ) / cfg.n_vpe
    return MemoryResult(
        traffic=traffic,
        cache_misses=n_miss,
        cache_accesses=n_acc,
        dram_bytes=dram_bytes,
        mat=mat,
        stall_cycles=stall,
    )
