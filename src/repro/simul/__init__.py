"""Reproduction of the paper's §V evaluation methodology."""
from repro.simul.datasets import TABLE_I, GraphData, dataset_names, load
from repro.simul.machine import MachineConfig
from repro.simul.memory import DramConfig
from repro.simul.sim import SimResult, geomean, simulate

__all__ = [
    "TABLE_I",
    "GraphData",
    "dataset_names",
    "load",
    "MachineConfig",
    "DramConfig",
    "SimResult",
    "geomean",
    "simulate",
]
