"""Compute-cycle model of the queue-based vector processor (paper §IV/V).

The paper's tool processes the streamed dataflow "cycle-wise to determine
the number of MACs ... and on-chip SRAM reads/writes"; we reproduce it with
a vectorized event model whose assumptions are stated inline:

* One nonzero a_ij contributes a scalar x vector FMA over the feature row:
  cost c = ceil(F / N_PE) VPE-cycles.  MAC count = nnz * F for every
  sparse format — the paper's iso-MAC discipline (BCSR is the deliberate
  exception: dense blocks do B*B*F MACs per block, its §II-B.3 liability).

* Scheduling is modeled with critical-path / barrier bounds (standard
  makespan lower bounds, tight here because entry costs are uniform):

  - CSR processes one output row at a time ("PS is computed before moving
    on to the next row", §II-B.2): a row with k nonzeros spans
    ceil(k / N_VPE) issue slots; other rows cannot overlap because the
    dataflow is row-sequential.  Ultra-sparse graphs (avg degree ~ a few)
    leave most VPEs idle in every slot — Fig. 8's idle-cycle story.

  - CSC streams entries column by column but statically owns output row i
    on VPE (i mod N_VPE) (§V-B "map a fixed set of output rows to a PE"):
    makespan = max(ideal, max VPE ownership load).  Power-law hub rows
    skew the ownership loads.

  - SCV's arbiter assigns entries greedily to any free VPE; the only
    serialization is per-output-row (same address -> same queue, §IV-B),
    so makespan = max(ideal, deg_max * c) — near-ideal unless one row
    outweighs 1/N_VPE of the matrix.  This is the paper's hazard-free-
    parallelism claim reduced to its scheduling consequence.

* MP (§II-B.4) re-scans the adjacency once per pass; passes are determined
  by how many Z rows fit in cache; each scan costs one arbiter cycle per
  skipped entry (work is "increased computation workload").

All models return VPE-cycles; idle = N_VPE * makespan - busy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import COOMatrix

HAZARD_WINDOW = 3  # cycles: 2-cycle write-to-read latency + issue (§IV-B)


@dataclasses.dataclass(frozen=True)
class MachineConfig:
    n_vpe: int = 8
    n_pe: int = 64
    queue_depth: int = 16
    mem_a_bytes: int = 64 * 1024  # adjacency partition of local memory
    mem_z_bytes: int = 64 * 1024  # combined-feature partition
    mem_ps_bytes: int = 256 * 1024  # partial-sum partition
    cache_bytes: int = 2 * 1024 * 1024
    cache_line: int = 64
    dram_gbps: float = 1.0  # paper: Ramulator HBM default, 1 Gb/s noted
    bytes_per_elem: int = 4

    @property
    def total_macs_per_cycle(self) -> int:
        return self.n_vpe * self.n_pe

    def fingerprint(self) -> str:
        """Stable short hash over every model constant.

        ``repro.tune`` keys its on-disk config cache on this (plus the jax
        backend): change any field — a different simulated machine — and
        every cached ``TunedConfig`` goes stale by construction, because
        its cache key no longer exists.
        """
        import hashlib

        payload = ";".join(
            f"{f.name}={getattr(self, f.name)!r}"
            for f in dataclasses.fields(self)
        )
        return hashlib.blake2b(payload.encode(), digest_size=8).hexdigest()


@dataclasses.dataclass
class ComputeResult:
    cycles: float  # makespan in cycles
    busy: float  # sum of VPE busy cycles
    idle: float  # N_VPE * makespan - busy
    macs: float


def _entry_cost(f: int, cfg: MachineConfig) -> int:
    return -(-f // cfg.n_pe)


def compute_entry_stream(
    rows_in_order: np.ndarray, f: int, cfg: MachineConfig
) -> ComputeResult:
    """SCV (and CSB-like) greedy queue scheduling: near-ideal makespan;
    the only critical path is a single output row's serialized updates."""
    c = _entry_cost(f, cfg)
    nnz = len(rows_in_order)
    busy = float(nnz) * c
    ideal = busy / cfg.n_vpe
    deg_max = int(np.bincount(rows_in_order.astype(np.int64)).max()) if nnz else 0
    makespan = max(ideal, deg_max * c)
    return ComputeResult(
        cycles=makespan,
        busy=busy,
        idle=cfg.n_vpe * makespan - busy,
        macs=float(nnz) * f,
    )


def compute_csc_fixed_rows(
    rows_in_order: np.ndarray, f: int, cfg: MachineConfig
) -> ComputeResult:
    """CSC: output row i is owned by VPE (i % N_VPE) (§V-B fixed mapping):
    makespan = max ownership load (hub rows skew it)."""
    c = _entry_cost(f, cfg)
    nnz = len(rows_in_order)
    busy = float(nnz) * c
    loads = np.bincount(rows_in_order % cfg.n_vpe, minlength=cfg.n_vpe) * c
    makespan = max(busy / cfg.n_vpe, float(loads.max()))
    return ComputeResult(
        cycles=makespan,
        busy=busy,
        idle=cfg.n_vpe * makespan - busy,
        macs=float(nnz) * f,
    )


def compute_csr_row_barrier(
    row_nnz: np.ndarray, f: int, cfg: MachineConfig
) -> ComputeResult:
    """CSR: one output row at a time; a row with k nonzeros fills
    ceil(k / N_VPE) issue slots and the remaining VPE slots idle
    (§II-B.2 row-sequential dataflow + §V-B imbalance discussion)."""
    c = _entry_cost(f, cfg)
    active = row_nnz[row_nnz > 0].astype(np.int64)
    slots = -(-active // cfg.n_vpe)  # ceil
    makespan = float(slots.sum()) * c
    busy = float(active.sum()) * c
    return ComputeResult(
        cycles=makespan,
        busy=busy,
        idle=cfg.n_vpe * makespan - busy,
        macs=float(active.sum()) * f,
    )


def compute_bcsr_blocks(
    n_blocks: int, block: int, f: int, cfg: MachineConfig
) -> ComputeResult:
    """BCSR: dense B x B blocks — every stored zero is a real MAC."""
    c = _entry_cost(f, cfg)
    per_block = block * block * c  # dense MACs over the block
    busy = float(n_blocks) * per_block
    # blocks parallelize cleanly (regular): idle only from the tail
    makespan = -(-n_blocks // cfg.n_vpe) * per_block
    return ComputeResult(
        cycles=float(makespan),
        busy=busy,
        idle=cfg.n_vpe * makespan - busy,
        macs=float(n_blocks) * block * block * f,
    )


def compute_multipass(
    rows_in_order: np.ndarray,
    n_passes: int,
    nnz: int,
    f: int,
    cfg: MachineConfig,
) -> ComputeResult:
    """MP: CSC-like compute + one arbiter scan cycle per deferred entry per
    pass (the "increased computation workload" of §II-B.4)."""
    base = compute_entry_stream(rows_in_order, f, cfg)
    rescan = float(nnz) * max(0, n_passes - 1) / cfg.n_vpe
    return ComputeResult(
        cycles=base.cycles + rescan,
        busy=base.busy,
        idle=base.idle + rescan * cfg.n_vpe,
        macs=base.macs,
    )
