"""Evaluation datasets (paper Table I / Fig. 6).

OGB/Planetoid downloads are unavailable in this offline container, so we
generate *synthetic graphs matching Table I statistics* — node count, edge
count, feature size, adjacency density — scaled by ``max_edges`` to fit the
CPU budget (scale factor recorded in the result and in EXPERIMENTS.md).

Degree structure matters for the paper's claims (hub-induced imbalance is
why CSR loses), so edges are drawn from a Chung-Lu-style power-law model:
expected degree sequence w_i ~ Zipf(alpha), endpoints sampled proportional
to w.  ``ultra``/``highly`` sparse categories follow Fig. 6's split.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import COOMatrix


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    nodes: int
    edges: int
    feature_size: int
    category: str  # "ultra" | "highly"  (Fig. 6 split)


# Table I, verbatim. Categories per Fig. 6(a): the four densest datasets
# (Reddit, proteins, CoBuy Computer, CoBuy Photo) are "highly-sparse", the
# rest "ultra-sparse".
TABLE_I: dict[str, DatasetSpec] = {
    "mag": DatasetSpec("mag", 1_939_743, 21_111_007, 128, "ultra"),
    "products": DatasetSpec("products", 2_449_029, 61_859_140, 100, "ultra"),
    "arxiv": DatasetSpec("arxiv", 169_343, 1_166_243, 128, "ultra"),
    "pubmed": DatasetSpec("pubmed", 19_717, 88_651, 500, "ultra"),
    "cora": DatasetSpec("cora", 19_793, 126_842, 8_710, "ultra"),
    "citeseer": DatasetSpec("citeseer", 3_327, 9_228, 3_703, "ultra"),
    "reddit": DatasetSpec("reddit", 232_965, 114_615_892, 602, "highly"),
    "proteins": DatasetSpec("proteins", 132_534, 39_561_252, 8, "highly"),
    "cobuy_computer": DatasetSpec("cobuy_computer", 13_752, 491_722, 767, "highly"),
    "cobuy_photo": DatasetSpec("cobuy_photo", 7_650, 238_163, 745, "highly"),
}


@dataclasses.dataclass(frozen=True)
class GraphData:
    spec: DatasetSpec
    adj: COOMatrix  # weighted normalized adjacency (with self loops)
    feature_size: int
    scale: float  # nodes/edges scale factor applied vs Table I


def powerlaw_graph(
    n: int, m: int, alpha: float = 2.1, seed: int = 0
) -> COOMatrix:
    """Chung-Lu style: P(edge u->v) ∝ w_u * w_v with Zipf weights."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (alpha - 1.0))
    rng.shuffle(w)
    p = w / w.sum()
    # sample with replacement, dedup: overdraw slightly to land near m
    draw = int(m * 1.15) + 16
    src = rng.choice(n, size=draw, p=p)
    dst = rng.choice(n, size=draw, p=p)
    key = src.astype(np.int64) * n + dst
    key = np.unique(key)
    rng.shuffle(key)
    key = key[:m]
    rows = (key // n).astype(np.int32)
    cols = (key % n).astype(np.int32)
    vals = np.ones(len(key), np.float32)
    return COOMatrix(rows, cols, vals, (n, n))


def gcn_normalize(a: COOMatrix) -> COOMatrix:
    """Â = D^-1/2 (A + I) D^-1/2 — the weighted adjacency of GCN [10]."""
    n = a.shape[0]
    rows = np.concatenate([a.rows, np.arange(n, dtype=np.int32)])
    cols = np.concatenate([a.cols, np.arange(n, dtype=np.int32)])
    vals = np.concatenate([a.vals, np.ones(n, np.float32)])
    deg = np.zeros(n, np.float64)
    np.add.at(deg, rows, vals)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
    w = (dinv[rows] * vals * dinv[cols]).astype(np.float32)
    return COOMatrix(rows, cols, w, (n, n))


def load(
    name: str,
    max_edges: int = 2_000_000,
    normalize: bool = True,
    seed: int = 0,
) -> GraphData:
    spec = TABLE_I[name]
    scale = min(1.0, max_edges / spec.edges)
    n = max(64, int(spec.nodes * scale))
    m = max(256, int(spec.edges * scale))
    adj = powerlaw_graph(n, m, seed=seed + hash(name) % 2**16)
    if normalize:
        adj = gcn_normalize(adj)
    return GraphData(spec=spec, adj=adj, feature_size=spec.feature_size, scale=scale)


def dataset_names(category: str | None = None) -> list[str]:
    return [
        k for k, v in TABLE_I.items() if category is None or v.category == category
    ]
