"""mamba2-780m [ssm]: 48L d=1536, attn-free, ssm_state=128, vocab 50280.
SSD (state-space duality) chunked scan; decode state is O(1) in context
length, so long_500k runs [arXiv:2405.21060]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.ssm import Mamba2LMConfig

_full = Mamba2LMConfig(
    name="mamba2-780m", n_layers=48, d_model=1536, vocab=50_280,
    d_state=128, headdim=64,
)

_reduced = Mamba2LMConfig(
    name="mamba2-780m-reduced", n_layers=3, d_model=64, vocab=512,
    d_state=16, headdim=16, dtype=jnp.float32,
)

spec = ArchSpec(
    name="mamba2-780m", kind="mamba_lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
