"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local(4096)+global alternating, attn/final logit softcaps, post-norms,
query_pre_attn_scalar=144 [arXiv:2408.00118]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="gemma2-27b", n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    head_dim=128, d_ff=36864, vocab=256_000, act="gelu_tanh",
    attn_softcap=50.0, final_softcap=30.0, embed_scale=True, post_norms=True,
    layer_pattern=("l", "g"), window=4096, query_scale=144.0 ** -0.5,
    kv_quant=True,
)

_reduced = LMConfig(
    name="gemma2-27b-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, act="gelu_tanh",
    attn_softcap=50.0, final_softcap=30.0, embed_scale=True, post_norms=True,
    layer_pattern=("l", "g"), window=16, query_scale=16.0 ** -0.5,
    dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=2,
    name="gemma2-27b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: global layers are full attention (DESIGN.md §4)",
)
