"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H, MLA (kv_lora=512, rope 64),
64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense
(d_ff 10944), vocab 102400 [arXiv:2405.04434].  SCV-sorted MoE dispatch."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.layers import MLAConfig, MoEConfig
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="deepseek-v2-lite", n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    head_dim=128, d_ff=1408, vocab=102_400,
    mla=MLAConfig(d_model=2048, n_heads=16, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_model=2048, d_ff=1408, n_shared=2),
    first_dense=1, first_dense_ff=10944, kv_quant=True,
)

_reduced = LMConfig(
    name="dsv2-lite-reduced", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=32, vocab=512,
    mla=MLAConfig(d_model=64, n_heads=4, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, n_shared=1,
                  capacity_factor=4.0),
    first_dense=1, first_dense_ff=96, dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=2,
    name="deepseek-v2-lite", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention (MLA)",
    uses_paper_technique=True,
)
