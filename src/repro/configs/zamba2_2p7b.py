"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks d=2560 (ssm_state=64) + one
SHARED attention block (32H) invoked every 6 blocks, d_ff=10240,
vocab 32000 [arXiv:2411.15242].  Hybrid state = O(window + d_state), so
long_500k runs (shared-attn cache is windowed)."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.hybrid import HybridConfig

_full = HybridConfig(
    name="zamba2-2.7b", n_mamba=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32_000, d_state=64, headdim=64, share_every=6,
    window=4096,
)

_reduced = HybridConfig(
    name="zamba2-2.7b-reduced", n_mamba=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, d_state=16, headdim=16, share_every=2, window=16,
    dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=2,
    name="zamba2-2.7b", kind="hybrid", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
