"""Architecture registry machinery.

Each ``configs/<arch>.py`` exports ``spec: ArchSpec``.  An ArchSpec binds
a model family (lm / mamba_lm / hybrid / encdec) to its full-size config,
a reduced same-family config for CPU smoke tests, and the set of
applicable input shapes.  ``steps()`` returns uniform jit-able step
functions; ``input_specs()`` returns ShapeDtypeStruct stand-ins so the
multi-pod dry-run lowers without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import AdamConfig, adam_update, init_adam


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    kind: str  # "lm" | "mamba_lm" | "hybrid" | "encdec"
    config: Any
    reduced: Any
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""
    uses_paper_technique: bool = False  # SCV-sorted MoE dispatch
    train_microbatch: int = 1  # grad-accumulation splits (activation memory)
    master_weights: bool = False  # bf16 params + f32 master in opt state

    def cfg(self, reduced=False):
        return self.reduced if reduced else self.config

    # -- family dispatch ---------------------------------------------------
    def init(self, key, reduced=False):
        cfg = self.cfg(reduced)
        if self.kind == "lm":
            from repro.models.transformer import init_lm

            return init_lm(key, cfg)
        if self.kind == "mamba_lm":
            from repro.models.ssm import init_mamba2_lm

            return init_mamba2_lm(key, cfg)
        if self.kind == "hybrid":
            from repro.models.hybrid import init_hybrid

            return init_hybrid(key, cfg)
        if self.kind == "encdec":
            from repro.models.encdec import init_encdec

            return init_encdec(key, cfg)
        raise ValueError(self.kind)

    def loss_fn(self, reduced=False) -> Callable:
        cfg = self.cfg(reduced)
        if self.kind == "lm":
            from repro.models.transformer import train_loss

            return lambda p, batch: train_loss(p, cfg, batch)
        if self.kind == "mamba_lm":
            from repro.models.ssm import mamba2_lm_loss

            return lambda p, batch: mamba2_lm_loss(p, cfg, batch)
        if self.kind == "hybrid":
            from repro.models.hybrid import train_loss

            return lambda p, batch: train_loss(p, cfg, batch)
        if self.kind == "encdec":
            from repro.models.encdec import train_loss

            return lambda p, batch: train_loss(p, cfg, batch)
        raise ValueError(self.kind)

    def make_train_step(self, adam_cfg: AdamConfig | None = None, reduced=False,
                        microbatch: int | None = None,
                        gather_params_once: bool | None = None):
        """Train step with optional gradient accumulation: the global batch
        is split into ``microbatch`` slices scanned sequentially (activation
        memory scales 1/microbatch; grads accumulate in the param-sharded
        f32 buffer), then one Adam update runs.

        gather_params_once: with fsdp-sharded params, every microbatch
        would re-all-gather the weights; hoisting one explicit un-fsdp
        constraint before the scan trades +params/TP-shards bytes of HBM
        for a 1/microbatch reduction in all-gather traffic (§Perf)."""
        adam_cfg = adam_cfg or AdamConfig()
        loss_fn = self.loss_fn(reduced)
        k = microbatch if microbatch is not None else (
            1 if reduced else self.train_microbatch
        )
        # gather-once measured: -10% collectives but +16 GB temp on qwen
        # (the un-fsdp'd grads materialize before re-sharding) — opt-in only
        # (EXPERIMENTS.md §Perf Cell B iter 3)
        gather_once = bool(gather_params_once)
        axes_tree = self.init(jax.random.PRNGKey(0), reduced=True)[1] if gather_once else None

        def train_step(params, opt_state, batch):
            if k == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                from repro.train.sharding import unfsdp_params

                params_used = (
                    unfsdp_params(params, axes_tree) if gather_once else params
                )
                mb = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
                )

                def acc_step(carry, b):
                    loss_acc, g_acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params_used, b)
                    if gather_once:
                        # grads of the gathered params are un-fsdp'd; pin
                        # them back to the param sharding so the f32
                        # accumulator stays fully sharded
                        from repro.train.sharding import refsdp_params

                        g = refsdp_params(g, axes_tree)
                    g_acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32) / k, g_acc, g
                    )
                    return (loss_acc + l / k, g_acc), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), zeros), mb
                )
            params, opt_state, metrics = adam_update(adam_cfg, params, grads, opt_state)
            return params, opt_state, {"loss": loss, **metrics}

        return train_step

    def make_prefill_step(self, shape: Shape, reduced=False):
        cfg = self.cfg(reduced)
        S = shape.seq_len if not reduced else min(shape.seq_len, 64)
        if self.kind == "lm":
            from repro.models.transformer import prefill

            def step(params, batch):
                return prefill(params, cfg, batch["tokens"], extra_embed=batch.get("extra_embed"), max_len=S)

        elif self.kind == "mamba_lm":
            from repro.models.layers import unembed_logits
            from repro.models.ssm import mamba2_lm_hidden

            def step(params, batch):
                x, _ = mamba2_lm_hidden(params, cfg, batch["tokens"])
                return unembed_logits(params["embed"], x[:, -1:], true_vocab=cfg.vocab)

        elif self.kind == "hybrid":
            from repro.models.hybrid import hidden_states
            from repro.models.layers import unembed_logits

            def step(params, batch):
                x, _ = hidden_states(params, cfg, batch["tokens"])
                return unembed_logits(params["embed"], x[:, -1:], true_vocab=cfg.vocab)

        elif self.kind == "encdec":
            from repro.models.encdec import encode, init_dec_cache

            def step(params, batch):
                enc = encode(params, cfg, batch["frames"])
                return init_dec_cache(params, cfg, enc, max_len=8)

        else:
            raise ValueError(self.kind)
        return step

    def make_decode_step(self, shape: Shape, reduced=False):
        cfg = self.cfg(reduced)
        if self.kind == "lm":
            from repro.models.transformer import decode_step

            def step(params, state, batch):
                return decode_step(params, cfg, batch["token"], state, batch["pos"])

        elif self.kind == "mamba_lm":
            from repro.models.ssm import mamba2_lm_decode

            def step(params, state, batch):
                return mamba2_lm_decode(params, cfg, batch["token"], state)

        elif self.kind == "hybrid":
            from repro.models.hybrid import decode_step

            def step(params, state, batch):
                return decode_step(params, cfg, batch["token"], state, batch["pos"])

        elif self.kind == "encdec":
            from repro.models.encdec import decode_step

            def step(params, state, batch):
                return decode_step(params, cfg, batch["token"], state, batch["pos"])

        else:
            raise ValueError(self.kind)
        return step

    # -- abstract inputs -----------------------------------------------------
    def input_specs(self, shape_name: str, reduced=False) -> dict:
        """ShapeDtypeStruct batch for the given shape (weak-type-correct,
        shardable, no allocation)."""
        shape = SHAPES[shape_name]
        cfg = self.cfg(reduced)
        B = shape.global_batch if not reduced else 2
        S = shape.seq_len if not reduced else min(shape.seq_len, 64)
        i32, f32 = jnp.int32, jnp.float32
        d = getattr(cfg, "d_model")
        if shape.kind == "train":
            if self.kind == "encdec":
                return {
                    "frames": jax.ShapeDtypeStruct((B, S, d), f32),
                    "tokens": jax.ShapeDtypeStruct((B, S + 1), i32),
                }
            batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), i32)}
            nfront = getattr(cfg, "n_frontend_tokens", 0)
            if nfront:
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - nfront + 1), i32)
                batch["extra_embed"] = jax.ShapeDtypeStruct((B, nfront, d), f32)
            return batch
        if shape.kind == "prefill":
            if self.kind == "encdec":
                return {"frames": jax.ShapeDtypeStruct((B, S, d), f32)}
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            nfront = getattr(cfg, "n_frontend_tokens", 0)
            if nfront:
                batch["tokens"] = jax.ShapeDtypeStruct((B, S - nfront), i32)
                batch["extra_embed"] = jax.ShapeDtypeStruct((B, nfront, d), f32)
            return batch
        # decode
        return {
            "token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B, 1), i32),
        }

    def state_specs(self, shape_name: str, reduced=False):
        """(shape_tree, axes_tree) for decode-time state, abstract."""
        shape = SHAPES[shape_name]
        cfg = self.cfg(reduced)
        B = shape.global_batch if not reduced else 2
        S = shape.seq_len if not reduced else min(shape.seq_len, 64)
        if self.kind == "lm":
            from repro.models.transformer import cache_specs, init_cache

            shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
            return shapes, cache_specs(cfg)
        if self.kind == "mamba_lm":
            from repro.models.ssm import init_mamba2_lm_state, mamba2_lm_state_specs

            shapes = jax.eval_shape(lambda: init_mamba2_lm_state(cfg, B))
            return shapes, mamba2_lm_state_specs(cfg)
        if self.kind == "hybrid":
            from repro.models.hybrid import init_state, state_specs

            shapes = jax.eval_shape(lambda: init_state(cfg, B, S))
            return shapes, state_specs(cfg)
        if self.kind == "encdec":
            from repro.models.encdec import cache_specs as ed_specs

            H, D = cfg.n_heads, cfg.head_dim
            L_ = cfg.n_layers
            dt = cfg.dtype
            shapes = {
                "k": jax.ShapeDtypeStruct((L_, B, S, H, D), dt),
                "v": jax.ShapeDtypeStruct((L_, B, S, H, D), dt),
                "pos": jax.ShapeDtypeStruct((L_, S), jnp.int32),
                "len": jax.ShapeDtypeStruct((L_,), jnp.int32),
                "xk": jax.ShapeDtypeStruct((L_, B, S, H, D), dt),
                "xv": jax.ShapeDtypeStruct((L_, B, S, H, D), dt),
            }
            return shapes, ed_specs(cfg)
        raise ValueError(self.kind)

    def param_count(self, reduced=False) -> int:
        shapes = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0), reduced)[0])
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self, reduced=False) -> int:
        cfg = self.cfg(reduced)
        moe = getattr(cfg, "moe", None)
        shapes = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0), reduced)[0])
        total = 0
        for path, x in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            n = int(np.prod(x.shape))
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            if moe is not None and "moe" in keys and (
                keys.endswith("wi") or keys.endswith("wg") or keys.endswith("wo")
            ):
                n = n * moe.top_k // moe.n_experts
            total += n
        return total


def make_abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def abstract_opt_state(params_shapes, master_weights: bool = False):
    f32 = lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32)
    st = {
        "m": jax.tree.map(f32, params_shapes),
        "v": jax.tree.map(f32, params_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if master_weights:
        st["master"] = jax.tree.map(f32, params_shapes)
    return st


def bf16_params(params_shapes):
    """bf16 compute-param tree (master_weights mode)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype
        ),
        params_shapes,
    )
