"""The paper's own model family: GCN on the Table-I graph suite, with SCV
aggregation as the first-class backend.  Used by the paper-reproduction
benchmarks and examples; not part of the 10-arch LM matrix."""
from repro.configs.common import ArchSpec
from repro.models.gnn import GNNConfig

_full = GNNConfig(name="gcn-paper", kind="gcn", d_in=128, d_hidden=128,
                  n_classes=40, n_layers=2, backend="pallas")
_reduced = GNNConfig(name="gcn-paper-reduced", kind="gcn", d_in=16,
                     d_hidden=32, n_classes=7, n_layers=2, backend="jnp")

spec = ArchSpec(name="gcn-paper", kind="gnn", config=_full, reduced=_reduced,
                shapes=(), uses_paper_technique=True)
