"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
5:1 local(1024):global pattern, dual rope bases (10k local / 1M global)
[hf:google/gemma-3-4b-pt].  34 = 4 leading global + 5 x (5 local + 1 global);
the leading remainder is realized via first_dense globals (DESIGN.md §4)."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    head_dim=256, d_ff=10240, vocab=262_144, act="gelu_tanh",
    embed_scale=True, post_norms=True,
    layer_pattern=("l", "l", "l", "l", "l", "g"), window=1024,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    first_dense=4, first_dense_ff=10240,
    kv_quant=True,
)

# reduced keeps the FULL structural skeleton (first_dense count, pattern)
# so its logical-axes tree matches the full config's param tree
_reduced = LMConfig(
    name="gemma3-4b-reduced", n_layers=10, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, act="gelu_tanh",
    embed_scale=True, post_norms=True,
    layer_pattern=("l", "l", "l", "l", "l", "g"), window=16,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    first_dense=4, first_dense_ff=128, dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=2,
    name="gemma3-4b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: global layers are full attention",
)
