"""qwen1.5-32b [dense]: 64L d=5120 40H (MHA kv=40) d_ff=27392 vocab=152064.
QKV bias, SwiGLU, RMSNorm [hf:Qwen/Qwen1.5-32B]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.transformer import LMConfig

# int8 KV cache: the 64L x 40H MHA cache at 32k x 128 is 5.5 TB in bf16 —
# over 21 GB/chip even fully sharded on 256 chips.  int8 (+f32 scales)
# halves it AND halves decode HBM read traffic (EXPERIMENTS.md §Perf).
_full = LMConfig(
    name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    head_dim=128, d_ff=27392, vocab=152_064, qkv_bias=True, kv_quant=True,
)

_reduced = LMConfig(
    name="qwen1.5-32b-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, qkv_bias=True, dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=4,
    master_weights=True,
    name="qwen1.5-32b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention",
)
