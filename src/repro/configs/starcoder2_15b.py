"""starcoder2-15b [dense]: 40L d=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
GQA + RoPE (theta 1e5), LayerNorm, non-gated GeLU MLP, bias terms
[arXiv:2402.19173]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="starcoder2-15b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    head_dim=128, d_ff=24576, vocab=49_152, norm="layernorm", act="gelu_tanh",
    gated=False, qkv_bias=True, rope_base=100_000.0,
    kv_quant=True,
)

_reduced = LMConfig(
    name="starcoder2-15b-reduced", n_layers=4, d_model=64, n_heads=8, n_kv_heads=2,
    head_dim=8, d_ff=128, vocab=512, norm="layernorm", act="gelu_tanh",
    gated=False, qkv_bias=True, rope_base=100_000.0, dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=2,
    name="starcoder2-15b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention",
)
