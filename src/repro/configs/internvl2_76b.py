"""internvl2-76b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
InternViT frontend is a STUB per task spec (precomputed patch embeddings
prepended); the LM backbone (Llama-3-70B-style) is real [arXiv:2404.16821]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="internvl2-76b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=28672, vocab=128_256, rope_base=500_000.0,
    n_frontend_tokens=256,
    kv_quant=True,
)

_reduced = LMConfig(
    name="internvl2-76b-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, rope_base=500_000.0,
    n_frontend_tokens=8, dtype=jnp.float32,
)

spec = ArchSpec(
    train_microbatch=4,
    name="internvl2-76b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention",
)
