"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (
    deepseek_v2_lite,
    gcn_paper,
    gemma2_27b,
    gemma3_4b,
    internvl2_76b,
    mamba2_780m,
    olmoe_1b_7b,
    qwen15_32b,
    starcoder2_15b,
    whisper_small,
    zamba2_2p7b,
)

ARCHS = {
    m.spec.name: m.spec
    for m in (
        gemma2_27b,
        starcoder2_15b,
        gemma3_4b,
        qwen15_32b,
        olmoe_1b_7b,
        deepseek_v2_lite,
        whisper_small,
        mamba2_780m,
        internvl2_76b,
        zamba2_2p7b,
    )
}

GNN_ARCHS = {gcn_paper.spec.name: gcn_paper.spec}


def get(name: str):
    if name in ARCHS:
        return ARCHS[name]
    if name in GNN_ARCHS:
        return GNN_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(GNN_ARCHS)}")
