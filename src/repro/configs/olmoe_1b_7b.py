"""olmoe-1b-7b [moe]: 16L d=2048 16H d_ff(expert)=1024, 64 experts top-8,
vocab 50304 [arXiv:2409.02060].  Uses the SCV-inspired sorted dispatch —
the paper's technique applied to the token->expert ultra-sparse matrix
(DESIGN.md §2/§4)."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.layers import MoEConfig
from repro.models.transformer import LMConfig

_full = LMConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    head_dim=128, d_ff=1024, vocab=50_304,
    moe=MoEConfig(n_experts=64, top_k=8, d_model=2048, d_ff=1024,
                  capacity_factor=1.0),
)

_reduced = LMConfig(
    name="olmoe-reduced", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=32, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, capacity_factor=4.0),
    dtype=jnp.float32,
)

spec = ArchSpec(
    name="olmoe-1b-7b", kind="lm", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention",
    uses_paper_technique=True,
)
