"""whisper-small [audio]: 12+12L d=768 12H d_ff=3072 vocab=51865, enc-dec.
Conv frontend is a STUB per task spec: input_specs supplies precomputed
frame embeddings [arXiv:2212.04356]."""
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.models.encdec import EncDecConfig

_full = EncDecConfig(
    name="whisper-small", n_layers=12, d_model=768, n_heads=12, d_ff=3072,
    vocab=51_865, max_positions=32_768 + 8,
)

_reduced = EncDecConfig(
    name="whisper-small-reduced", n_layers=2, d_model=64, n_heads=4, d_ff=128,
    vocab=512, max_positions=128, dtype=jnp.float32,
)

spec = ArchSpec(
    name="whisper-small", kind="encdec", config=_full, reduced=_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes="long_500k skipped: full attention enc-dec",
)
