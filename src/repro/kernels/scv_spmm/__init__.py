from repro.kernels.scv_spmm.ops import scv_spmm, ensure_row_coverage
from repro.kernels.scv_spmm.ref import scv_spmm_reference

__all__ = ["scv_spmm", "scv_spmm_reference", "ensure_row_coverage"]
