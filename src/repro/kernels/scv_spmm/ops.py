"""Jit-ready public wrapper around the SCV SpMM Pallas kernel.

Handles:
* padding Z to (tile, feature_block) multiples,
* inserting zero-nnz dummy tiles so every PS block-row is visited (the
  kernel zero-initializes a strip on first visit; unvisited strips would
  be undefined),
* segmented (nnz-bucketed) plans: one kernel launch per capacity bucket,
  partial outputs summed (DESIGN.md §2),
* custom VJP: d/dZ = Â^T g (played through the reference segment-sum path,
  which XLA fuses well) and d/dvals = <g[row], z[col]> — making SCV
  aggregation trainable end-to-end (GNN training, §VII future work (i)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.scv_spmm import ref as _ref
from repro.kernels.scv_spmm.scv_spmm import scv_spmm_pallas


def ensure_row_coverage(
    tile_row: np.ndarray,
    tile_col: np.ndarray,
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    nnz_in_tile: np.ndarray,
    n_row_blocks: int,
):
    """Append one zero-nnz dummy tile per unvisited block-row (host-side)."""
    if rows.ndim != 2 or cols.ndim != 2 or vals.ndim != 2:
        raise ValueError(
            "entry arrays must be 2-D [n_tiles, cap]; got rows.ndim="
            f"{rows.ndim}, cols.ndim={cols.ndim}, vals.ndim={vals.ndim} "
            "(reshape 1-D per-entry arrays to (n_tiles, cap) first)"
        )
    missing = np.setdiff1d(
        np.arange(n_row_blocks, dtype=np.int32), np.unique(tile_row)
    )
    if len(missing) == 0:
        return tile_row, tile_col, rows, cols, vals, nnz_in_tile
    k, cap = len(missing), rows.shape[1]
    return (
        np.concatenate([tile_row, missing]),
        np.concatenate([tile_col, np.zeros(k, tile_col.dtype)]),
        np.concatenate([rows, np.zeros((k, cap), rows.dtype)]),
        np.concatenate([cols, np.zeros((k, cap), cols.dtype)]),
        np.concatenate([vals, np.zeros((k, cap), vals.dtype)]),
        np.concatenate([nnz_in_tile, np.zeros(k, nnz_in_tile.dtype)]),
    )


def _feature_block_for(f: int, feature_block: int) -> int:
    """Clamp the feature block to the lane-padded (128-multiple) feature
    width — the one clamp rule shared by ``scv_spmm`` and
    ``scv_spmm_plan`` so a pre-padded Z always matches the inner kernel."""
    return min(feature_block, -(-f // 128) * 128)


def _pad_z(z: jnp.ndarray, tile: int, feature_block: int) -> jnp.ndarray:
    n, f = z.shape
    np_ = -(-n // tile) * tile
    fp = -(-f // feature_block) * feature_block
    if (np_, fp) == (n, f):
        return z
    return jnp.zeros((np_, fp), z.dtype).at[:n, :f].set(z)


def _infer_nnz(rows, cols, vals) -> jnp.ndarray:
    """Per-tile nnz from structural padding (legacy no-nnz callers).

    Padding slots are a suffix of each tile row with val == 0 AND
    row == col == 0; the inferred count is one past the last slot that
    breaks that pattern.  (A *real* trailing entry at local (0, 0) with
    value exactly 0 is indistinguishable from padding — it contributes
    nothing to the forward either way, and its d/dvals is dropped; pass
    ``nnz_in_tile`` explicitly where that distinction matters.)
    """
    if vals.shape[1] == 0:
        return jnp.zeros(vals.shape[0], jnp.int32)
    slot = jnp.arange(vals.shape[1], dtype=jnp.int32)[None, :]
    is_real = (vals != 0) | (rows != 0) | (cols != 0)
    return jnp.max(jnp.where(is_real, slot + 1, 0), axis=1).astype(jnp.int32)


# custom_vjp over (vals, z).  The integer index arrays are regular
# (residual-carried) arguments rather than nondiff_argnums: nondiff_argnums
# rejects tracers, and under an end-to-end jitted GNN forward (plans are
# pytree *arguments*, not closure constants) every plan array arrives as a
# tracer.  Their cotangents are symbolic float0 zeros.
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12, 13))
def _spmm(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z,
          tile, n_rows, feature_block, interpret, body, chunk, dense_threshold):
    return scv_spmm_pallas(
        tile_row, tile_col, nnz_in_tile, rows, cols, vals, z,
        tile=tile, n_rows=n_rows, feature_block=feature_block,
        interpret=interpret, body=body, chunk=chunk,
        dense_threshold=dense_threshold,
    )


def _spmm_fwd(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z,
              tile, n_rows, feature_block, interpret, body, chunk, dense_threshold):
    out = _spmm(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z,
                tile, n_rows, feature_block, interpret, body, chunk,
                dense_threshold)
    return out, (tile_row, tile_col, nnz_in_tile, rows, cols, vals, z)


def _entry_grads(tile, tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, g):
    """(dvals, dz) for one launch — shared by the plain and the
    accumulate-mode VJPs (the acc contribution is identity: d/dacc = g)."""
    grows = (tile_row[:, None] * tile + rows).reshape(-1)
    gcols = (tile_col[:, None] * tile + cols).reshape(-1)
    gf = g.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    # d/dvals_e = <g[row_e], z[col_e]>
    dvals = jnp.sum(gf[grows] * zf[gcols], axis=-1).reshape(vals.shape)
    # mask padding slots (their val is structurally zero)
    slot = jnp.arange(vals.shape[1], dtype=jnp.int32)[None, :]
    dvals = jnp.where(slot < nnz_in_tile[:, None], dvals, 0.0).astype(vals.dtype)
    # d/dZ = A^T g : scatter-add g rows into z rows, weighted
    dz = jnp.zeros(z.shape, jnp.float32)
    dz = dz.at[gcols].add(gf[grows] * vals.reshape(-1)[:, None].astype(jnp.float32))
    return dvals, dz.astype(z.dtype)


def _f0(a):  # integer-typed primals take float0 cotangents
    # jax requires float0 cotangents as *numpy* arrays (jnp.zeros
    # cannot hold dtype float0) — deliberate host-side constant.
    return np.zeros(a.shape, jax.dtypes.float0)  # scvlint: ignore[SCV001]


def _spmm_bwd(tile, n_rows, feature_block, interpret, body, chunk,
              dense_threshold, res, g):
    tile_row, tile_col, nnz_in_tile, rows, cols, vals, z = res
    dvals, dz = _entry_grads(
        tile, tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, g
    )
    return (
        _f0(tile_row), _f0(tile_col), _f0(nnz_in_tile), _f0(rows), _f0(cols),
        dvals, dz,
    )


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


# Accumulate-mode launch: out = acc + Â Z with the accumulator aliased onto
# the output buffer.  ``acc`` is a *differentiable* operand — the chain
# out_k = out_{k-1} + contrib_k backpropagates by plain composition, each
# link passing the cotangent through to its predecessor unchanged.
@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11, 12, 13, 14))
def _spmm_acc(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, acc,
              tile, n_rows, feature_block, interpret, body, chunk,
              dense_threshold):
    return scv_spmm_pallas(
        tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, acc,
        tile=tile, n_rows=n_rows, feature_block=feature_block,
        interpret=interpret, body=body, chunk=chunk,
        dense_threshold=dense_threshold,
    )


def _spmm_acc_fwd(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, acc,
                  tile, n_rows, feature_block, interpret, body, chunk,
                  dense_threshold):
    out = _spmm_acc(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, acc,
                    tile, n_rows, feature_block, interpret, body, chunk,
                    dense_threshold)
    return out, (tile_row, tile_col, nnz_in_tile, rows, cols, vals, z)


def _spmm_acc_bwd(tile, n_rows, feature_block, interpret, body, chunk,
                  dense_threshold, res, g):
    tile_row, tile_col, nnz_in_tile, rows, cols, vals, z = res
    dvals, dz = _entry_grads(
        tile, tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, g
    )
    # out = acc + contribution, identically in every row: d/dacc = g
    return (
        _f0(tile_row), _f0(tile_col), _f0(nnz_in_tile), _f0(rows), _f0(cols),
        dvals, dz, g,
    )


_spmm_acc.defvjp(_spmm_acc_fwd, _spmm_acc_bwd)


def scv_spmm(
    tile_row: jnp.ndarray,
    tile_col: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: jnp.ndarray,
    z: jnp.ndarray,
    *,
    tile: int,
    n_rows: int,
    nnz_in_tile: jnp.ndarray | None = None,
    feature_block: int = 256,
    interpret: bool = False,
    body: str = "vector",
    chunk: int | None = None,
    dense_threshold: int | None = None,
) -> jnp.ndarray:
    """out = Â Z over the SCV tile layout.  Returns f32[n_rows, F]."""
    from repro.core.scv import DEFAULT_CHUNK

    if tile_row.shape[0] == 0:
        return jnp.zeros((n_rows, z.shape[1]), jnp.float32)
    f_orig = z.shape[1]
    feature_block = _feature_block_for(f_orig, feature_block)
    zp = _pad_z(z, tile, feature_block)
    if nnz_in_tile is None:
        # infer the structural padding suffix: without a mask, d/dvals
        # would be nonzero on padding slots (they share local (0, 0) with a
        # real corner entry, and <g[0], z[0]> is generally nonzero)
        nnz_in_tile = _infer_nnz(rows, cols, vals)
    out = _spmm(
        tile_row.astype(jnp.int32),
        tile_col.astype(jnp.int32),
        nnz_in_tile.astype(jnp.int32),
        rows.astype(jnp.int32),
        cols.astype(jnp.int32),
        vals,
        zp,
        tile,
        n_rows,
        feature_block,
        interpret,
        body,
        int(DEFAULT_CHUNK if chunk is None else chunk),
        dense_threshold,
    )
    return out[:, :f_orig]


def scv_spmm_plan(
    plan,
    z: jnp.ndarray,
    *,
    feature_block: int = 256,
    interpret: bool = False,
    body: str = "vector",
    chunk: int | None = None,
    dense_threshold: int | None = None,
    init: str = "coverage",
) -> jnp.ndarray:
    """``scv_spmm`` over a ``core.scv`` plan pytree (``SCVPlan`` or the
    nnz-bucketed ``SCVBucketedPlan``).

    All static kernel configuration (tile size, padded row count, entry
    capacity via the leaf shapes, the bucket ladder via the segment tuple)
    comes from the plan's aux data — nothing needs to be threaded alongside
    the arrays, so callers stay jit-able.  A bucketed plan runs one kernel
    launch per capacity segment, **chained through one accumulator**: the
    first launch zero-initializes its strips (its coverage dummies define
    the whole output — ``plan_from_tiles_bucketed`` emits them in the
    first segment only), and every later launch runs in accumulate mode
    (``input_output_aliases``) — visited strips are seeded from the
    previous launch's output, unvisited strips pass through.  Coverage
    dummies therefore exist once per *plan*, not once per segment at that
    segment's cap, and there is no partial-output sum tree.  Z is padded
    **once** for all segments (same tile, same feature_block — per-launch
    re-padding would be redundant work in eager mode).

    ``init="zeros"`` starts the chain from an explicit zero accumulator
    instead: every row is then defined even when *no* segment covers it —
    the executor's sharded spans (which carry no per-span coverage) use
    this mode.

    Under the executor's feature-axis sharding (``core.exec``), ``z`` is a
    device-local ``Z[:, f0:f1]`` slab: the kernel's feature-block grid
    axis then simply runs over fewer blocks — the mesh mapping happens at
    the ``shard_map`` layer, the kernel is unchanged.
    """
    from repro.core.scv import DEFAULT_CHUNK

    if init not in ("coverage", "zeros"):
        raise ValueError(f"init must be 'coverage' or 'zeros', got {init!r}")
    # a bare SCVPlan is a 1-tuple; SCVBucketedPlan guarantees >= 1 segment
    segments = getattr(plan, "segments", (plan,))
    f_orig = z.shape[1]
    fb = _feature_block_for(f_orig, feature_block)
    zp = _pad_z(z, segments[0].tile, fb)
    n_rows = segments[0].padded_shape[0]
    chunk = int(DEFAULT_CHUNK if chunk is None else chunk)
    out = None
    if init == "zeros":
        out = jnp.zeros((n_rows, zp.shape[1]), jnp.float32)
    for seg in segments:
        args = (
            seg.tile_row.astype(jnp.int32),
            seg.tile_col.astype(jnp.int32),
            seg.nnz_in_tile.astype(jnp.int32),
            seg.rows.astype(jnp.int32),
            seg.cols.astype(jnp.int32),
            seg.vals,
            zp,
        )
        statics = (seg.tile, n_rows, fb, interpret, body, chunk, dense_threshold)
        if seg.tile_row.shape[0] == 0:  # empty segment: nothing to launch
            if out is None:
                out = jnp.zeros((n_rows, zp.shape[1]), jnp.float32)
            continue
        if out is None:
            out = _spmm(*args, *statics)
        else:
            out = _spmm_acc(*args, out, *statics)
    return out[:, :f_orig]


def scv_spmm_reference(*args, **kw):
    return _ref.scv_spmm_reference(*args, **kw)
