"""Jit-ready public wrapper around the SCV SpMM Pallas kernel.

Handles:
* padding Z to (tile, feature_block) multiples,
* inserting zero-nnz dummy tiles so every PS block-row is visited (the
  kernel zero-initializes a strip on first visit; unvisited strips would
  be undefined),
* custom VJP: d/dZ = Â^T g (played through the reference segment-sum path,
  which XLA fuses well) and d/dvals = <g[row], z[col]> — making SCV
  aggregation trainable end-to-end (GNN training, §VII future work (i)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.scv_spmm import ref as _ref
from repro.kernels.scv_spmm.scv_spmm import scv_spmm_pallas


def ensure_row_coverage(
    tile_row: np.ndarray,
    tile_col: np.ndarray,
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    nnz_in_tile: np.ndarray,
    n_row_blocks: int,
):
    """Append one zero-nnz dummy tile per unvisited block-row (host-side)."""
    missing = np.setdiff1d(
        np.arange(n_row_blocks, dtype=np.int32), np.unique(tile_row)
    )
    if len(missing) == 0:
        return tile_row, tile_col, rows, cols, vals, nnz_in_tile
    k, cap = len(missing), rows.shape[1] if rows.ndim == 2 else 1
    return (
        np.concatenate([tile_row, missing]),
        np.concatenate([tile_col, np.zeros(k, tile_col.dtype)]),
        np.concatenate([rows, np.zeros((k, cap), rows.dtype)]),
        np.concatenate([cols, np.zeros((k, cap), cols.dtype)]),
        np.concatenate([vals, np.zeros((k, cap), vals.dtype)]),
        np.concatenate([nnz_in_tile, np.zeros(k, nnz_in_tile.dtype)]),
    )


def _pad_z(z: jnp.ndarray, tile: int, feature_block: int) -> jnp.ndarray:
    n, f = z.shape
    np_ = -(-n // tile) * tile
    fp = -(-f // feature_block) * feature_block
    if (np_, fp) == (n, f):
        return z
    return jnp.zeros((np_, fp), z.dtype).at[:n, :f].set(z)


# custom_vjp over (vals, z).  The integer index arrays are regular
# (residual-carried) arguments rather than nondiff_argnums: nondiff_argnums
# rejects tracers, and under an end-to-end jitted GNN forward (plans are
# pytree *arguments*, not closure constants) every plan array arrives as a
# tracer.  Their cotangents are symbolic float0 zeros.
@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _spmm(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, tile, n_rows, feature_block, interpret):
    return scv_spmm_pallas(
        tile_row, tile_col, nnz_in_tile, rows, cols, vals, z,
        tile=tile, n_rows=n_rows, feature_block=feature_block, interpret=interpret,
    )


def _spmm_fwd(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, tile, n_rows, feature_block, interpret):
    out = _spmm(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z, tile, n_rows, feature_block, interpret)
    return out, (tile_row, tile_col, nnz_in_tile, rows, cols, vals, z)


def _spmm_bwd(tile, n_rows, feature_block, interpret, res, g):
    tile_row, tile_col, nnz_in_tile, rows, cols, vals, z = res
    grows = (tile_row[:, None] * tile + rows).reshape(-1)
    gcols = (tile_col[:, None] * tile + cols).reshape(-1)
    gf = g.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    # d/dvals_e = <g[row_e], z[col_e]>
    dvals = jnp.sum(gf[grows] * zf[gcols], axis=-1).reshape(vals.shape)
    # mask padding slots (their val is structurally zero)
    slot = jnp.arange(vals.shape[1], dtype=jnp.int32)[None, :]
    dvals = jnp.where(slot < nnz_in_tile[:, None], dvals, 0.0).astype(vals.dtype)
    # d/dZ = A^T g : scatter-add g rows into z rows, weighted
    dz = jnp.zeros(z.shape, jnp.float32)
    dz = dz.at[gcols].add(gf[grows] * vals.reshape(-1)[:, None].astype(jnp.float32))

    def f0(a):  # integer-typed primals take float0 cotangents
        return np.zeros(a.shape, jax.dtypes.float0)

    return (
        f0(tile_row), f0(tile_col), f0(nnz_in_tile), f0(rows), f0(cols),
        dvals, dz.astype(z.dtype),
    )


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


def scv_spmm(
    tile_row: jnp.ndarray,
    tile_col: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: jnp.ndarray,
    z: jnp.ndarray,
    *,
    tile: int,
    n_rows: int,
    nnz_in_tile: jnp.ndarray | None = None,
    feature_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """out = Â Z over the SCV tile layout.  Returns f32[n_rows, F]."""
    if tile_row.shape[0] == 0:
        return jnp.zeros((n_rows, z.shape[1]), jnp.float32)
    f_orig = z.shape[1]
    feature_block = min(feature_block, -(-f_orig // 128) * 128)
    zp = _pad_z(z, tile, feature_block)
    if nnz_in_tile is None:
        # infer: padding slots have val exactly 0 *and* row/col 0; count
        # conservatively as "all slots" (val==0 slots are harmless anyway)
        nnz_in_tile = jnp.full(tile_row.shape, vals.shape[1], jnp.int32)
    out = _spmm(
        tile_row.astype(jnp.int32),
        tile_col.astype(jnp.int32),
        nnz_in_tile.astype(jnp.int32),
        rows.astype(jnp.int32),
        cols.astype(jnp.int32),
        vals,
        zp,
        tile,
        n_rows,
        feature_block,
        interpret,
    )
    return out[:, :f_orig]


def scv_spmm_plan(
    plan,
    z: jnp.ndarray,
    *,
    feature_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """``scv_spmm`` over a ``core.scv.SCVPlan`` pytree.

    All static kernel configuration (tile size, padded row count, entry
    capacity via the leaf shapes) comes from the plan's aux data — nothing
    needs to be threaded alongside the arrays, so callers stay jit-able.
    """
    return scv_spmm(
        plan.tile_row, plan.tile_col, plan.rows, plan.cols, plan.vals, z,
        tile=plan.tile, n_rows=plan.padded_shape[0],
        nnz_in_tile=plan.nnz_in_tile,
        feature_block=feature_block, interpret=interpret,
    )


def scv_spmm_reference(*args, **kw):
    return _ref.scv_spmm_reference(*args, **kw)
