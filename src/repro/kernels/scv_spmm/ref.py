"""Pure-jnp oracle for the SCV SpMM kernel.

Numerically identical to the Pallas kernel (same tile layout, same
accumulation order up to float-add reassociation); used by unit tests and
as the CPU fallback backend in ``core.aggregate``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("tile", "n_rows"))
def scv_spmm_reference(
    tile_row: jnp.ndarray,  # i32[nt]
    tile_col: jnp.ndarray,  # i32[nt]
    rows: jnp.ndarray,  # i32[nt, cap] local row within tile
    cols: jnp.ndarray,  # i32[nt, cap] local col within tile
    vals: jnp.ndarray,  # f32[nt, cap] (0 for padding slots)
    z: jnp.ndarray,  # [n_cols, F] dense combined features
    *,
    tile: int,
    n_rows: int,
    nnz_in_tile: jnp.ndarray | None = None,  # i32[nt] — masks padding slots
) -> jnp.ndarray:
    """out[tile_row*T + rows] += vals * z[tile_col*T + cols]  (accum f32).

    Padding slots are structural zeros: masking them (rather than relying
    on val == 0) keeps d/dvals zero there, matching the kernel's VJP.
    """
    if tile_row.shape[0] == 0:
        return jnp.zeros((n_rows, z.shape[1]), jnp.float32)
    if nnz_in_tile is not None:
        slot = jnp.arange(vals.shape[1], dtype=jnp.int32)[None, :]
        vals = jnp.where(slot < nnz_in_tile[:, None], vals, 0.0)
    gcols = (tile_col[:, None] * tile + cols).reshape(-1)
    grows = (tile_row[:, None] * tile + rows).reshape(-1)
    gathered = z[gcols].astype(jnp.float32) * vals.reshape(-1)[:, None].astype(
        jnp.float32
    )
    return jax.ops.segment_sum(gathered, grows, num_segments=n_rows)


def scv_spmm_reference_plan(plan, z: jnp.ndarray) -> jnp.ndarray:
    """Oracle over a ``core.scv`` plan pytree — ``SCVPlan`` or the
    nnz-bucketed ``SCVBucketedPlan`` (duck-typed on ``segments`` to keep
    this module import-light).  Returns the *padded* [n_rows_p, F] output,
    matching ``ops.scv_spmm_plan``; segment partials sum exactly like the
    per-bucket kernel launches."""
    n_rows = plan.padded_shape[0]
    segments = getattr(plan, "segments", (plan,))
    out = jnp.zeros((n_rows, z.shape[1]), jnp.float32)
    for seg in segments:
        zp = z
        if z.shape[0] < seg.padded_shape[1]:
            zp = jnp.zeros((seg.padded_shape[1], z.shape[1]), z.dtype).at[
                : z.shape[0]
            ].set(z)
        out = out + scv_spmm_reference(
            seg.tile_row, seg.tile_col, seg.rows, seg.cols, seg.vals, zp,
            tile=seg.tile, n_rows=n_rows, nnz_in_tile=seg.nnz_in_tile,
        )
    return out
