"""Pallas TPU kernel for SCV aggregation (DESIGN.md §2).

Mapping of the paper's mechanisms onto Pallas/TPU:

* One grid step processes one SCV tile (a Z-Morton vector group: T column
  vectors of height T).  ``PrefetchScalarGridSpec`` prefetches the tile
  coordinate arrays so the BlockSpec index maps are data-dependent — the
  "implicitly stores non-zero column locations → efficient prefetching"
  property of §III-B: Pallas double-buffers the *next* tile's Z block while
  the current tile computes, and skips the copy entirely when consecutive
  tiles share a column block (SCV's Z-reuse).

* The output BlockSpec revisits the same PS strip for every tile of a
  block-row; because the tile schedule keeps block-rows contiguous
  (``SCVTiles`` invariant), the strip lives in VMEM across all its tiles
  and is written back to HBM exactly once — §III-B's "fetched PS rows are
  reused multiple times before being evicted".

* Within a tile, entries are in column-vector order; consecutive entries
  hit *different* PS sublanes (distinct rows within a vector), so the FMA
  chain has no same-address RAW dependency — the TPU analogue of the
  paper's hazard-free parallelism (§IV-B); see DESIGN.md for the mapping.

* Padding entries carry val == 0 and are additionally skipped by bounding
  the entry loop with the prefetched per-tile nnz.

VMEM budget per step (defaults T=256, Fb=256, cap<=2048):
  Z block 256x256 f32 = 256 KiB, PS block 256 KiB, entries ~24 KiB
  -> ~0.6 MiB double-buffered, comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    # scalar-prefetch operands
    tile_row_ref,  # i32[nt]
    tile_col_ref,  # i32[nt]  (steers z BlockSpec; unused in body)
    nnz_ref,  # i32[nt]
    # array operands
    rows_ref,  # i32[1, cap]   (SMEM) local row of each entry
    cols_ref,  # i32[1, cap]   (SMEM) local col of each entry
    vals_ref,  # f32[1, cap]   (SMEM) value of each entry
    z_ref,  # [T, Fb]       (VMEM) combined-feature block
    out_ref,  # f32[T, Fb]    (VMEM) PS strip block
):
    t = pl.program_id(1)

    # Fresh PS strip?  (first tile overall, or block-row changed.)
    prev = jnp.maximum(t - 1, 0)
    new_strip = jnp.logical_or(t == 0, tile_row_ref[t] != tile_row_ref[prev])

    @pl.when(new_strip)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nnz = nnz_ref[t]

    def body(i, _):
        r = rows_ref[0, i]
        c = cols_ref[0, i]
        v = vals_ref[0, i].astype(jnp.float32)
        zrow = z_ref[pl.ds(c, 1), :].astype(jnp.float32)
        out_ref[pl.ds(r, 1), :] += v * zrow
        return 0

    # NB: `unroll` requires statically-known bounds; nnz is dynamic.
    jax.lax.fori_loop(0, nnz, body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "n_rows", "feature_block", "interpret"),
)
def scv_spmm_pallas(
    tile_row: jnp.ndarray,  # i32[nt]
    tile_col: jnp.ndarray,  # i32[nt]
    nnz_in_tile: jnp.ndarray,  # i32[nt]
    rows: jnp.ndarray,  # i32[nt, cap]
    cols: jnp.ndarray,  # i32[nt, cap]
    vals: jnp.ndarray,  # f32[nt, cap]
    z: jnp.ndarray,  # [n_cols_padded, F_padded] — multiples of (tile, feature_block)
    *,
    tile: int,
    n_rows: int,  # padded to a multiple of tile
    feature_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    nt, cap = vals.shape
    n_cols_p, f_p = z.shape
    T, Fb = tile, feature_block
    assert n_rows % T == 0 and n_cols_p % T == 0 and f_p % Fb == 0, (
        n_rows,
        z.shape,
        T,
        Fb,
    )

    grid = (f_p // Fb, nt)  # feature blocks outer, tiles inner

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            # entry coordinate/value arrays: one tile's slice per step, SMEM
            pl.BlockSpec(
                (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec(
                (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec(
                (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=pltpu.SMEM
            ),
            # Z block steered by the prefetched tile column
            pl.BlockSpec((T, Fb), lambda f, t, tr, tc, nz: (tc[t], f)),
        ],
        out_specs=pl.BlockSpec((T, Fb), lambda f, t, tr, tc, nz: (tr[t], f)),
    )

    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, f_p), jnp.float32),
        interpret=interpret,
    )(tile_row, tile_col, nnz_in_tile, rows, cols, vals, z)
