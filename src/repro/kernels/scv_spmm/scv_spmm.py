"""Pallas TPU kernel for SCV aggregation (DESIGN.md §2).

Mapping of the paper's mechanisms onto Pallas/TPU:

* One grid step processes one SCV tile (a Z-Morton vector group: T column
  vectors of height T).  ``PrefetchScalarGridSpec`` prefetches the tile
  coordinate arrays so the BlockSpec index maps are data-dependent — the
  "implicitly stores non-zero column locations → efficient prefetching"
  property of §III-B: Pallas double-buffers the *next* tile's Z block while
  the current tile computes, and skips the copy entirely when consecutive
  tiles share a column block (SCV's Z-reuse).

* The output BlockSpec revisits the same PS strip for every tile of a
  block-row; because the tile schedule keeps block-rows contiguous
  (``SCVTiles`` invariant), the strip lives in VMEM across all its tiles
  and is written back to HBM exactly once — §III-B's "fetched PS rows are
  reused multiple times before being evicted".

* Two kernel bodies (DESIGN.md §2):

  - ``body="vector"`` (default) — per chunk of C entries, a ``(T, C)``
    scatter matrix S (``S[t, j] = vals[j] * (rows[j] == t)``, built from a
    ``broadcasted_iota`` one-hot compare) and a ``(T, C)`` gather one-hot
    G (``G[u, j] = cols[j] == u``) turn the chunk into two MXU matmuls:
    ``out += S @ (Gᵀ Z)``.  Entries within a chunk land in *different* PS
    sublanes (the SCV column-vector order), and the matmul formulation
    removes the per-entry serialization entirely.  Tiles whose prefetched
    nnz exceeds ``dense_tile_threshold(T)`` are instead densified
    in-kernel (``D += S Gᵀ``, a ``(T, T)`` block) and hit the MXU as one
    plain ``out += D @ Z`` matmul — the hybrid selection rule
    ``benchmarks/kernel_roofline.py`` models, implemented.  Coverage-dummy
    tiles (nnz == 0) skip all compute via ``pl.when``.

  - ``body="scalar"`` — the pre-vectorization per-entry FMA loop, kept as
    the measured baseline for ``benchmarks/kernel_bench.py``.

* Padding entries carry val == 0 and are additionally skipped by bounding
  the chunk/entry loop with the prefetched per-tile nnz.

VMEM budget per step (defaults T=256, Fb=256, cap<=2048):
  Z block 256x256 f32 = 256 KiB, PS block 256 KiB, entries ~24 KiB,
  dense scratch 256 KiB -> ~0.8 MiB double-buffered, comfortably inside
  the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scv import DEFAULT_CHUNK, dense_tile_threshold


def _kernel_scalar(
    # scalar-prefetch operands
    tile_row_ref,  # i32[nt]
    tile_col_ref,  # i32[nt]  (steers z BlockSpec; unused in body)
    nnz_ref,  # i32[nt]
    # array operands
    rows_ref,  # i32[1, cap]   (SMEM) local row of each entry
    cols_ref,  # i32[1, cap]   (SMEM) local col of each entry
    vals_ref,  # f32[1, cap]   (SMEM) value of each entry
    z_ref,  # [T, Fb]       (VMEM) combined-feature block
    *refs,  # (out_ref,) or (acc_ref, out_ref) in accumulate mode
):
    acc_ref, out_ref = refs if len(refs) == 2 else (None, refs[0])
    t = pl.program_id(1)

    # Fresh PS strip?  (first tile overall, or block-row changed.)
    prev = jnp.maximum(t - 1, 0)
    new_strip = jnp.logical_or(t == 0, tile_row_ref[t] != tile_row_ref[prev])

    @pl.when(new_strip)
    def _init():
        # accumulate mode: seed the strip from the chained accumulator
        # (the prior launch's output, aliased into this launch's buffer)
        # instead of zero — unvisited strips pass through untouched.
        if acc_ref is None:
            out_ref[...] = jnp.zeros_like(out_ref)
        else:
            out_ref[...] = acc_ref[...]

    nnz = nnz_ref[t]

    def body(i, _):
        r = rows_ref[0, i]
        c = cols_ref[0, i]
        v = vals_ref[0, i].astype(jnp.float32)
        zrow = z_ref[pl.ds(c, 1), :].astype(jnp.float32)
        out_ref[pl.ds(r, 1), :] += v * zrow
        return 0

    # No `unroll=`: jax (0.4.x and current) raises ValueError for
    # unrolled fori_loop with traced bounds, and nnz is prefetched data.
    jax.lax.fori_loop(0, nnz, body, 0)


def _kernel_vector(
    tile_row_ref,  # i32[nt]
    tile_col_ref,  # i32[nt]  (steers z BlockSpec; unused in body)
    nnz_ref,  # i32[nt]
    rows_ref,  # i32[1, cap]   (VMEM) local row of each entry
    cols_ref,  # i32[1, cap]   (VMEM) local col of each entry
    vals_ref,  # f32[1, cap]   (VMEM) value of each entry
    z_ref,  # [T, Fb]       (VMEM) combined-feature block
    *refs,  # (out_ref,) or (acc_ref, out_ref) in accumulate mode
    tile: int,
    chunk: int,
    dense_threshold: int,
):
    acc_ref, out_ref = refs if len(refs) == 2 else (None, refs[0])
    T, C = tile, chunk
    t = pl.program_id(1)

    prev = jnp.maximum(t - 1, 0)
    new_strip = jnp.logical_or(t == 0, tile_row_ref[t] != tile_row_ref[prev])

    @pl.when(new_strip)
    def _init():
        if acc_ref is None:
            out_ref[...] = jnp.zeros_like(out_ref)
        else:
            out_ref[...] = acc_ref[...]

    nnz = nnz_ref[t]
    n_chunks = (nnz + C - 1) // C
    iota_tc = jax.lax.broadcasted_iota(jnp.int32, (T, C), 0)

    def chunk_mats(k):
        """Scatter matrix S[t, j] = vals[j]*(rows[j]==t) and gather one-hot
        G[u, j] = (cols[j]==u) for chunk k.  Padding entries have val == 0,
        so their S column is zero and they contribute nothing."""
        sl = pl.ds(k * C, C)
        r = rows_ref[:, sl]  # (1, C) broadcasts against the (T, C) iota
        c = cols_ref[:, sl]
        v = vals_ref[:, sl].astype(jnp.float32)
        scatter = jnp.where(iota_tc == r, v, 0.0)
        onehot = (iota_tc == c).astype(jnp.float32)
        return scatter, onehot

    # Hybrid rule: a tile dense enough that T^2 MXU MACs beat nnz VPU FMAs
    # is densified in-kernel and runs as one plain matmul.  The branch is
    # compiled out when no tile of this capacity can reach the threshold.
    use_dense = 0 <= dense_threshold < rows_ref.shape[1]
    is_dense = nnz > dense_threshold if use_dense else False

    @pl.when(jnp.logical_and(nnz > 0, jnp.logical_not(is_dense)))
    def _sparse():
        z = z_ref[...].astype(jnp.float32)

        def body(k, _):
            scatter, onehot = chunk_mats(k)
            # gathered[j, :] = Z[cols[j], :]  (one-hot matmul == exact gather)
            gathered = jax.lax.dot_general(
                onehot, z, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            out_ref[...] += jax.lax.dot_general(
                scatter, gathered, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            return 0

        jax.lax.fori_loop(0, n_chunks, body, 0)

    if use_dense:

        @pl.when(is_dense)
        def _dense():
            def body(k, d):
                scatter, onehot = chunk_mats(k)
                # D[t, u] += sum_j vals[j] * (rows[j]==t) * (cols[j]==u)
                return d + jax.lax.dot_general(
                    scatter, onehot, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )

            d = jax.lax.fori_loop(
                0, n_chunks, body, jnp.zeros((T, T), jnp.float32)
            )
            out_ref[...] += jax.lax.dot_general(
                d, z_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile", "n_rows", "feature_block", "interpret", "body", "chunk",
        "dense_threshold",
    ),
)
def scv_spmm_pallas(
    tile_row: jnp.ndarray,  # i32[nt]
    tile_col: jnp.ndarray,  # i32[nt]
    nnz_in_tile: jnp.ndarray,  # i32[nt]
    rows: jnp.ndarray,  # i32[nt, cap]
    cols: jnp.ndarray,  # i32[nt, cap]
    vals: jnp.ndarray,  # f32[nt, cap]
    z: jnp.ndarray,  # [n_cols_padded, F_padded] — multiples of (tile, feature_block)
    acc: jnp.ndarray | None = None,  # f32[n_rows, F_padded] chained accumulator
    *,
    tile: int,
    n_rows: int,  # padded to a multiple of tile
    feature_block: int = 256,
    interpret: bool = False,
    body: str = "vector",
    chunk: int = DEFAULT_CHUNK,
    dense_threshold: int | None = None,
) -> jnp.ndarray:
    """One SCV SpMM launch.

    With ``acc`` (accumulate mode) the launch computes ``acc + Â Z``
    instead of ``Â Z``: the accumulator is aliased onto the output buffer
    (``input_output_aliases``), visited PS strips are *seeded* from it on
    first visit, and unvisited strips pass through untouched — so a chain
    of launches (one per capacity bucket) needs coverage dummies only in
    its first link (DESIGN.md §2).
    """
    nt, cap = vals.shape
    n_cols_p, f_p = z.shape
    T, Fb = tile, feature_block
    assert n_rows % T == 0 and n_cols_p % T == 0 and f_p % Fb == 0, (
        n_rows,
        z.shape,
        T,
        Fb,
    )

    if body == "vector":
        # chunk the entry arrays evenly: pad cap up to a multiple of the
        # chunk size (static shapes; the pad slots are structural zeros)
        C = min(int(chunk), max(cap, 1))
        if cap % C:
            pad = C - cap % C
            rows = jnp.pad(rows, ((0, 0), (0, pad)))
            cols = jnp.pad(cols, ((0, 0), (0, pad)))
            vals = jnp.pad(vals, ((0, 0), (0, pad)))
            cap += pad
        thr = dense_tile_threshold(T) if dense_threshold is None else int(dense_threshold)
        kernel = functools.partial(
            _kernel_vector, tile=T, chunk=C, dense_threshold=thr
        )
        # entry arrays feed vector compute (iota compares + matmuls): VMEM
        entry_space = pltpu.VMEM
    elif body == "scalar":
        kernel = _kernel_scalar
        entry_space = pltpu.SMEM
    else:
        raise ValueError(f"unknown kernel body {body!r}")

    grid = (f_p // Fb, nt)  # feature blocks outer, tiles inner

    in_specs = [
        # entry coordinate/value arrays: one tile's slice per step
        pl.BlockSpec(
            (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=entry_space
        ),
        pl.BlockSpec(
            (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=entry_space
        ),
        pl.BlockSpec(
            (1, cap), lambda f, t, tr, tc, nz: (t, 0), memory_space=entry_space
        ),
        # Z block steered by the prefetched tile column
        pl.BlockSpec((T, Fb), lambda f, t, tr, tc, nz: (tc[t], f)),
    ]
    operands = (tile_row, tile_col, nnz_in_tile, rows, cols, vals, z)
    aliases = {}
    if acc is not None:
        assert acc.shape == (n_rows, f_p), (acc.shape, n_rows, f_p)
        # the accumulator rides the same index map as the output: the
        # kernel seeds each strip from its acc block on first visit, and
        # the buffer alias (acc is input 7 counting the scalar-prefetch
        # operands) makes unvisited strips retain the accumulator bytes
        in_specs.append(pl.BlockSpec((T, Fb), lambda f, t, tr, tc, nz: (tr[t], f)))
        operands += (acc.astype(jnp.float32),)
        aliases = {7: 0}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((T, Fb), lambda f, t, tr, tc, nz: (tr[t], f)),
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, f_p), jnp.float32),
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
