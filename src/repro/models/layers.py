"""Neural-net building blocks for the assigned architecture zoo.

Everything is pure-functional JAX: ``init_*`` returns (params, specs)
where ``specs`` mirrors the params pytree with *logical axis names*;
``repro.train.sharding`` maps logical axes -> mesh axes (MaxText-style
rules), so the same model code runs on 1 CPU device and on the 512-chip
production mesh.

Attention supports the variant matrix required by the zoo: GQA, RoPE (per-
layer base), QKV bias (qwen), logit softcapping (gemma2), sliding-window
local layers (gemma2/gemma3), and MLA (deepseek-v2).  Long sequences use a
blockwise (flash-style, online-softmax) formulation so 32k prefill fits.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Specs = dict

# ---------------------------------------------------------------------------
# param helpers
# ---------------------------------------------------------------------------


def _init_dense(key, shape, scale_axis=0):
    scale = 1.0 / math.sqrt(max(1, shape[scale_axis]))
    return jax.random.normal(key, shape, jnp.float32) * scale


def make_param(key, shape, axes, scale_axis=0, zeros=False):
    """Returns (array, logical-axes tuple)."""
    arr = (
        jnp.zeros(shape, jnp.float32)
        if zeros
        else _init_dense(key, shape, scale_axis)
    )
    assert len(axes) == len(shape), (axes, shape)
    return arr, axes


def split_tree(tree):
    """Split {name: (arr, axes)} nested dict -> (params, specs)."""
    if isinstance(tree, tuple) and len(tree) == 2 and not isinstance(tree[0], dict):
        return tree[0], tree[1]
    params, specs = {}, {}
    for k, v in tree.items():
        params[k], specs[k] = split_tree(v)
    return params, specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d):
    return {"scale": (jnp.ones((d,), jnp.float32), ("embed",))}


def rmsnorm(p, x, eps=1e-6, zero_centered=True):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    nx = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    scale = p["scale"] + 1.0 if zero_centered else p["scale"]
    return (nx * scale).astype(x.dtype)


def init_layernorm(d):
    return {
        "scale": (jnp.ones((d,), jnp.float32), ("embed",)),
        "bias": (jnp.zeros((d,), jnp.float32), ("embed",)),
    }


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, base=10000.0, dims: Optional[int] = None):
    """x: [..., S, H, D]; positions: [..., S]. Rotates the first `dims`."""
    d = x.shape[-1] if dims is None else dims
    half = d // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if d < x.shape[-1]:
        rot = jnp.concatenate([rot, x[..., d:]], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    rope_base: float = 10000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0  # 0 = off (gemma2: 50.0)
    window: int = 0  # 0 = global; >0 = sliding-window local
    query_scale: float = 0.0  # 0 -> 1/sqrt(head_dim)
    causal: bool = True  # False: bidirectional (whisper encoder)


def init_attention(key, cfg: AttnConfig):
    ks = jax.random.split(key, 4)
    H, K, D, M = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": make_param(ks[0], (M, H, D), ("embed", "heads", "head_dim")),
        "wk": make_param(ks[1], (M, K, D), ("embed", "kv_heads", "head_dim")),
        "wv": make_param(ks[2], (M, K, D), ("embed", "kv_heads", "head_dim")),
        "wo": make_param(ks[3], (H, D, M), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = (jnp.zeros((H, D), jnp.float32), ("heads", "head_dim"))
        p["bk"] = (jnp.zeros((K, D), jnp.float32), ("kv_heads", "head_dim"))
        p["bv"] = (jnp.zeros((K, D), jnp.float32), ("kv_heads", "head_dim"))
    return p


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def _mask_bias(q_pos, k_pos, window, dtype, causal=True):
    """[..., Sq, Sk] additive mask: validity + causal + sliding window.
    k positions >= 2**29 denote invalid (padded / unwritten cache) slots."""
    ok = k_pos[..., None, :] < 2**29
    if causal:
        ok &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def attention_scores(q, k, v, q_pos, k_pos, cfg: AttnConfig):
    """Reference (materialized-scores) attention.  q: [B,Sq,H,D],
    k/v: [B,Sk,K,D]."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = cfg.query_scale or (1.0 / math.sqrt(D))
    qg = q.reshape(B, Sq, K, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.logit_softcap)
    logits = logits + _mask_bias(q_pos, k_pos, cfg.window, jnp.float32, cfg.causal)[
        :, None, None
    ]
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, D)


def attention_blockwise(q, k, v, q_pos, k_pos, cfg: AttnConfig, kv_block=1024):
    """Flash-style online-softmax over KV blocks: O(Sq*D + Sq*kv_block)
    live memory, scan steps rematerialized (per-block score matrices are
    never saved for backward).

    KV is expanded to H heads first so the score tensor carries a full
    "heads" dim — shardable over the model axis, with a sequence-sharding
    fallback when H doesn't divide it (qwen's 40H, whisper's 12H on a
    16-way axis); see sharding.attn_axes.
    """
    from repro.train.sharding import attn_axes, constrain

    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = cfg.query_scale or (1.0 / math.sqrt(D))
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    ax = attn_axes(H)
    q = constrain(q, ax)
    k = constrain(k, ax)
    v = constrain(v, ax)
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    kb = k.reshape(B, nb, kv_block, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, H, D).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, blk):
        m, l, acc = carry
        kcur, vcur, pcur = blk
        s = jnp.einsum("bqhd,bshd->bhqs", q, kcur).astype(jnp.float32) * scale
        s = _softcap(s, cfg.logit_softcap)
        s = s + _mask_bias(q_pos, pcur, cfg.window, jnp.float32, cfg.causal)[:, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vcur.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(p, x, cfg: AttnConfig, positions, cache=None, blockwise=None):
    """Full attention block (no norms).  x: [B,S,M].

    cache: None for train/prefill-without-cache, or dict with
    {"k": [B,Smax,K,D], "v": ..., "len": scalar} for decode; returns
    (out, new_cache_or_None).
    """
    B, S, M = x.shape
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mkd->bskd", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = rope(q, positions, cfg.rope_base)
    k = rope(k, positions, cfg.rope_base)

    if cache is not None:
        # Ring-buffer cache: size may be < max context (sliding-window
        # truncation for local layers).  Absolute position of each slot is
        # tracked in cache["pos"]; unwritten slots stay at 2**30 (invalid).
        # int8-quantized caches carry per-(pos, head) scales ("k_scale"):
        # halves HBM footprint and decode read traffic (qwen's 5.5 TB MHA
        # cache does not fit 256 chips in bf16 — EXPERIMENTS.md §Dry-run).
        from repro.train.sharding import constrain as _c

        kv_ax = ("batch", None, "kv_heads", "head_dim")
        k = _c(k, kv_ax)
        v = _c(v, kv_ax)
        quant = "k_scale" in cache

        def _q(t):
            scale = jnp.max(jnp.abs(t), axis=-1, keepdims=True) / 127.0 + 1e-8
            return (
                jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8),
                scale[..., 0].astype(jnp.float32),
            )

        def _dq(tq, scale, dtype):
            return (tq.astype(jnp.float32) * scale[..., None]).astype(dtype)

        size = cache["k"].shape[1]
        cur = cache["len"]
        if S == 1:
            # decode: scatter the new key, attend over the ring in place
            slot = cur % size
            new_cache_extra = {}
            if quant:
                kq, ks = _q(k[:, 0])
                vq, vs = _q(v[:, 0])
                kfull = cache["k"].at[:, slot].set(kq)
                vfull = cache["v"].at[:, slot].set(vq)
                kscale = cache["k_scale"].at[:, slot].set(ks)
                vscale = cache["v_scale"].at[:, slot].set(vs)
                k_at = _dq(kfull, kscale, x.dtype)
                v_at = _dq(vfull, vscale, x.dtype)
                new_cache_extra = {"k_scale": kscale, "v_scale": vscale}
            else:
                kfull = cache["k"].at[:, slot].set(k[:, 0].astype(cache["k"].dtype))
                vfull = cache["v"].at[:, slot].set(v[:, 0].astype(cache["v"].dtype))
                k_at, v_at = kfull.astype(x.dtype), vfull.astype(x.dtype)
            posfull = cache["pos"].at[slot].set(cur)
            k_pos = jnp.broadcast_to(posfull[None], (B, size))
            out = attention_scores(q, k_at, v_at, positions, k_pos, cfg)
        else:
            # prefill chunk: queries need *all* in-chunk keys (the ring may
            # be narrower than the chunk), so attend over cache ∪ chunk …
            new_cache_extra = {}
            k_pos_old = jnp.broadcast_to(cache["pos"][None], (B, size))
            if quant:
                k_old = _dq(cache["k"], cache["k_scale"], x.dtype)
                v_old = _dq(cache["v"], cache["v_scale"], x.dtype)
            else:
                k_old = cache["k"].astype(x.dtype)
                v_old = cache["v"].astype(x.dtype)
            k_attn = jnp.concatenate([k_old, k], axis=1)
            v_attn = jnp.concatenate([v_old, v], axis=1)
            k_pos = jnp.concatenate([k_pos_old, positions], axis=1)
            use_block = blockwise if blockwise is not None else S >= 2048
            fn = attention_blockwise if use_block else attention_scores
            out = fn(q, k_attn, v_attn, positions, k_pos, cfg)
            # … then persist only the tail into the ring
            if S >= size:
                k_eff, v_eff = k[:, -size:], v[:, -size:]
                offs = cur + (S - size) + jnp.arange(size, dtype=jnp.int32)
            else:
                k_eff, v_eff = k, v
                offs = cur + jnp.arange(S, dtype=jnp.int32)
            slots = offs % size
            if quant:
                kq, ks = _q(k_eff)
                vq, vs = _q(v_eff)
                kfull = cache["k"].at[:, slots].set(kq)
                vfull = cache["v"].at[:, slots].set(vq)
                new_cache_extra = {
                    "k_scale": cache["k_scale"].at[:, slots].set(ks),
                    "v_scale": cache["v_scale"].at[:, slots].set(vs),
                }
            else:
                kfull = cache["k"].at[:, slots].set(k_eff.astype(cache["k"].dtype))
                vfull = cache["v"].at[:, slots].set(v_eff.astype(cache["v"].dtype))
            posfull = cache["pos"].at[slots].set(offs)
        new_cache = {"k": kfull, "v": vfull, "pos": posfull, "len": cur + S,
                     **new_cache_extra}
    else:
        k_pos = positions
        use_block = blockwise if blockwise is not None else S >= 2048
        fn = attention_blockwise if use_block else attention_scores
        out = fn(q, k, v, positions, k_pos, cfg)
        new_cache = None
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): compressed KV cache attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0


def init_mla(key, cfg: MLAConfig):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    M = cfg.d_model
    R = cfg.kv_lora_rank
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": make_param(ks[0], (M, H, qd), ("embed", "heads", "head_dim")),
        "wdkv": make_param(ks[1], (M, R + cfg.qk_rope_dim), ("embed", "mla_rank")),
        "wuk": make_param(ks[2], (R, H, cfg.qk_nope_dim), ("mla_rank", "heads", "head_dim")),
        "wuv": make_param(ks[3], (R, H, cfg.v_head_dim), ("mla_rank", "heads", "head_dim")),
        "wo": make_param(ks[4], (H, cfg.v_head_dim, M), ("heads", "head_dim", "embed")),
    }


def mla_attention(p, x, cfg: MLAConfig, positions, cache=None):
    """Multi-head latent attention; the cache stores only the compressed
    c_kv (rank R) plus the shared rope key — MLA's memory win."""
    B, S, M = x.shape
    H, R = cfg.n_heads, cfg.kv_lora_rank
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_base)
    ckv = jnp.einsum("bsm,mr->bsr", x, p["wdkv"].astype(x.dtype))
    c, k_rope = ckv[..., :R], ckv[..., R:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_base)[:, :, 0]

    quant = cache is not None and "ckv_scale" in cache
    cscale = None
    if cache is not None:
        cur = cache["len"]
        if quant:
            # int8 latent cache: per-position scale; the absorbed decode
            # folds the scale into the logits/weights so the dequantized
            # cache is never materialized
            s_new = jnp.max(jnp.abs(c), axis=-1) / 127.0 + 1e-8
            cq = jnp.clip(jnp.round(c / s_new[..., None]), -127, 127).astype(jnp.int8)
            c = jax.lax.dynamic_update_slice(cache["ckv"], cq, (0, cur, 0))
            cscale = jax.lax.dynamic_update_slice(
                cache["ckv_scale"], s_new.astype(jnp.float32), (0, cur)
            )
        else:
            c = jax.lax.dynamic_update_slice(
                cache["ckv"], c.astype(cache["ckv"].dtype), (0, cur, 0)
            )
        k_rope = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, cur, 0)
        )
        Smax = c.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32)[None], (B, Smax))
        k_pos = jnp.where(k_pos < cur + S, k_pos, 2**30)
        new_cache = {"ckv": c, "krope": k_rope, "len": cur + S}
        if quant:
            new_cache["ckv_scale"] = cscale
    else:
        k_pos = positions
        new_cache = None
    if not quant:
        c = c.astype(x.dtype)
    elif S != 1:
        # prefill/train with a quantized cache: dequantize for the
        # blockwise/materialized paths (decode keeps the folded form)
        c = c.astype(x.dtype) * cscale[..., None].astype(x.dtype)
        quant = False
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)

    if cache is not None and S == 1:
        # ABSORBED decode (deepseek-v2 §2.1.3 trick): fold W_uk into the
        # query and W_uv into the output so per-position K/V are never
        # materialized — attention runs directly against the rank-R cache.
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["wuk"].astype(x.dtype))
        c_mat = c.astype(x.dtype)
        logits = jnp.einsum("bqhr,bsr->bhqs", q_abs, c_mat).astype(jnp.float32)
        if quant:
            logits = logits * cscale[:, None, None, :]
        logits = (
            logits
            + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope.astype(x.dtype)).astype(jnp.float32)
        ) * scale
        logits = logits + _mask_bias(positions, k_pos, 0, jnp.float32)[:, None]
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        if quant:  # fold the per-position scale into the weights
            w = w * cscale[:, None, None, :].astype(x.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", w, c_mat)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, p["wuv"].astype(x.dtype))
    elif S >= 2048:
        out = _mla_blockwise(p, q_nope, q_rope, c, k_rope, positions, k_pos, cfg, scale, x.dtype)
    else:
        k_nope = jnp.einsum("bsr,rhd->bshd", c, p["wuk"].astype(x.dtype))
        vv = jnp.einsum("bsr,rhd->bshd", c, p["wuv"].astype(x.dtype))
        logits = (
            jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
            + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope.astype(x.dtype))
        ).astype(jnp.float32) * scale
        logits = logits + _mask_bias(positions, k_pos, 0, jnp.float32)[:, None]
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", w, vv)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"].astype(x.dtype))
    return y, new_cache


def _mla_blockwise(p, q_nope, q_rope, c, k_rope, q_pos, k_pos, cfg, scale, dtype,
                   kv_block=1024):
    """Memory-efficient MLA prefill/train: scan over compressed-cache
    blocks; per-position K/V are expanded ONE BLOCK AT A TIME from the
    rank-R latents and immediately consumed (checkpointed)."""
    from repro.train.sharding import attn_axes, constrain

    B, Sq, H, Dn = q_nope.shape
    Dv = cfg.v_head_dim
    Sk = c.shape[1]
    ax = attn_axes(H)
    q_nope = constrain(q_nope, ax)
    q_rope = constrain(q_rope, ax)
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    cb = c.reshape(B, nb, kv_block, -1).transpose(1, 0, 2, 3)
    rb = k_rope.reshape(B, nb, kv_block, -1).transpose(1, 0, 2, 3)
    pb = k_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)
    wuk = p["wuk"].astype(dtype)
    wuv = p["wuv"].astype(dtype)

    @jax.checkpoint
    def step(carry, blk):
        m, l, acc = carry
        ccur, rcur, pcur = blk
        kn = jnp.einsum("bsr,rhd->bshd", ccur, wuk)
        vv = jnp.einsum("bsr,rhd->bshd", ccur, wuv)
        s = (
            jnp.einsum("bqhd,bshd->bhqs", q_nope, kn)
            + jnp.einsum("bqhd,bsd->bhqs", q_rope, rcur.astype(dtype))
        ).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, pcur, 0, jnp.float32)[:, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pexp.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", pexp, vv.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (cb, rb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, gated=True, act="silu"):
    ks = jax.random.split(key, 3)
    p = {
        "wi": make_param(ks[0], (d_model, d_ff), ("embed", "mlp")),
        "wo": make_param(ks[1], (d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        p["wg"] = make_param(ks[2], (d_model, d_ff), ("embed", "mlp"))
    return p


_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}


def mlp(p, x, act="silu"):
    h = jnp.einsum("bsm,mf->bsf", x, p["wi"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("bsm,mf->bsf", x, p["wg"].astype(x.dtype))
        h = _ACT[act](g) * h
    else:
        h = _ACT[act](h)
    return jnp.einsum("bsf,fm->bsm", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE with SCV-inspired sorted dispatch (DESIGN.md §2, §4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden
    n_shared: int = 0  # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25
    act: str = "silu"


def init_moe(key, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    E, M, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": make_param(ks[0], (M, E), ("embed", "expert")),
        "wi": make_param(ks[1], (E, M, F), ("expert", "embed", "mlp")),
        "wg": make_param(ks[2], (E, M, F), ("expert", "embed", "mlp")),
        "wo": make_param(ks[3], (E, F, M), ("expert", "mlp", "embed")),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[4], M, F * cfg.n_shared, gated=True)
    return p


def moe_sorted(p, x, cfg: MoEConfig):
    """Token-grouped (sorted) dispatch — the SCV trick applied to MoE.

    The token->expert assignment matrix is ultra-sparse (top-k of E).  As
    in SCV, we sort the entries so each expert ("column vector") consumes a
    contiguous block, which turns the expert FFN into dense blocked
    matmuls and makes Z/PS-style reuse explicit.  Sorting is per batch row,
    so it shards cleanly over the data axes.

    Returns (y, aux) with aux = load-balancing loss (Switch-style).
    """
    B, S, M = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = S * K
    cap = int(cfg.capacity_factor * N / E) + 1

    logits = jnp.einsum("bsm,me->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # [B,S,K]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)  # renorm

    flat_e = eidx.reshape(B, N)  # expert of each (token, k) slot
    flat_t = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K)).reshape(N)
    order = jnp.argsort(flat_e, axis=1)  # SCV sort: group by expert
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    # rank of each slot within its expert group
    start = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(E)))(e_sorted)
    rank = jnp.arange(N)[None] - jnp.take_along_axis(start, e_sorted, axis=1)
    keep = rank < cap
    dst = jnp.where(keep, e_sorted * cap + rank, E * cap)  # overflow slot

    # gather token vectors into [B, E*cap+1, M] expert buffers.
    # Fused dispatch (§Perf iteration olmoe-1): compose the two gathers
    # (token-of-slot ∘ sort-order) into ONE index array so a single gather
    # feeds the scatter — the intermediate [B,N,M] copies of the v0
    # dispatch never materialize.
    from repro.train.sharding import constrain

    src_tok = jnp.take_along_axis(
        jnp.broadcast_to(flat_t[None], (B, N)), order, axis=1
    )  # [B,N] token index feeding each sorted slot
    tok_sorted = jnp.take_along_axis(x, src_tok[..., None], axis=1)
    tok_sorted = constrain(tok_sorted, ("batch", None, "embed"))
    buf = jnp.zeros((B, E * cap + 1, M), x.dtype)
    buf = jax.vmap(lambda b, d, t: b.at[d].set(t))(buf, dst, tok_sorted)
    # expert-parallel: the dispatch buffer re-shards from (embed-TP) to
    # (expert-EP) — GSPMD emits the all-to-all here (DESIGN.md §5)
    ebuf = constrain(
        buf[:, : E * cap].reshape(B, E, cap, M), ("batch", "expert", None, None)
    )

    h = jnp.einsum("becm,emf->becf", ebuf, p["wi"].astype(x.dtype))
    g = jnp.einsum("becm,emf->becf", ebuf, p["wg"].astype(x.dtype))
    h = constrain(_ACT[cfg.act](g) * h, ("batch", "expert", None, None))
    out = jnp.einsum("becf,efm->becm", h, p["wo"].astype(x.dtype))
    out = constrain(out, ("batch", "expert", None, None))
    out = constrain(out.reshape(B, E * cap, M), ("batch", "expert", None))
    out = jnp.concatenate([out, jnp.zeros((B, 1, M), x.dtype)], axis=1)

    # un-sort with ONE gather: slot of (token,k) = dst[inv] — the composed
    # index reads expert outputs directly (no [B,N,M] val_sorted copy)
    inv = jnp.argsort(order, axis=1)
    slot_of_tok = jnp.take_along_axis(jnp.where(keep, dst, E * cap), inv, axis=1)
    val = jnp.take_along_axis(out, slot_of_tok[..., None], axis=1)  # [B,N,M]
    val = constrain(val, ("batch", None, "embed"))
    val = val.reshape(B, S, K, M)
    y = jnp.einsum("bskm,bsk->bsm", val, gate.astype(x.dtype))

    if cfg.n_shared:
        y = y + mlp(p["shared"], x, cfg.act)

    # Switch aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(E).at[eidx.reshape(-1)].add(1.0) / (B * N)
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_dense(p, x, cfg: MoEConfig):
    """Dense one-hot fallback (every expert sees every token, masked).
    FLOP-heavy but collective-simple; used for A/B in §Perf."""
    B, S, M = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsm,me->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    mask = jnp.zeros((B, S, E), jnp.float32)
    mask = jax.vmap(jax.vmap(lambda m, i, g: m.at[i].add(g)))(mask, eidx, gate)
    h = jnp.einsum("bsm,emf->bsef", x, p["wi"].astype(x.dtype))
    g = jnp.einsum("bsm,emf->bsef", x, p["wg"].astype(x.dtype))
    h = _ACT[cfg.act](g) * h
    out = jnp.einsum("bsef,efm->bsem", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("bsem,bse->bsm", out, mask.astype(x.dtype))
    if cfg.n_shared:
        y = y + mlp(p["shared"], x, cfg.act)
    me = probs.mean(axis=(0, 1))
    ce = mask.mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# embeddings / unembed
# ---------------------------------------------------------------------------


def init_embed(key, vocab, d_model, pad_to=256):
    """Embedding table padded to a shardable row count (vocab axis is
    tensor-parallel; e.g. 50280 -> 50432 = 16 x 3152).  The true vocab is
    enforced by masking in unembed_logits."""
    vpad = -(-vocab // pad_to) * pad_to
    return {"table": make_param(key, (vpad, d_model), ("vocab", "embed"))}


def embed(p, tokens, scale=False):
    t = p["table"]
    x = t[tokens]
    if scale:
        x = x * math.sqrt(t.shape[1])
    return x


def unembed_logits(p, x, softcap=0.0, true_vocab=None):
    from repro.train.sharding import constrain

    logits = jnp.einsum("bsm,vm->bsv", x, p["table"].astype(x.dtype))
    logits = constrain(logits, ("batch", None, "vocab"))
    logits = _softcap(logits.astype(jnp.float32), softcap)
    vpad = p["table"].shape[0]
    if true_vocab is not None and true_vocab < vpad:
        mask = jnp.arange(vpad) >= true_vocab
        logits = jnp.where(mask[None, None, :], -1e30, logits)
    return logits


def chunked_softmax_xent(p_embed, x, labels, softcap=0.0, chunk=512, mask=None, true_vocab=None):
    """Cross-entropy without materializing [B,S,V] at once: scan over
    sequence chunks (production trick for 256k vocabularies)."""
    B, S, M = x.shape
    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else jnp.pad(
            jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad))
        )
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    xs = x.reshape(B, nchunk, chunk, M).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, inp):
        xc, lc, mc = inp
        logits = unembed_logits(p_embed, xc, softcap, true_vocab=true_vocab)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (0.0, 0.0), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
