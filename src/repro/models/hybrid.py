"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block
(arXiv:2411.15242).

Every ``share_every`` mamba blocks, one transformer block runs whose
weights are shared across all its invocations; its input is the
concatenation of the current hidden state with the original embedding
(Zamba's residual trick), projected back to d_model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.ssm import Mamba2Config, init_mamba2, init_mamba2_state, mamba2_forward
from repro.models.transformer import _stacked_init


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_mamba: int  # mamba2 blocks (zamba2-2.7b: 54)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_state: int = 64
    headdim: int = 64
    share_every: int = 6  # shared attn block cadence
    window: int = 4096  # attention window for 500k decode feasibility
    dtype: object = jnp.bfloat16

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    @property
    def mamba_cfg(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model, d_state=self.d_state, headdim=self.headdim
        )

    @property
    def n_shared_calls(self) -> int:
        return self.n_mamba // self.share_every

    def attn_cfg(self):
        return L.AttnConfig(
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            d_model=self.d_model,
            window=self.window,
        )


def _init_mamba_block(key, cfg: HybridConfig):
    return {
        "ln": L.init_rmsnorm(cfg.d_model),
        "mamba": init_mamba2(key, cfg.mamba_cfg),
    }


def init_hybrid(key, cfg: HybridConfig):
    ks = jax.random.split(key, 6)
    params, specs = L.split_tree(
        {
            "embed": L.init_embed(ks[0], cfg.vocab, cfg.d_model),
            "ln_final": L.init_rmsnorm(cfg.d_model),
            "shared": {
                "proj_in": L.make_param(
                    ks[1], (2 * cfg.d_model, cfg.d_model), ("embed", "embed2")
                ),
                "ln": L.init_rmsnorm(2 * cfg.d_model),
                "attn": L.init_attention(ks[2], cfg.attn_cfg()),
                "ln_mlp": L.init_rmsnorm(cfg.d_model),
                "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.d_ff),
            },
        }
    )
    bp, bs = _stacked_init(
        lambda k: _init_mamba_block(k, cfg), ks[4], cfg.n_mamba
    )
    # group mamba blocks by share_every so the shared-attn cadence scans
    grp = cfg.share_every
    bp = jax.tree.map(lambda a: a.reshape((cfg.n_shared_calls, grp) + a.shape[1:]), bp)
    params["mamba"] = bp
    # bs already has a leading "layers"; the reshape adds a second stack dim
    specs["mamba"] = jax.tree.map(
        lambda ax: ("layers",) + ax, bs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return params, specs


def _shared_block(sp, x, x0, cfg: HybridConfig, positions, cache=None):
    h = jnp.concatenate([x, x0], axis=-1)
    h = L.rmsnorm(sp["ln"], h)
    h = jnp.einsum("bsm,md->bsd", h, sp["proj_in"].astype(x.dtype))
    a, new_cache = L.attention(sp["attn"], h, cfg.attn_cfg(), positions, cache)
    x = x + a
    h = L.rmsnorm(sp["ln_mlp"], x)
    x = x + L.mlp(sp["mlp"], h)
    return x, new_cache


def hidden_states(params, cfg: HybridConfig, tokens, positions=None, state=None):
    """state: None (train/prefill) or dict from init_state (decode)."""
    from repro.train.sharding import constrain

    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = constrain(x, ("batch", None, "embed"))
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x0 = x
    mcfg = cfg.mamba_cfg
    sp = params["shared"]

    def group(x, gp, gstate):
        # shared attention first, then `share_every` mamba blocks
        new_attn = None
        if gstate is not None:
            x, new_attn = _shared_block(sp, x, x0, cfg, positions, gstate["attn"])
        else:
            x, _ = _shared_block(sp, x, x0, cfg, positions, None)
        new_ssm = []
        for i in range(cfg.share_every):
            lp = jax.tree.map(lambda a: a[i], gp)
            h = L.rmsnorm(lp["ln"], x)
            st = (
                jax.tree.map(lambda a: a[i], gstate["ssm"])
                if gstate is not None
                else None
            )
            y, ns = mamba2_forward(lp["mamba"], h, mcfg, state=st)
            x = constrain(x + y, ("batch", None, "embed"))
            if ns is not None:
                new_ssm.append(ns)
        if gstate is None:
            return x, None
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_ssm)
        return x, {"attn": new_attn, "ssm": stacked}

    if state is None:
        gfn = jax.checkpoint(lambda x, gp: group(x, gp, None)[0])

        def body(x, gp):
            return gfn(x, gp), None

        x, _ = jax.lax.scan(body, x, params["mamba"])
        new_state = None
    else:

        def body(x, xs):
            gp, gs = xs
            return group(x, gp, gs)

        x, new_state = jax.lax.scan(body, x, (params["mamba"], state))
    x = L.rmsnorm(params["ln_final"], x)
    return x, new_state


def init_state(cfg: HybridConfig, batch, max_attn_len):
    """Decode state: per group, one shared-attn ring cache + per-mamba ssm
    state.  Attention cache is windowed (cfg.window) — with a 500k context
    the whole state is O(window + d_state), not O(S)."""
    size = min(cfg.window, max_attn_len)
    H, D = cfg.n_kv_heads, cfg.head_dim
    mcfg = cfg.mamba_cfg
    one_ssm = init_mamba2_state(mcfg, batch, cfg.dtype)

    def rep(a, n):
        return jnp.broadcast_to(a, (n,) + a.shape)

    g = cfg.n_shared_calls
    return {
        "attn": {
            "k": jnp.zeros((g, batch, size, H, D), cfg.dtype),
            "v": jnp.zeros((g, batch, size, H, D), cfg.dtype),
            "pos": jnp.full((g, size), 2**30, jnp.int32),
            "len": jnp.zeros((g,), jnp.int32),
        },
        "ssm": jax.tree.map(
            lambda a: rep(rep(a, cfg.share_every), g), one_ssm
        ),
    }


def train_loss(params, cfg: HybridConfig, batch):
    x, _ = hidden_states(params, cfg, batch["tokens"][:, :-1])
    return L.chunked_softmax_xent(params["embed"], x, batch["tokens"][:, 1:], true_vocab=cfg.vocab)


def decode_step(params, cfg: HybridConfig, token, state, pos):
    x, state = hidden_states(params, cfg, token, positions=pos, state=state)
    logits = L.unembed_logits(params["embed"], x, true_vocab=cfg.vocab)
    return logits, state


def state_specs(cfg: HybridConfig):
    return {
        "attn": {
            "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
            "pos": ("layers", "seq"),
            "len": ("layers",),
        },
        "ssm": {
            "ssm": ("layers", "sublayers", "batch", "mamba_heads", "head_dim", "state"),
            "conv": ("layers", "sublayers", "batch", "conv", "inner"),
        },
    }
