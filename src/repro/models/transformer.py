"""Generic decoder-only LM covering the assigned-architecture matrix.

One config class + one code path handles: dense (gemma2/gemma3/starcoder2/
qwen), MoE (olmoe), MLA+MoE (deepseek-v2-lite), and the VLM backbone
(internvl2 — the ViT frontend is a stub supplying precomputed patch
embeddings, per the task spec).  Layers are scanned in *pattern groups*
(e.g. gemma2's (local, global) pair, gemma3's 5xlocal+global) so the HLO
stays compact and per-position params stack along a leading "layers" axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    norm: str = "rmsnorm"
    act: str = "silu"
    gated: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope_base: float = 10_000.0
    rope_base_local: float = 0.0  # 0 -> same as rope_base (gemma3: 10k local/1M global)
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    embed_scale: bool = False
    post_norms: bool = False  # gemma2: extra norms after attn/mlp outputs
    layer_pattern: tuple = ("g",)  # cycled; "l" = local window, "g" = global
    window: int = 0
    query_scale: float = 0.0
    moe: Optional[L.MoEConfig] = None
    moe_dispatch: str = "sorted"  # "sorted" (SCV-style) | "dense"
    first_dense: int = 0  # deepseek: leading dense-FFN layers
    first_dense_ff: int = 0
    mla: Optional[L.MLAConfig] = None
    n_frontend_tokens: int = 0  # vlm: image tokens prepended (stub embeds)
    kv_quant: bool = False  # int8 KV cache (qwen's MHA cache, DESIGN.md §5)
    dtype: Any = jnp.bfloat16

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_rep(self) -> int:
        body = self.n_layers - self.first_dense
        assert body % self.pattern_len == 0, (self.n_layers, self.layer_pattern)
        return body // self.pattern_len

    def attn_cfg(self, kind: str) -> L.AttnConfig:
        local = kind == "l"
        base = (
            self.rope_base_local
            if (local and self.rope_base_local)
            else self.rope_base
        )
        return L.AttnConfig(
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            d_model=self.d_model,
            rope_base=base,
            qkv_bias=self.qkv_bias,
            logit_softcap=self.attn_softcap,
            window=self.window if local else 0,
            query_scale=self.query_scale,
        )

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline bookkeeping)."""
        import numpy as np

        def count(init_out):
            params, _ = L.split_tree(init_out)
            return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))

        # cheap: init with a fixed key on abstract eval
        shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), self)[0])
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        import numpy as np

        shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), self)[0])
        total = 0
        for path, x in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            n = int(np.prod(x.shape))
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            if self.moe and ("/wi" in keys or "/wg" in keys or "/wo" in keys) and "moe" in keys:
                n = n * self.moe.top_k // self.moe.n_experts
            total += n
        return total


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_norm(cfg, d=None):
    d = d or cfg.d_model
    return L.init_rmsnorm(d) if cfg.norm == "rmsnorm" else L.init_layernorm(d)


def _apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return L.rmsnorm(p, x)
    return L.layernorm(p, x)


def _init_block(key, cfg: LMConfig, kind: str, dense_ff: int = 0):
    """One layer's params: norms + attention (or MLA) + FFN (or MoE)."""
    ks = jax.random.split(key, 4)
    p = {"ln_attn": _init_norm(cfg), "ln_mlp": _init_norm(cfg)}
    if cfg.post_norms:
        p["ln_attn_post"] = _init_norm(cfg)
        p["ln_mlp_post"] = _init_norm(cfg)
    if cfg.mla is not None:
        p["attn"] = L.init_mla(ks[0], cfg.mla)
    else:
        p["attn"] = L.init_attention(ks[0], cfg.attn_cfg(kind))
    if dense_ff:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, dense_ff, cfg.gated)
    elif cfg.moe is not None:
        p["moe"] = L.init_moe(ks[1], cfg.moe)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated)
    return p


def _stacked_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    _, specs = L.split_tree(init_fn(keys[0]))
    params = jax.vmap(lambda k: L.split_tree(init_fn(k))[0])(keys)
    specs = jax.tree.map(
        lambda ax: ("layers",) + ax,
        specs,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return params, specs


def init_lm(key, cfg: LMConfig):
    """Returns (params, specs)."""
    ks = jax.random.split(key, 4 + cfg.first_dense)
    tree = {
        "embed": L.init_embed(ks[0], cfg.vocab, cfg.d_model),
        "ln_final": _init_norm(cfg),
    }
    params, specs = L.split_tree(tree)
    blocks_p, blocks_s = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        bp, bs = _stacked_init(
            lambda k, kind=kind: _init_block(k, cfg, kind), ks[1 + i % 3], cfg.n_rep
        )
        blocks_p[f"pos{i}"] = bp
        blocks_s[f"pos{i}"] = bs
    params["blocks"] = blocks_p
    specs["blocks"] = blocks_s
    for j in range(cfg.first_dense):
        hp, hs = L.split_tree(
            _init_block(ks[4 + j], cfg, "g", dense_ff=cfg.first_dense_ff or cfg.d_ff)
        )
        params[f"head{j}"] = hp
        specs[f"head{j}"] = hs
    return params, specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(p, x, cfg: LMConfig, kind, positions, cache=None, dense_ff=False):
    acfg = cfg.attn_cfg(kind)
    h = _apply_norm(cfg, p["ln_attn"], x)
    if cfg.mla is not None:
        a, new_cache = L.mla_attention(p["attn"], h, cfg.mla, positions, cache)
    else:
        a, new_cache = L.attention(p["attn"], h, acfg, positions, cache)
    if cfg.post_norms:
        a = _apply_norm(cfg, p["ln_attn_post"], a)
    x = x + a
    h = _apply_norm(cfg, p["ln_mlp"], x)
    aux = 0.0
    if "moe" in p and not dense_ff:
        fn = L.moe_sorted if cfg.moe_dispatch == "sorted" else L.moe_dense
        m, aux = fn(p["moe"], h, cfg.moe)
    else:
        m = L.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norms:
        m = _apply_norm(cfg, p["ln_mlp_post"], m)
    x = x + m
    return x, new_cache, aux


def _activation_sharding(x):
    """Residual-stream constraint: batch over (pod,data), features over
    model — applied when a mesh is active (no-op otherwise)."""
    from repro.train.sharding import constrain

    return constrain(x, ("batch", None, "embed"))


def hidden_states(
    params,
    cfg: LMConfig,
    tokens,
    positions=None,
    extra_embed=None,
    caches=None,
    decode=False,
):
    """Run embedding + all blocks.  Returns (hidden, new_caches, aux_sum).

    extra_embed: [B, n_front, d_model] stub frontend embeddings (vlm/audio),
    prepended before the token embeddings.
    caches: pytree from init_cache() for decode; None otherwise.
    """
    x = L.embed(params["embed"], tokens, scale=cfg.embed_scale).astype(cfg.dtype)
    if extra_embed is not None:
        x = jnp.concatenate([extra_embed.astype(cfg.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _activation_sharding(x)

    aux_total = jnp.zeros((), jnp.float32)
    # leading special (dense) layers — deepseek's first_dense
    for j in range(cfg.first_dense):
        c = caches[f"head{j}"] if caches is not None else None
        apply = _block_apply if caches is not None else jax.checkpoint(
            _block_apply, static_argnums=(2, 3, 6)
        )
        x, nc, aux = apply(
            params[f"head{j}"], x, cfg, "g", positions, c, True
        )
        aux_total += aux
        if caches is not None:
            caches = dict(caches)
            caches[f"head{j}"] = nc

    blocks = params["blocks"]

    def group_fn(x, grp_params, grp_caches):
        new_caches = {}
        aux = 0.0
        for i, kind in enumerate(cfg.layer_pattern):
            c = grp_caches[f"pos{i}"] if grp_caches is not None else None
            x, nc, a = _block_apply(grp_params[f"pos{i}"], x, cfg, kind, positions, c)
            x = _activation_sharding(x)
            aux += a
            if nc is not None:
                new_caches[f"pos{i}"] = nc
        return x, (new_caches if new_caches else None), aux

    if caches is None:
        group = jax.checkpoint(lambda x, gp: group_fn(x, gp, None)[::2])

        def body(carry, gp):
            x, aux = carry
            x, a = group(x, gp)
            return (x, aux + a), None

        (x, aux_total2), _ = jax.lax.scan(body, (x, aux_total), blocks)
        aux_total = aux_total2
        new_caches = None
    else:
        # Cache lives in the CARRY (not xs/ys): per-layer slices are read
        # with dynamic_slice and written back with dynamic_update_slice, so
        # XLA updates the (multi-GB) stacked cache IN PLACE instead of
        # double-buffering a ys copy — §Perf decode iteration 1.
        stacked = caches["blocks"]

        def take(tree, i):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                tree,
            )

        def put(tree, sub, i):
            return jax.tree.map(
                lambda a, s: jax.lax.dynamic_update_index_in_dim(a, s, i, 0),
                tree,
                sub,
            )

        def body(carry, xs):
            x, aux, cstack = carry
            gp, i = xs
            x, nc, a = group_fn(x, gp, take(cstack, i))
            return (x, aux + a, put(cstack, nc, i)), None

        (x, aux_total, stacked_caches), _ = jax.lax.scan(
            body,
            (x, aux_total, stacked),
            (blocks, jnp.arange(cfg.n_rep, dtype=jnp.int32)),
        )
        new_caches = dict(caches)
        new_caches["blocks"] = stacked_caches

    x = _apply_norm(cfg, params["ln_final"], x)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# task heads
# ---------------------------------------------------------------------------


def train_loss(params, cfg: LMConfig, batch, aux_weight=0.01):
    """Next-token CE (+ MoE aux).  batch: {"tokens": [B,S] int32,
    "extra_embed": optional [B,n,front]}."""
    tokens = batch["tokens"]
    extra = batch.get("extra_embed")
    x, _, aux = hidden_states(params, cfg, tokens[:, :-1], extra_embed=extra)
    if extra is not None:
        x = x[:, extra.shape[1] :]  # loss only on text positions
    loss = L.chunked_softmax_xent(
        params["embed"], x, tokens[:, 1:], softcap=cfg.final_softcap,
        true_vocab=cfg.vocab,
    )
    return loss + aux_weight * aux


def init_cache(cfg: LMConfig, batch, max_len, dtype=None):
    """KV caches.  Local (windowed) layers use a ring buffer of size
    min(window, max_len) — the sliding-window truncation that halves
    gemma2/gemma3 decode cache (DESIGN.md §5)."""
    dtype = dtype or cfg.dtype
    K, D = cfg.n_kv_heads, cfg.head_dim

    def one(kind):
        if cfg.mla is not None:
            R = cfg.mla.kv_lora_rank
            c = {
                "ckv": jnp.zeros(
                    (batch, max_len, R), jnp.int8 if cfg.kv_quant else dtype
                ),
                "krope": jnp.zeros((batch, max_len, cfg.mla.qk_rope_dim), dtype),
                "len": jnp.zeros((), jnp.int32),
            }
            if cfg.kv_quant:
                c["ckv_scale"] = jnp.zeros((batch, max_len), jnp.float32)
            return c
        size = min(cfg.window, max_len) if (kind == "l" and cfg.window) else max_len
        kv_dtype = jnp.int8 if cfg.kv_quant else dtype
        c = {
            "k": jnp.zeros((batch, size, K, D), kv_dtype),
            "v": jnp.zeros((batch, size, K, D), kv_dtype),
            "pos": jnp.full((size,), 2**30, jnp.int32),
            "len": jnp.zeros((), jnp.int32),
        }
        if cfg.kv_quant:
            c["k_scale"] = jnp.zeros((batch, size, K), jnp.float32)
            c["v_scale"] = jnp.zeros((batch, size, K), jnp.float32)
        return c

    caches = {
        "blocks": {
            f"pos{i}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_rep,) + a.shape)
                if a.ndim
                else jnp.broadcast_to(a, (cfg.n_rep,)),
                one(kind),
            )
            for i, kind in enumerate(cfg.layer_pattern)
        }
    }
    for j in range(cfg.first_dense):
        caches[f"head{j}"] = one("g")
    return caches


def prefill(params, cfg: LMConfig, tokens, extra_embed=None, max_len=None):
    """Prefill: runs hidden_states writing into fresh caches sized
    max_len (>= prompt length + decode budget)."""
    B = tokens.shape[0]
    S = tokens.shape[1] + (extra_embed.shape[1] if extra_embed is not None else 0)
    max_len = max_len or S
    caches = init_cache(cfg, B, max_len)
    x, caches, _ = hidden_states(
        params, cfg, tokens, extra_embed=extra_embed, caches=caches
    )
    logits = L.unembed_logits(params["embed"], x[:, -1:], cfg.final_softcap, true_vocab=cfg.vocab)
    return logits, caches


def decode_step(params, cfg: LMConfig, token, caches, pos):
    """One decode step.  token: [B,1] int32; pos: [B,1] absolute position."""
    x, caches, _ = hidden_states(
        params, cfg, token, positions=pos, caches=caches, decode=True
    )
    logits = L.unembed_logits(params["embed"], x, cfg.final_softcap, true_vocab=cfg.vocab)
    return logits, caches


def cache_specs(cfg: LMConfig):
    """Logical-axes tree mirroring init_cache() for sharding resolution."""

    def one():
        if cfg.mla is not None:
            c = {
                "ckv": ("layers", "batch", "seq", "mla_rank"),
                "krope": ("layers", "batch", "seq", "head_dim"),
                "len": ("layers",),
            }
            if cfg.kv_quant:
                c["ckv_scale"] = ("layers", "batch", "seq")
            return c
        c = {
            "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
            "pos": ("layers", "seq"),
            "len": ("layers",),
        }
        if cfg.kv_quant:
            c["k_scale"] = ("layers", "batch", "seq", "kv_heads")
            c["v_scale"] = ("layers", "batch", "seq", "kv_heads")
        return c

    specs = {"blocks": {f"pos{i}": one() for i in range(cfg.pattern_len)}}
    for j in range(cfg.first_dense):
        specs[f"head{j}"] = jax.tree.map(
            lambda ax: ax[1:], one(), is_leaf=lambda x: isinstance(x, tuple)
        )
    return specs
