"""Mamba2 — state-space duality (SSD) blocks (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence via lax.scan); decode is the O(1) recurrent
update.  The implementation follows the minimal listing in the Mamba2
paper, adapted to this framework's (params, logical-axes) convention.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import make_param, rmsnorm, init_rmsnorm


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba2(key, cfg: Mamba2Config):
    ks = jax.random.split(key, 5)
    Din, H, G, N = cfg.d_inner, cfg.n_heads, cfg.n_groups, cfg.d_state
    d_in_proj = 2 * Din + 2 * G * N + H
    return {
        "w_in": make_param(ks[0], (cfg.d_model, d_in_proj), ("embed", "inner")),
        "conv_w": make_param(ks[1], (cfg.conv_width, cfg.conv_dim), ("conv", "inner")),
        "conv_b": (jnp.zeros((cfg.conv_dim,), jnp.float32), ("inner",)),
        "a_log": (jnp.zeros((H,), jnp.float32), ("mamba_heads",)),
        "dt_bias": (jnp.zeros((H,), jnp.float32), ("mamba_heads",)),
        "d_skip": (jnp.ones((H,), jnp.float32), ("mamba_heads",)),
        "norm": init_rmsnorm(Din),
        "w_out": make_param(ks[4], (Din, cfg.d_model), ("inner", "embed")),
    }


def _segsum(x):
    """log-decay matrix: L[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk):
    """SSD over chunks.  Shapes:
      x: [B,S,H,P]; dt: [B,S,H]; a_log: [H]; b,c: [B,S,G,N].
    Returns y: [B,S,H,P] and final state [B,H,P,N].
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    Q = chunk
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rep = H // G  # heads per B/C group
    # fold dt into x; decay per step
    xd = x * dt[..., None]
    adt = -jnp.exp(a_log)[None, None, :] * dt  # [B,S',H] (negative)

    def to_chunks(t):
        return t.reshape((B, nc, Q) + t.shape[2:]).transpose(1, 0, *range(2, t.ndim + 1))

    from repro.train.sharding import constrain

    xc = constrain(to_chunks(xd), (None, "batch", None, "mamba_heads", None))
    ac = constrain(to_chunks(adt), (None, "batch", None, "mamba_heads"))
    bc = to_chunks(b)  # [nc,B,Q,G,N] — G==1 stays replicated over model
    cc = to_chunks(c)

    acum = jnp.cumsum(ac, axis=2)  # [nc,B,Q,H]

    # intra-chunk (diagonal) term
    Lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # [nc,B,H,Q,Q]
    if G == 1:
        bh = jnp.broadcast_to(bc, bc.shape[:3] + (H, N))
        ch = jnp.broadcast_to(cc, cc.shape[:3] + (H, N))
    else:
        bh = jnp.repeat(bc, rep, axis=3)
        ch = jnp.repeat(cc, rep, axis=3)
    scores = jnp.einsum("nbqhs,nbkhs->nbhqk", ch, bh)  # q,k within chunk
    y_diag = jnp.einsum("nbhqk,nbhqk,nbkhp->nbqhp", scores, Lmat, xc)

    # end-of-chunk states
    decay_states = jnp.exp(acum[:, :, -1:, :] - acum)  # [nc,B,Q,H]
    states = jnp.einsum("nbqhs,nbqh,nbqhp->nbhps", bh, decay_states, xc)
    chunk_decay = jnp.exp(acum[:, :, -1, :])  # [nc,B,H]

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, h_in = jax.lax.scan(
        scan_fn, h0, (states.astype(jnp.float32), chunk_decay.astype(jnp.float32))
    )

    # contribution of entering state to each position
    state_decay = jnp.exp(acum)  # [nc,B,Q,H]
    y_off = jnp.einsum(
        "nbqhs,nbhps,nbqh->nbqhp", ch, h_in.astype(ch.dtype), state_decay
    )
    y = (y_diag + y_off).transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, P)
    return y[:, :S], h_final


def mamba2_forward(p, u, cfg: Mamba2Config, state=None):
    """u: [B,S,M].  state=None for train/prefill; for decode pass
    {"ssm": [B,H,P,N], "conv": [B,W-1,conv_dim]} and S must be 1.
    Returns (y, new_state_or_None)."""
    B, S, M = u.shape
    Din, H, G, N, P = cfg.d_inner, cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.headdim
    zxbcdt = jnp.einsum("bsm,md->bsd", u, p["w_in"].astype(u.dtype))
    z, xbc, dt_raw = jnp.split(zxbcdt, [Din, Din + cfg.conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]

    w = p["conv_w"].astype(u.dtype)  # [W, conv_dim]
    if state is None:
        pad = jnp.zeros((B, cfg.conv_width - 1, cfg.conv_dim), u.dtype)
        xbc_pad = jnp.concatenate([pad, xbc], axis=1)
        new_conv = None
    else:
        xbc_pad = jnp.concatenate([state["conv"].astype(u.dtype), xbc], axis=1)
        new_conv = xbc_pad[:, -(cfg.conv_width - 1) :]
    # causal depthwise conv as W shifted scaled adds (no W-x window copy)
    acc = xbc_pad[:, 0:S] * w[0]
    for j in range(1, cfg.conv_width):
        acc = acc + xbc_pad[:, j : j + S] * w[j]
    xbc = jax.nn.silu(acc + p["conv_b"].astype(u.dtype))

    x, b, c = jnp.split(xbc, [Din, Din + G * N], axis=-1)
    from repro.train.sharding import constrain as _constrain

    x = _constrain(x.reshape(B, S, H, P), ("batch", None, "mamba_heads", None))
    b = b.reshape(B, S, G, N)
    c = c.reshape(B, S, G, N)

    if state is None:
        y, h_final = ssd_chunked(
            x.astype(jnp.float32), dt, p["a_log"], b.astype(jnp.float32),
            c.astype(jnp.float32), cfg.chunk,
        )
        new_state = None
    else:
        # recurrent step (S == 1): h = h*exp(a*dt) + dt * B x^T ; y = C h
        h = state["ssm"]
        adt = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt[:, 0])  # [B,H]
        bh = jnp.broadcast_to(b[:, 0, :, :], (B, H, N)) if G == 1 else jnp.repeat(
            b[:, 0], H // G, axis=1
        )
        ch = jnp.broadcast_to(c[:, 0, :, :], (B, H, N)) if G == 1 else jnp.repeat(
            c[:, 0], H // G, axis=1
        )
        upd = jnp.einsum("bhp,bhn->bhpn", (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32), bh.astype(jnp.float32))
        h = h * adt[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ch.astype(jnp.float32), h)[:, None]
        h_final = h
        new_state = {"ssm": h_final, "conv": new_conv}
    y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, Din).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsd,dm->bsm", y, p["w_out"].astype(u.dtype))
    return out, new_state


def init_mamba2_state(cfg: Mamba2Config, batch, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
    }


# ---------------------------------------------------------------------------
# Pure-Mamba2 LM (mamba2-780m)
# ---------------------------------------------------------------------------
import dataclasses as _dc

from repro.models.layers import (
    chunked_softmax_xent,
    embed as _embed,
    init_embed as _init_embed,
    split_tree as _split_tree,
    unembed_logits as _unembed_logits,
)


@_dc.dataclass(frozen=True)
class Mamba2LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    headdim: int = 64
    dtype: object = jnp.bfloat16

    @property
    def block_cfg(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model, d_state=self.d_state, headdim=self.headdim
        )


def init_mamba2_lm(key, cfg: Mamba2LMConfig):
    from repro.models.transformer import _stacked_init

    ks = jax.random.split(key, 3)
    params, specs = _split_tree(
        {
            "embed": _init_embed(ks[0], cfg.vocab, cfg.d_model),
            "ln_final": init_rmsnorm(cfg.d_model),
        }
    )
    bp, bs = _stacked_init(
        lambda k: {"ln": init_rmsnorm(cfg.d_model), "mamba": init_mamba2(k, cfg.block_cfg)},
        ks[1],
        cfg.n_layers,
    )
    params["blocks"] = bp
    specs["blocks"] = bs
    return params, specs


def mamba2_lm_hidden(params, cfg: Mamba2LMConfig, tokens, state=None):
    from repro.train.sharding import constrain

    x = _embed(params["embed"], tokens).astype(cfg.dtype)
    x = constrain(x, ("batch", None, "embed"))
    mcfg = cfg.block_cfg

    def block(x, lp, st):
        h = rmsnorm(lp["ln"], x)
        y, ns = mamba2_forward(lp["mamba"], h, mcfg, state=st)
        return constrain(x + y, ("batch", None, "embed")), ns

    if state is None:
        gfn = jax.checkpoint(lambda x, lp: block(x, lp, None)[0])

        def body(x, lp):
            return gfn(x, lp), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        new_state = None
    else:

        def body(x, xs):
            lp, st = xs
            return block(x, lp, st)

        x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    return rmsnorm(params["ln_final"], x), new_state


def mamba2_lm_loss(params, cfg: Mamba2LMConfig, batch):
    x, _ = mamba2_lm_hidden(params, cfg, batch["tokens"][:, :-1])
    return chunked_softmax_xent(params["embed"], x, batch["tokens"][:, 1:], true_vocab=cfg.vocab)


def init_mamba2_lm_state(cfg: Mamba2LMConfig, batch):
    one = init_mamba2_state(cfg.block_cfg, batch, cfg.dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one
    )


def mamba2_lm_state_specs(cfg: Mamba2LMConfig):
    return {
        "ssm": ("layers", "batch", "mamba_heads", "head_dim", "state"),
        "conv": ("layers", "batch", "conv", "inner"),
    }


def mamba2_lm_decode(params, cfg: Mamba2LMConfig, token, state, pos=None):
    x, state = mamba2_lm_hidden(params, cfg, token, state=state)
    return _unembed_logits(params["embed"], x, true_vocab=cfg.vocab), state
