"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the task spec the conv/audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings [B, S_enc, d_model].  The transformer backbone
(bidirectional encoder, causal decoder with cross-attention) is real.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import _stacked_init


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int  # per side (whisper-small: 12 + 12)
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    max_positions: int = 32768 + 8
    act: str = "gelu"
    dtype: object = jnp.bfloat16

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def attn_cfg(self, causal):
        return L.AttnConfig(
            n_heads=self.n_heads,
            n_kv_heads=self.n_heads,
            head_dim=self.head_dim,
            d_model=self.d_model,
            causal=causal,
        )


def _init_cross(key, cfg: EncDecConfig):
    ks = jax.random.split(key, 4)
    H, D, M = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": L.make_param(ks[0], (M, H, D), ("embed", "heads", "head_dim")),
        "wk": L.make_param(ks[1], (M, H, D), ("embed", "heads", "head_dim")),
        "wv": L.make_param(ks[2], (M, H, D), ("embed", "heads", "head_dim")),
        "wo": L.make_param(ks[3], (H, D, M), ("heads", "head_dim", "embed")),
    }


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.attn_cfg(False)),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=False),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.attn_cfg(True)),
        "ln_x": L.init_layernorm(cfg.d_model),
        "xattn": _init_cross(ks[1], cfg),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


def init_encdec(key, cfg: EncDecConfig):
    ks = jax.random.split(key, 5)
    params, specs = L.split_tree(
        {
            "embed": L.init_embed(ks[0], cfg.vocab, cfg.d_model),
            "pos_dec": L.make_param(
                ks[1], (cfg.max_positions, cfg.d_model), ("seq", "embed")
            ),
            "ln_enc": L.init_layernorm(cfg.d_model),
            "ln_dec": L.init_layernorm(cfg.d_model),
        }
    )
    for name, fn in [("enc", _init_enc_layer), ("dec", _init_dec_layer)]:
        p, s = _stacked_init(lambda k: fn(k, cfg), ks[3 if name == "enc" else 4], cfg.n_layers)
        params[name] = p
        specs[name] = s
    return params, specs


def _sinusoid(S, d, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def cross_attention(p, x, kv, cfg: EncDecConfig, precomputed=None):
    """x: [B,Sq,M] queries (decoder); kv: [B,Sk,M] encoder output (or None
    when precomputed k/v are given — the decode-time fast path)."""
    B, Sq, M = x.shape
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(x.dtype))
    if precomputed is None:
        k = jnp.einsum("bsm,mhd->bshd", kv, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsm,mhd->bshd", kv, p["wv"].astype(x.dtype))
    else:
        k, v = precomputed
    Sk = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    pos_k = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))
    acfg = cfg.attn_cfg(False)
    fn = L.attention_blockwise if Sk >= 2048 else L.attention_scores
    out = fn(q, k, v, pos_q, pos_k, acfg)
    y = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(x.dtype))
    return y, (k, v)


def encode(params, cfg: EncDecConfig, frames):
    """frames: [B, S_enc, d_model] stub frontend embeddings."""
    x = (frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]).astype(
        cfg.dtype
    )
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    acfg = cfg.attn_cfg(False)

    def body(x, lp):
        h = L.layernorm(lp["ln1"], x)
        a, _ = L.attention(lp["attn"], h, acfg, pos)
        x = x + a
        h = L.layernorm(lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
    return L.layernorm(params["ln_enc"], x)


def decode_train(params, cfg: EncDecConfig, tokens, enc_out):
    """Teacher-forced decoder pass.  tokens: [B, S_dec]."""
    B, S = tokens.shape
    x = (
        L.embed(params["embed"], tokens)
        + params["pos_dec"][:S][None]
    ).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    acfg = cfg.attn_cfg(True)

    def body(x, lp):
        h = L.layernorm(lp["ln1"], x)
        a, _ = L.attention(lp["attn"], h, acfg, pos)
        x = x + a
        h = L.layernorm(lp["ln_x"], x)
        a, _ = cross_attention(lp["xattn"], h, enc_out, cfg)
        x = x + a
        h = L.layernorm(lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    return L.layernorm(params["ln_dec"], x)


def train_loss(params, cfg: EncDecConfig, batch):
    enc_out = encode(params, cfg, batch["frames"])
    x = decode_train(params, cfg, batch["tokens"][:, :-1], enc_out)
    return L.chunked_softmax_xent(params["embed"], x, batch["tokens"][:, 1:], true_vocab=cfg.vocab)


def init_dec_cache(params, cfg: EncDecConfig, enc_out, max_len):
    """Self-attn ring caches + precomputed cross K/V per layer."""
    B = enc_out.shape[0]
    H, D = cfg.n_heads, cfg.head_dim

    def xkv(lp):
        k = jnp.einsum("bsm,mhd->bshd", enc_out, lp["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsm,mhd->bshd", enc_out, lp["wv"].astype(enc_out.dtype))
        return k, v

    xk, xv = jax.vmap(xkv)(params["dec"]["xattn"])
    return {
        "k": jnp.zeros((cfg.n_layers, B, max_len, H, D), cfg.dtype),
        "v": jnp.zeros((cfg.n_layers, B, max_len, H, D), cfg.dtype),
        "pos": jnp.full((cfg.n_layers, max_len), 2**30, jnp.int32),
        "len": jnp.zeros((cfg.n_layers,), jnp.int32),
        "xk": xk,
        "xv": xv,
    }


def decode_step(params, cfg: EncDecConfig, token, cache, pos):
    """One decoder token.  token: [B,1]; pos: [B,1]."""
    B = token.shape[0]
    x = (
        L.embed(params["embed"], token)
        + jnp.take(params["pos_dec"], pos[0], axis=0)[None]
    ).astype(cfg.dtype)
    acfg = cfg.attn_cfg(True)

    def body(x, xs):
        lp, k, v, slot_pos, ln, xk, xv = xs
        h = L.layernorm(lp["ln1"], x)
        a, nc = L.attention(
            lp["attn"], h, acfg, pos, cache={"k": k, "v": v, "pos": slot_pos, "len": ln}
        )
        x = x + a
        h = L.layernorm(lp["ln_x"], x)
        a, _ = cross_attention(lp["xattn"], h, None, cfg, precomputed=(xk, xv))
        x = x + a
        h = L.layernorm(lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, cfg.act)
        return x, (nc["k"], nc["v"], nc["pos"], nc["len"])

    x, (nk, nv, npos, nlen) = jax.lax.scan(
        body,
        x,
        (
            params["dec"],
            cache["k"],
            cache["v"],
            cache["pos"],
            cache["len"],
            cache["xk"],
            cache["xv"],
        ),
    )
    x = L.layernorm(params["ln_dec"], x)
    logits = L.unembed_logits(params["embed"], x, true_vocab=cfg.vocab)
    new_cache = dict(cache, k=nk, v=nv, pos=npos, len=nlen)
    return logits, new_cache


def cache_specs(cfg: EncDecConfig):
    return {
        "k": ("layers", "batch", "seq", "heads", "head_dim"),
        "v": ("layers", "batch", "seq", "heads", "head_dim"),
        "pos": ("layers", "seq"),
        "len": ("layers",),
        "xk": ("layers", "batch", "seq", "heads", "head_dim"),
        "xv": ("layers", "batch", "seq", "heads", "head_dim"),
    }
