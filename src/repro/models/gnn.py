"""GNN model zoo (the paper's own family): GCN, GraphSAGE, GIN, GAT.

All models express aggregation through ``repro.core.aggregate`` so any
sparse backend (CSR / CSC / SCV / SCV-Z / Pallas kernel) is a drop-in —
this is the paper's technique as a first-class framework feature, and it
is *trainable*: edge weights flow through the kernel's custom VJP (the
paper's future-work item (i)).

Graphs are passed as a ``Graph`` bundle carrying the COO plus prebuilt SCV
tiles; per-edge attention (GAT) re-weights tile values through
``SCVTiles.perm``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import aggregate_scv_tiles, scv_device_arrays
from repro.core.formats import COOMatrix, block_diag_coo
from repro.core.scv import SCVTiles, coo_to_scv_tiles
from repro.models.layers import make_param, split_tree


@dataclasses.dataclass
class Graph:
    """Device-ready graph: COO arrays + SCV tiles + degree info."""

    n_nodes: int
    rows: jnp.ndarray  # i32[E] (normalized adjacency entries)
    cols: jnp.ndarray
    vals: jnp.ndarray  # f32[E] normalized weights (GCN) or 1s
    tiles: SCVTiles
    tile_arrays: dict  # device bundle incl. dummy coverage rows
    perm: jnp.ndarray  # i64[nt, cap] source entry of each tile slot


def build_graph(adj: COOMatrix, tile: int = 64, backend_cap: Optional[int] = None) -> Graph:
    tiles = coo_to_scv_tiles(adj, tile, cap=backend_cap)
    arrays = scv_device_arrays(tiles)
    nt_cov = arrays["tile_row"].shape[0]
    perm = np.full((nt_cov, tiles.cap), -1, np.int64)
    perm[: tiles.perm.shape[0]] = tiles.perm
    return Graph(
        n_nodes=adj.shape[0],
        rows=jnp.asarray(adj.rows),
        cols=jnp.asarray(adj.cols),
        vals=jnp.asarray(adj.vals),
        tiles=tiles,
        tile_arrays=arrays,
        perm=jnp.asarray(perm),
    )


def _agg(g: Graph, z, edge_vals=None, backend="jnp"):
    """Aggregate with optional per-edge re-weighting (GAT)."""
    arrays = g.tile_arrays
    if edge_vals is not None:
        ev = jnp.concatenate([edge_vals, jnp.zeros((1,), edge_vals.dtype)])
        arrays = dict(arrays, vals=ev[g.perm].astype(arrays["vals"].dtype))
    return aggregate_scv_tiles(g.tiles, z, backend=backend, arrays=arrays)[
        : g.n_nodes
    ]


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def init_gcn_layer(key, d_in, d_out):
    return {"w": make_param(key, (d_in, d_out), ("gnn_in", "gnn_out"))}


def gcn_layer(p, g: Graph, h, backend="jnp"):
    z = h @ p["w"].astype(h.dtype)  # combination, Eq. (2)
    return _agg(g, z, backend=backend)  # aggregation, Eq. (3)


def init_sage_layer(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w_self": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "w_neigh": make_param(k2, (d_in, d_out), ("gnn_in", "gnn_out")),
    }


def sage_layer(p, g: Graph, h, backend="jnp"):
    neigh = _agg(g, h @ p["w_neigh"].astype(h.dtype), backend=backend)
    return h @ p["w_self"].astype(h.dtype) + neigh


def init_gin_layer(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w1": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "w2": make_param(k2, (d_out, d_out), ("gnn_in", "gnn_out")),
        "eps": (jnp.zeros((), jnp.float32), ()),
    }


def gin_layer(p, g: Graph, h, backend="jnp"):
    agg = _agg(g, h, backend=backend)  # sum aggregation over raw features
    z = (1.0 + p["eps"]) * h + agg
    z = jax.nn.relu(z @ p["w1"].astype(h.dtype))
    return z @ p["w2"].astype(h.dtype)


def init_gat_layer(key, d_in, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "a_src": make_param(k2, (d_out,), ("gnn_out",)),
        "a_dst": make_param(k3, (d_out,), ("gnn_out",)),
    }


def gat_layer(p, g: Graph, h, backend="jnp"):
    """Single-head GAT: per-edge attention -> SCV aggregation with
    re-weighted values (weighted aggregation, §IV-D)."""
    z = h @ p["w"].astype(h.dtype)
    e_src = z @ p["a_src"].astype(h.dtype)  # [N]
    e_dst = z @ p["a_dst"].astype(h.dtype)
    logits = jax.nn.leaky_relu(e_src[g.rows] + e_dst[g.cols], 0.2)
    # edge softmax per destination row (stable)
    rmax = jnp.full((g.n_nodes,), -1e30, logits.dtype).at[g.rows].max(logits)
    ex = jnp.exp(logits - rmax[g.rows])
    denom = jnp.zeros((g.n_nodes,), ex.dtype).at[g.rows].add(ex)
    alpha = ex / jnp.maximum(denom[g.rows], 1e-9)
    return _agg(g, z, edge_vals=alpha, backend=backend)


# ---------------------------------------------------------------------------
# models
# ---------------------------------------------------------------------------

_LAYERS = {
    "gcn": (init_gcn_layer, gcn_layer),
    "sage": (init_sage_layer, sage_layer),
    "gin": (init_gin_layer, gin_layer),
    "gat": (init_gat_layer, gat_layer),
}


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # gcn | sage | gin | gat
    d_in: int
    d_hidden: int
    n_classes: int
    n_layers: int = 2
    backend: str = "jnp"  # aggregation backend (pallas on TPU)


def init_gnn(key, cfg: GNNConfig):
    init_fn, _ = _LAYERS[cfg.kind]
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    tree = {}
    for i, k in enumerate(jax.random.split(key, cfg.n_layers)):
        tree[f"layer{i}"] = init_fn(k, dims[i], dims[i + 1])
    return split_tree(tree)


def gnn_forward(params, cfg: GNNConfig, g: Graph, x):
    _, layer_fn = _LAYERS[cfg.kind]
    h = x
    for i in range(cfg.n_layers):
        h = layer_fn(params[f"layer{i}"], g, h, backend=cfg.backend)
        if i + 1 < cfg.n_layers:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# batched multi-graph forward (serving path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedGraph:
    """Many small graphs composed into one block-diagonal ``Graph``.

    Because the composite adjacency is block-diagonal, one aggregation
    launch over it equals the per-graph aggregations stacked.  Request i
    owns node rows ``node_offsets[i] : node_offsets[i] + node_counts[i]``;
    every other composite row is structural padding (members may sit at
    tile-aligned offsets, and the composite is grown to a padding bucket so
    jit sees few distinct shapes).  ``n_real_nodes`` is the total real node
    count across members — NOT a row boundary; always use the offset/count
    arrays to locate real rows.
    """

    graph: Graph
    node_offsets: np.ndarray  # int64[k+1] — request i starts at composite row off[i]
    node_counts: np.ndarray  # int64[k] — request i owns off[i] : off[i]+counts[i]
    n_real_nodes: int

    @property
    def n_graphs(self) -> int:
        return len(self.node_counts)


def build_batched_graph(
    adjs: list[COOMatrix],
    tile: int = 64,
    backend_cap: Optional[int] = None,
    pad_nodes: Optional[int] = None,
) -> BatchedGraph:
    """Compose per-request adjacencies into one device-ready Graph."""
    for a in adjs:
        if a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
    n_real = int(sum(a.shape[0] for a in adjs))
    pad_shape = None
    if pad_nodes is not None:
        if pad_nodes < n_real:
            raise ValueError(f"pad_nodes={pad_nodes} < total nodes {n_real}")
        pad_shape = (pad_nodes, pad_nodes)
    comp, row_off, _ = block_diag_coo(adjs, pad_shape=pad_shape)
    g = build_graph(comp, tile=tile, backend_cap=backend_cap)
    return BatchedGraph(
        graph=g,
        node_offsets=row_off,
        node_counts=np.diff(row_off),
        n_real_nodes=n_real,
    )


def batch_features(bg: BatchedGraph, xs: list) -> jnp.ndarray:
    """Stack per-request feature matrices into the composite node space
    (zeros in padding rows)."""
    if len(xs) != bg.n_graphs:
        raise ValueError(f"{len(xs)} feature blocks for {bg.n_graphs} graphs")
    d = int(np.asarray(xs[0]).shape[1]) if xs else 0
    x = np.zeros((bg.graph.n_nodes, d), np.float32)
    for i, xi in enumerate(xs):
        s = int(bg.node_offsets[i])
        x[s : s + int(bg.node_counts[i])] = np.asarray(xi, np.float32)
    return jnp.asarray(x)


def split_outputs(bg: BatchedGraph, out: jnp.ndarray) -> list[np.ndarray]:
    """Scatter the composite output back into per-request blocks.

    Blocks are copies, not views: a view would pin the whole bucket-sized
    composite alive for as long as any request retains its (much smaller)
    output."""
    host = np.asarray(out)
    return [
        host[
            int(bg.node_offsets[i]) : int(bg.node_offsets[i]) + int(bg.node_counts[i])
        ].copy()
        for i in range(bg.n_graphs)
    ]


def gnn_forward_batched(params, cfg: GNNConfig, bg: BatchedGraph, xs: list):
    """One forward over the block-diagonal composite; returns the
    per-request outputs (exactly ``gnn_forward`` on each graph, up to
    float-add reassociation across tile boundaries)."""
    out = gnn_forward(params, cfg, bg.graph, batch_features(bg, xs))
    return split_outputs(bg, out)


def gnn_loss(params, cfg: GNNConfig, g: Graph, x, labels, mask):
    logits = gnn_forward(params, cfg, g, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
