"""GNN model zoo (the paper's own family): GCN, GraphSAGE, GIN, GAT.

All models express aggregation through ``repro.core.aggregate`` so any
sparse backend (CSR / CSC / SCV / SCV-Z / Pallas kernel) is a drop-in —
this is the paper's technique as a first-class framework feature, and it
is *trainable*: edge weights flow through the kernel's custom VJP (the
paper's future-work item (i)).

``Graph`` and ``BatchedGraph`` are registered jax pytrees wrapping a plan
(single-cap ``SCVPlan``, nnz-bucketed ``SCVBucketedPlan``, or a
mesh-placed ``core.exec.ShardedPlan``): device arrays are leaves,
counts/offsets are static aux data.  ``gnn_forward`` and
``gnn_forward_batched`` therefore run under a single outer ``jax.jit``
(``gnn_forward_jit`` is the prebuilt wrapper) — every layer's combination
*and* aggregation compiles into one XLA program, with retraces bounded by
the padding buckets because jit keys only on leaf shapes + static aux.
Per-edge attention (GAT) re-weights the plan's tile values through its
``perm`` leaf.

Device placement is the plan's business, not the model's:
``core.exec.PlanExecutor.prepare_graph`` swaps the plan for a
``ShardedPlan`` (mesh + sharding decision in its static aux), and the
same ``gnn_forward`` then compiles to a multi-device program — the
``shard_map`` aggregation launches (one boundary ``psum`` over the
``"tiles"`` axis, feature slabs collective-free) sit inside the one XLA
program like any other op.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import aggregate_scv_plan
from repro.core.formats import COOMatrix, block_diag_coo
from repro.core.scv import (
    DEFAULT_TILE,
    SCVBucketedPlan,
    SCVPlan,
    coo_to_scv_tiles,
    plan_from_tiles,
    plan_from_tiles_bucketed,
)
from repro.models.layers import make_param, split_tree


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Graph:
    """Device-ready graph plan, registered as a jax pytree.

    Leaves: the ``SCVPlan`` (itself a pytree) and the optional COO edge
    arrays (``rows`` / ``cols`` / ``vals`` — only GAT's attention reads
    them; batched composites may omit them, see
    ``serve.graph_engine.assemble_batched_graph``).  Static aux:
    ``n_nodes``.
    """

    n_nodes: int
    plan: "SCVPlan | SCVBucketedPlan | ShardedPlan"
    rows: Optional[jnp.ndarray] = None  # i32[E] (normalized adjacency entries)
    cols: Optional[jnp.ndarray] = None
    vals: Optional[jnp.ndarray] = None  # f32[E] normalized weights (GCN) or 1s

    def tree_flatten(self):
        return (self.plan, self.rows, self.cols, self.vals), (self.n_nodes,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)


def build_graph(
    adj: COOMatrix,
    tile: int = DEFAULT_TILE,
    backend_cap: Optional[int] = None,
    with_edges: bool = True,
    bucket_caps=None,
    config=None,
) -> Graph:
    """COO adjacency -> device-ready :class:`Graph`.

    ``bucket_caps`` selects the nnz-bucketed plan layout: ``"auto"``
    derives the capacity ladder from the tile nnz histogram
    (``core.scv.bucket_caps_for``); an explicit ascending tuple pins it
    (serving uses a fixed ladder so every member plan shares segment aux).
    ``None`` keeps the single-cap :class:`SCVPlan`.  When a ladder is
    active it supersedes ``backend_cap`` entirely (heavy tiles chain-split
    at ``caps[-1]``, the per-segment caps come from the ladder).

    ``config`` — a ``repro.tune.TunedConfig`` (mutually exclusive with
    the explicit layout arguments): its tile and ladder (or single cap
    when the ladder is empty) define the whole layout, so an autotuned
    regime threads through as one object.
    """
    if config is not None:
        if bucket_caps is not None or backend_cap is not None or tile != DEFAULT_TILE:
            raise ValueError(
                "config carries tile/cap/ladder; don't also pass them explicitly"
            )
        tile = config.tile
        if config.bucket_caps:
            bucket_caps = tuple(config.bucket_caps)
        else:
            backend_cap = config.cap
    if bucket_caps is not None and backend_cap is not None:
        raise ValueError(
            "backend_cap and bucket_caps are mutually exclusive: the "
            "bucket ladder defines every capacity (chain-split at caps[-1])"
        )
    if bucket_caps is not None:
        if bucket_caps == "auto":
            from repro.core.scv import bucket_caps_for, tile_nnz_histogram

            caps = bucket_caps_for(tile_nnz_histogram(adj, tile), tile)
        else:
            caps = tuple(int(c) for c in bucket_caps)
            if list(caps) != sorted(set(caps)) or caps[0] <= 0:
                raise ValueError(
                    f"bucket_caps must be ascending distinct positives, got {caps}"
                )
        # chain-split heavy tiles at the ladder's largest cap so every
        # chain fits some bucket
        tiles = coo_to_scv_tiles(adj, tile, cap=caps[-1])
        plan = plan_from_tiles_bucketed(tiles, caps=caps)
    else:
        tiles = coo_to_scv_tiles(adj, tile, cap=backend_cap)
        plan = plan_from_tiles(tiles)  # coverage dummies + perm padding, one path
    if with_edges:
        rows, cols, vals = (
            jnp.asarray(adj.rows), jnp.asarray(adj.cols), jnp.asarray(adj.vals),
        )
    else:
        rows = cols = vals = None
    return Graph(n_nodes=adj.shape[0], plan=plan, rows=rows, cols=cols, vals=vals)


def _agg(g: Graph, z, edge_vals=None, backend="jnp"):
    """Aggregate with optional per-edge re-weighting (GAT).

    ``aggregate_scv_plan`` dispatches on the plan kind — a mesh-placed
    ``ShardedPlan`` runs the executor's shard_map launch; the layers never
    know where the plan lives."""
    plan = g.plan
    if edge_vals is not None:
        # perm == -1 (padding slot) gathers an appended zero; bucketed
        # and sharded plans re-gather per capacity segment
        plan = plan.reweighted(edge_vals)
    return aggregate_scv_plan(plan, z, backend=backend)[: g.n_nodes]


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def init_gcn_layer(key, d_in, d_out):
    return {"w": make_param(key, (d_in, d_out), ("gnn_in", "gnn_out"))}


def gcn_layer(p, g: Graph, h, backend="jnp"):
    z = h @ p["w"].astype(h.dtype)  # combination, Eq. (2)
    return _agg(g, z, backend=backend)  # aggregation, Eq. (3)


def init_sage_layer(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w_self": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "w_neigh": make_param(k2, (d_in, d_out), ("gnn_in", "gnn_out")),
    }


def sage_layer(p, g: Graph, h, backend="jnp"):
    neigh = _agg(g, h @ p["w_neigh"].astype(h.dtype), backend=backend)
    return h @ p["w_self"].astype(h.dtype) + neigh


def init_gin_layer(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w1": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "w2": make_param(k2, (d_out, d_out), ("gnn_in", "gnn_out")),
        "eps": (jnp.zeros((), jnp.float32), ()),
    }


def gin_layer(p, g: Graph, h, backend="jnp"):
    agg = _agg(g, h, backend=backend)  # sum aggregation over raw features
    z = (1.0 + p["eps"]) * h + agg
    z = jax.nn.relu(z @ p["w1"].astype(h.dtype))
    return z @ p["w2"].astype(h.dtype)


def init_gat_layer(key, d_in, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": make_param(k1, (d_in, d_out), ("gnn_in", "gnn_out")),
        "a_src": make_param(k2, (d_out,), ("gnn_out",)),
        "a_dst": make_param(k3, (d_out,), ("gnn_out",)),
    }


def gat_layer(p, g: Graph, h, backend="jnp"):
    """Single-head GAT: per-edge attention -> SCV aggregation with
    re-weighted values (weighted aggregation, §IV-D)."""
    if g.rows is None:
        raise ValueError(
            "GAT needs the graph's COO edge arrays; build the plan with "
            "with_edges=True (serving: assemble_batched_graph(with_edges=True))"
        )
    z = h @ p["w"].astype(h.dtype)
    e_src = z @ p["a_src"].astype(h.dtype)  # [N]
    e_dst = z @ p["a_dst"].astype(h.dtype)
    logits = jax.nn.leaky_relu(e_src[g.rows] + e_dst[g.cols], 0.2)
    # edge softmax per destination row (stable)
    rmax = jnp.full((g.n_nodes,), -1e30, logits.dtype).at[g.rows].max(logits)
    ex = jnp.exp(logits - rmax[g.rows])
    denom = jnp.zeros((g.n_nodes,), ex.dtype).at[g.rows].add(ex)
    alpha = ex / jnp.maximum(denom[g.rows], 1e-9)
    return _agg(g, z, edge_vals=alpha, backend=backend)


# ---------------------------------------------------------------------------
# models
# ---------------------------------------------------------------------------

_LAYERS = {
    "gcn": (init_gcn_layer, gcn_layer),
    "sage": (init_sage_layer, sage_layer),
    "gin": (init_gin_layer, gin_layer),
    "gat": (init_gat_layer, gat_layer),
}


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # gcn | sage | gin | gat
    d_in: int
    d_hidden: int
    n_classes: int
    n_layers: int = 2
    backend: str = "jnp"  # aggregation backend (pallas on TPU)


def init_gnn(key, cfg: GNNConfig):
    init_fn, _ = _LAYERS[cfg.kind]
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    tree = {}
    for i, k in enumerate(jax.random.split(key, cfg.n_layers)):
        tree[f"layer{i}"] = init_fn(k, dims[i], dims[i + 1])
    return split_tree(tree)


def gnn_forward(params, cfg: GNNConfig, g: Graph, x):
    """Full multi-layer forward.  Pure function of pytree arguments —
    ``g`` is a registered pytree and ``cfg`` is hashable — so the whole
    thing jits: see ``gnn_forward_jit``."""
    _, layer_fn = _LAYERS[cfg.kind]
    h = x
    for i in range(cfg.n_layers):
        h = layer_fn(params[f"layer{i}"], g, h, backend=cfg.backend)
        if i + 1 < cfg.n_layers:
            h = jax.nn.relu(h)
    return h


#: End-to-end jitted forward: one XLA program per (cfg, graph aux + leaf
#: shapes, x shape) — i.e. at most one trace per serving padding bucket.
gnn_forward_jit = jax.jit(gnn_forward, static_argnames=("cfg",))


# ---------------------------------------------------------------------------
# batched multi-graph forward (serving path)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BatchedGraph:
    """Many small graphs composed into one block-diagonal ``Graph``.

    Because the composite adjacency is block-diagonal, one aggregation
    launch over it equals the per-graph aggregations stacked.  Request i
    owns node rows ``node_offsets[i] : node_offsets[i] + node_counts[i]``;
    every other composite row is structural padding (members may sit at
    tile-aligned offsets, and the composite is grown to a padding bucket so
    jit sees few distinct shapes).  ``n_real_nodes`` is the total real node
    count across members — NOT a row boundary; always use the offset/count
    arrays to locate real rows.

    Pytree: the composite ``graph`` is the only leaf subtree; the offset /
    count arrays are static aux data (as int tuples), so the per-member
    scatter/split slices stay Python ints under jit.  Note this makes the
    member layout part of a jit trace signature — the serving engine
    therefore jits the composite ``gnn_forward`` (whose signature depends
    only on the padding bucket) and keeps the member bookkeeping eager.
    """

    graph: Graph
    node_offsets: np.ndarray  # int64[k+1] — request i starts at composite row off[i]
    node_counts: np.ndarray  # int64[k] — request i owns off[i] : off[i]+counts[i]
    n_real_nodes: int

    def tree_flatten(self):
        return (self.graph,), (
            tuple(int(o) for o in self.node_offsets),
            tuple(int(c) for c in self.node_counts),
            self.n_real_nodes,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        off, cnt, n_real = aux
        return cls(
            graph=children[0],
            node_offsets=np.asarray(off, np.int64),
            node_counts=np.asarray(cnt, np.int64),
            n_real_nodes=n_real,
        )

    @property
    def n_graphs(self) -> int:
        return len(self.node_counts)


def build_batched_graph(
    adjs: list[COOMatrix],
    tile: int = DEFAULT_TILE,
    backend_cap: Optional[int] = None,
    pad_nodes: Optional[int] = None,
) -> BatchedGraph:
    """Compose per-request adjacencies into one device-ready Graph."""
    for a in adjs:
        if a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
    n_real = int(sum(a.shape[0] for a in adjs))
    pad_shape = None
    if pad_nodes is not None:
        if pad_nodes < n_real:
            raise ValueError(f"pad_nodes={pad_nodes} < total nodes {n_real}")
        pad_shape = (pad_nodes, pad_nodes)
    comp, row_off, _ = block_diag_coo(adjs, pad_shape=pad_shape)
    g = build_graph(comp, tile=tile, backend_cap=backend_cap)
    return BatchedGraph(
        graph=g,
        node_offsets=row_off,
        node_counts=np.diff(row_off),
        n_real_nodes=n_real,
    )


def batch_features(bg: BatchedGraph, xs) -> jnp.ndarray:
    """Stack per-request feature matrices into the composite node space
    (zeros in padding rows).

    Works both eagerly (numpy fill, one host->device transfer) and under a
    jit trace (static-slice ``.at[].set`` updates — the offsets are static
    aux of ``bg``), so ``gnn_forward_batched`` is jit-able end to end.
    """
    if len(xs) != bg.n_graphs:
        raise ValueError(f"{len(xs)} feature blocks for {bg.n_graphs} graphs")
    if any(isinstance(xi, jax.core.Tracer) for xi in xs):
        d = int(xs[0].shape[1]) if xs else 0
        x = jnp.zeros((bg.graph.n_nodes, d), jnp.float32)
        for i, xi in enumerate(xs):
            s, c = int(bg.node_offsets[i]), int(bg.node_counts[i])
            x = x.at[s : s + c].set(xi.astype(jnp.float32))
        return x
    d = int(np.asarray(xs[0]).shape[1]) if xs else 0
    x = np.zeros((bg.graph.n_nodes, d), np.float32)
    for i, xi in enumerate(xs):
        s = int(bg.node_offsets[i])
        x[s : s + int(bg.node_counts[i])] = np.asarray(xi, np.float32)
    return jnp.asarray(x)


def split_outputs(bg: BatchedGraph, out) -> list:
    """Scatter the composite output back into per-request blocks.

    Eagerly, blocks are numpy copies, not views: a view would pin the whole
    bucket-sized composite alive for as long as any request retains its
    (much smaller) output.  Under a jit trace, blocks are static slices of
    the traced composite (XLA owns the buffers there).
    """
    spans = [
        (int(bg.node_offsets[i]), int(bg.node_counts[i]))
        for i in range(bg.n_graphs)
    ]
    if isinstance(out, jax.core.Tracer):
        return [out[s : s + c] for s, c in spans]
    host = np.asarray(out)
    return [host[s : s + c].copy() for s, c in spans]


def gnn_forward_batched(params, cfg: GNNConfig, bg: BatchedGraph, xs):
    """One forward over the block-diagonal composite; returns the
    per-request outputs (exactly ``gnn_forward`` on each graph, up to
    float-add reassociation across tile boundaries).

    The composite forward runs through ``gnn_forward_jit`` (nested jit is
    inlined when this function is itself traced), so the per-layer hot path
    never round-trips through Python dispatch; only the per-member
    scatter/split bookkeeping stays host-side when called eagerly.  The
    function is also directly wrappable in ``jax.jit`` (``bg`` is a pytree
    whose member layout is static aux).
    """
    out = gnn_forward_jit(params, cfg, bg.graph, batch_features(bg, xs))
    return split_outputs(bg, out)


def gnn_loss(params, cfg: GNNConfig, g: Graph, x, labels, mask):
    logits = gnn_forward(params, cfg, g, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
