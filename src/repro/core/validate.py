"""scvcheck leg 1: the plan-invariant verifier (DESIGN.md §6).

SCV-GNN's speedup story rests on structural invariants the rest of the
stack *assumes* but never checks: the tile schedule, row coverage,
per-tile capacity, perm bijectivity, the bucket ladder and the sharded
span layout.  Four layers transform those invariants (plan -> bucketed
plan -> sharded plan -> serving composite); any silent corruption turns
into wrong aggregations, not crashes — the dominant correctness risk the
GNN-acceleration surveys flag for sparse accelerator stacks.

``validate_plan`` takes any plan-like object (:class:`SCVTiles`,
:class:`SCVPlan`, :class:`SCVBucketedPlan`, ``core.exec.ShardedPlan``,
or a serve composite via ``models.gnn.Graph`` / ``BatchedGraph``) and
runs the full invariant chain, returning a machine-readable
:class:`ValidationReport` — per-invariant pass/fail plus the offending
tile / segment / span indices.  Everything is pure, host-side numpy:
leaves are read back once and no jit trace is touched, so the checker is
safe to call from tests, from the serving admission boundary
(``GraphServeEngine`` debug mode) and from future delta-plan maintenance.

The invariant chain (DESIGN.md §6 states the contract prose-side):

* **shape-aux** — leaf shapes/dtypes consistent with the plan's static
  aux (``[nt, cap]`` entry arrays, int32 indices, ascending distinct
  segment caps, segments agreeing on tile/shape/order).
* **bounds** — local rows/cols in ``[0, T)``; tile coordinates inside
  the padded block grid.
* **cap** — ``0 <= nnz_in_tile <= cap`` for every tile.
* **packing** — entries front-packed: every slot past ``nnz_in_tile``
  is structural padding (``val == 0``, ``row == col == 0``,
  ``perm == -1``).  The kernel relies on padding adding zero.
* **order** — the schedule invariant: restricted to real (``nnz > 0``)
  tiles, ``tile_row`` is non-decreasing and, within a block-row, the
  Z-Morton key (equivalently ``tile_col``) is non-decreasing.
* **coverage** — every PS block-row appears in ``tile_row`` (coverage
  dummies present wherever no real tile visits a row), and each
  block-row forms ONE contiguous run of the schedule: a second run for
  an already-visited row would make the Pallas kernel re-zero a PS
  strip and wipe real output.
* **perm** — the perm leaf is a bijection over the real (non-padding)
  slots: real slots carry distinct source-entry ids covering
  ``0 .. nnz-1`` exactly once (unioned across segments / spans),
  padding slots carry ``-1``.
* **ladder** — bucketed segments are disjoint and complete w.r.t. the
  source tiles: segment ``j`` holds exactly the real tiles with
  ``caps[j-1] < nnz <= caps[j]`` (zero-nnz coverage tiles may live in
  any segment — each per-bucket launch covers its own output).
* **shard-span** — a sharded plan's spans reassemble to the unsharded
  plan: concatenating each segment's spans (dropping zero-nnz span
  padding) yields the same entry multiset, and the per-span schedules
  still satisfy order/coverage-contiguity locally.
* **reassembly** (optional, ``coo=`` given) — the plan's real entries
  byte-match the source COO (same (row, col, val) multiset).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import SCVBucketedPlan, SCVPlan, SCVTiles


# ---------------------------------------------------------------------------
# report types
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InvariantResult:
    """One invariant checked on one plan / segment / span."""

    invariant: str  # "order" | "coverage" | "cap" | "packing" | ...
    ok: bool
    segment: Optional[int] = None  # capacity-bucket index, if any
    part: Optional[int] = None  # sharded span index, if any
    offending: tuple[int, ...] = ()  # tile (or segment) indices at fault
    detail: str = ""

    def where(self) -> str:
        loc = []
        if self.segment is not None:
            loc.append(f"segment {self.segment}")
        if self.part is not None:
            loc.append(f"span {self.part}")
        return ", ".join(loc) or "plan"


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    """Machine-readable outcome of :func:`validate_plan`."""

    kind: str  # "tiles" | "plan" | "bucketed" | "sharded" | "graph" | ...
    checks: tuple[InvariantResult, ...]

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def failures(self) -> tuple[InvariantResult, ...]:
        return tuple(c for c in self.checks if not c.ok)

    def failed(self, invariant: str) -> tuple[InvariantResult, ...]:
        return tuple(c for c in self.failures if c.invariant == invariant)

    def summary(self) -> str:
        if self.ok:
            return f"{self.kind}: all {len(self.checks)} invariant checks passed"
        lines = [f"{self.kind}: {len(self.failures)} invariant violation(s)"]
        for c in self.failures:
            off = f" tiles={list(c.offending[:8])}" if c.offending else ""
            lines.append(f"  {c.invariant} @ {c.where()}: {c.detail}{off}")
        return "\n".join(lines)

    def raise_if_failed(self) -> "ValidationReport":
        if not self.ok:
            raise PlanInvariantError(self)
        return self


class PlanInvariantError(ValueError):
    """Raised by ``ValidationReport.raise_if_failed`` (admission boundary)."""

    def __init__(self, report: ValidationReport):
        super().__init__(report.summary())
        self.report = report


# ---------------------------------------------------------------------------
# COO admission checks (serving boundary)
# ---------------------------------------------------------------------------
def check_coo(a: COOMatrix, square: bool = False) -> None:
    """Reject malformed client COO with a clear ``ValueError``.

    Out-of-range / negative indices would shift into a *neighbor's* block
    of a serving composite and silently corrupt co-batched outputs — the
    failure mode this admission hook exists to make loud.
    """
    m, n = a.shape
    if m < 0 or n < 0:
        raise ValueError(f"COO shape must be non-negative, got {a.shape}")
    if square and m != n:
        raise ValueError(f"adjacency must be square, got {a.shape}")
    if not (len(a.rows) == len(a.cols) == len(a.vals)):
        raise ValueError(
            f"COO arrays disagree on nnz: rows={len(a.rows)} "
            f"cols={len(a.cols)} vals={len(a.vals)}"
        )
    if a.nnz == 0:
        return
    rmin, rmax = int(a.rows.min()), int(a.rows.max())
    cmin, cmax = int(a.cols.min()), int(a.cols.max())
    if rmin < 0 or cmin < 0:
        raise ValueError(
            f"COO indices must be non-negative (rows >= {rmin}, cols >= {cmin})"
        )
    if rmax >= m or cmax >= n:
        raise ValueError(
            f"COO indices out of range for shape {a.shape}: "
            f"max row {rmax}, max col {cmax}"
        )
    if not np.all(np.isfinite(a.vals)):
        bad = np.flatnonzero(~np.isfinite(a.vals))
        raise ValueError(
            f"COO values must be finite; {len(bad)} non-finite entries "
            f"(first at {int(bad[0])})"
        )


# ---------------------------------------------------------------------------
# per-plan invariant checks (pure numpy over read-back leaves)
# ---------------------------------------------------------------------------
def _np(a) -> np.ndarray:
    return np.asarray(a)


@dataclasses.dataclass(frozen=True)
class _PlanView:
    """Host-side snapshot of one plan's arrays (works for SCVTiles too)."""

    tile_row: np.ndarray
    tile_col: np.ndarray
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    nnz_in_tile: np.ndarray
    perm: Optional[np.ndarray]
    tile: int
    cap: int
    shape: tuple[int, int]
    order: str

    @classmethod
    def of(cls, p: Union[SCVPlan, SCVTiles]) -> "_PlanView":
        return cls(
            tile_row=_np(p.tile_row).astype(np.int64),
            tile_col=_np(p.tile_col).astype(np.int64),
            rows=_np(p.rows),
            cols=_np(p.cols),
            vals=_np(p.vals),
            nnz_in_tile=_np(p.nnz_in_tile).astype(np.int64),
            perm=None if p.perm is None else _np(p.perm).astype(np.int64),
            tile=int(p.tile),
            cap=int(p.cap),
            shape=tuple(p.shape),
            order=p.order,
        )

    @property
    def n_tiles(self) -> int:
        return int(self.tile_row.shape[0])

    @property
    def n_row_blocks(self) -> int:
        return -(-self.shape[0] // self.tile)

    @property
    def n_col_blocks(self) -> int:
        return -(-self.shape[1] // self.tile)


def _check_shape_aux(v: _PlanView, loc: dict) -> list[InvariantResult]:
    out = []
    nt, cap = v.n_tiles, v.cap
    bad = []
    for name, arr, want in (
        ("tile_row", v.tile_row, (nt,)),
        ("tile_col", v.tile_col, (nt,)),
        ("nnz_in_tile", v.nnz_in_tile, (nt,)),
        ("rows", v.rows, (nt, cap)),
        ("cols", v.cols, (nt, cap)),
        ("vals", v.vals, (nt, cap)),
    ):
        if tuple(arr.shape) != want:
            bad.append(f"{name}.shape={tuple(arr.shape)} != {want}")
    if v.perm is not None and tuple(v.perm.shape) != (nt, cap):
        bad.append(f"perm.shape={tuple(v.perm.shape)} != {(nt, cap)}")
    for name, arr in (("rows", v.rows), ("cols", v.cols)):
        if not np.issubdtype(arr.dtype, np.integer):
            bad.append(f"{name}.dtype={arr.dtype} not integer")
    if not np.issubdtype(v.vals.dtype, np.floating):
        bad.append(f"vals.dtype={v.vals.dtype} not floating")
    if v.tile <= 0 or v.cap <= 0:
        bad.append(f"tile={v.tile}, cap={v.cap} must be positive")
    out.append(
        InvariantResult(
            "shape-aux", not bad, detail="; ".join(bad), **loc
        )
    )
    return out


def _check_bounds(v: _PlanView, loc: dict) -> list[InvariantResult]:
    T = v.tile
    slot = np.arange(v.cap)[None, :]
    real = slot < v.nnz_in_tile[:, None]
    bad_local = np.flatnonzero(
        ((v.rows < 0) | (v.rows >= T) | (v.cols < 0) | (v.cols >= T)) & real
        if real.size else np.zeros(0, bool)
    )
    bad_tiles = np.unique(bad_local // max(v.cap, 1)) if bad_local.size else []
    bad_coord = np.flatnonzero(
        (v.tile_row < 0)
        | (v.tile_row >= v.n_row_blocks)
        | (v.tile_col < 0)
        | (v.tile_col >= v.n_col_blocks)
    )
    off = tuple(int(i) for i in np.union1d(bad_tiles, bad_coord))
    detail = ""
    if len(bad_tiles):
        detail += f"local row/col outside [0, {T}) in {len(bad_tiles)} tile(s); "
    if len(bad_coord):
        detail += (
            f"tile coordinates outside {v.n_row_blocks}x{v.n_col_blocks} "
            f"block grid in {len(bad_coord)} tile(s)"
        )
    return [InvariantResult("bounds", not off, offending=off, detail=detail, **loc)]


def _check_cap(v: _PlanView, loc: dict) -> list[InvariantResult]:
    bad = np.flatnonzero((v.nnz_in_tile < 0) | (v.nnz_in_tile > v.cap))
    detail = (
        f"nnz_in_tile outside [0, cap={v.cap}] "
        f"(worst: {int(v.nnz_in_tile[bad[0]])} at tile {int(bad[0])})"
        if bad.size
        else ""
    )
    return [
        InvariantResult(
            "cap", not bad.size, offending=tuple(int(i) for i in bad),
            detail=detail, **loc,
        )
    ]


def _check_packing(v: _PlanView, loc: dict) -> list[InvariantResult]:
    nnz = np.clip(v.nnz_in_tile, 0, v.cap)
    slot = np.arange(v.cap)[None, :]
    pad = slot >= nnz[:, None]
    dirty = pad & ((v.vals != 0) | (v.rows != 0) | (v.cols != 0))
    if v.perm is not None:
        dirty |= pad & (v.perm != -1)
        dirty |= (~pad) & (v.perm < 0)  # real slots must carry a source id
    bad = np.unique(np.nonzero(dirty)[0]) if dirty.size else np.zeros(0, np.int64)
    detail = (
        f"{int(dirty.sum())} padding slot(s) not structurally zero "
        "(val==0, row==col==0, perm==-1) or real slot(s) with perm < 0"
        if bad.size
        else ""
    )
    return [
        InvariantResult(
            "packing", not bad.size, offending=tuple(int(i) for i in bad),
            detail=detail, **loc,
        )
    ]


def _check_order(v: _PlanView, loc: dict) -> list[InvariantResult]:
    """Schedule invariant over real tiles: non-decreasing block-row; inside
    a block-row, non-decreasing Z-Morton key (degenerates to ascending
    tile_col for both supported orders)."""
    real = np.flatnonzero(v.nnz_in_tile > 0)
    r, c = v.tile_row[real], v.tile_col[real]
    bad = []
    step = np.flatnonzero(np.diff(r) < 0)
    bad.extend(real[i + 1] for i in step)
    # within a block-row the Z-Morton key is monotone in tile_col (row bits
    # fixed), so ascending col IS ascending Z — one comparison covers both
    # supported orders
    back = np.flatnonzero((np.diff(r) == 0) & (np.diff(c) < 0))
    bad.extend(real[i + 1] for i in back)
    off = tuple(sorted(int(i) for i in set(bad)))
    detail = (
        f"{len(off)} real tile(s) break the (block-row, Z) schedule order"
        if off
        else ""
    )
    return [InvariantResult("order", not off, offending=off, detail=detail, **loc)]


def _check_coverage(
    v: _PlanView, loc: dict, require_full: bool = True
) -> list[InvariantResult]:
    """Row coverage + run contiguity.

    * full coverage: every block-row of the padded grid appears in
      ``tile_row`` (skipped for sharded spans — a span covers only the
      rows its tiles visit);
    * contiguity: each block-row forms one contiguous run of the
      schedule — a second, later run would make the Pallas kernel
      re-zero an already-written PS strip.
    """
    out = []
    if require_full:
        missing = np.setdiff1d(
            np.arange(v.n_row_blocks, dtype=np.int64), np.unique(v.tile_row)
        )
        out.append(
            InvariantResult(
                "coverage",
                not missing.size,
                offending=tuple(int(i) for i in missing),
                detail=(
                    f"{missing.size} block-row(s) have no tile (coverage "
                    "dummy missing) — Pallas output undefined there"
                    if missing.size
                    else ""
                ),
                **loc,
            )
        )
    # run contiguity over ALL tiles (dummies included)
    r = v.tile_row
    if r.size:
        change = np.r_[True, r[1:] != r[:-1]]
        first_seen: dict[int, int] = {}
        bad = []
        for i in np.flatnonzero(change):
            row = int(r[i])
            if row in first_seen:
                bad.append(int(i))
            else:
                first_seen[row] = int(i)
        out.append(
            InvariantResult(
                "coverage-contiguity",
                not bad,
                offending=tuple(bad),
                detail=(
                    f"{len(bad)} tile(s) start a second run for an already-"
                    "visited block-row (kernel would re-zero its PS strip)"
                    if bad
                    else ""
                ),
                **loc,
            )
        )
    else:
        out.append(InvariantResult("coverage-contiguity", True, **loc))
    return out


def _real_perm_values(v: _PlanView) -> np.ndarray:
    slot = np.arange(v.cap)[None, :]
    real = slot < np.clip(v.nnz_in_tile, 0, v.cap)[:, None]
    return v.perm[real] if v.perm is not None else np.zeros(0, np.int64)


def _check_perm_bijection(
    views: list[tuple[_PlanView, dict]], kind_loc: dict
) -> list[InvariantResult]:
    """perm values over real slots, unioned across segments/spans, must be
    a bijection onto ``0 .. nnz-1``."""
    if any(v.perm is None for v, _ in views):
        return []  # plans legitimately built without perm
    vals = np.concatenate([_real_perm_values(v) for v, _ in views]) if views else (
        np.zeros(0, np.int64)
    )
    n = vals.size
    ok = True
    detail = ""
    if n:
        uniq, counts = np.unique(vals, return_counts=True)
        dup = uniq[counts > 1]
        if vals.min() < 0:
            ok, detail = False, f"real slot carries negative perm {int(vals.min())}"
        elif dup.size:
            ok, detail = False, (
                f"{dup.size} source entr(ies) gathered more than once "
                f"(first duplicate id {int(dup[0])})"
            )
        elif uniq.size != n or int(vals.max()) != n - 1:
            ok, detail = False, (
                f"perm not onto 0..{n - 1}: {n} real slots cover "
                f"{uniq.size} distinct ids, max {int(vals.max())}"
            )
    return [InvariantResult("perm", ok, detail=detail, **kind_loc)]


def _check_ladder(plan: SCVBucketedPlan) -> list[InvariantResult]:
    out = []
    caps = plan.caps
    if list(caps) != sorted(set(caps)):
        out.append(
            InvariantResult(
                "ladder", False,
                detail=f"segment caps not ascending distinct: {caps}",
            )
        )
        return out
    for j, seg in enumerate(plan.segments):
        v = _PlanView.of(seg)
        lo = caps[j - 1] if j else 0
        nnz = v.nnz_in_tile
        # real tiles must land in the half-open bucket (lo, caps[j]];
        # zero-nnz coverage tiles may live in any segment
        bad = np.flatnonzero((nnz > 0) & ((nnz <= lo) | (nnz > caps[j])))
        out.append(
            InvariantResult(
                "ladder",
                not bad.size,
                segment=j,
                offending=tuple(int(i) for i in bad),
                detail=(
                    f"{bad.size} tile(s) outside bucket ({lo}, {caps[j]}] "
                    f"(worst nnz {int(nnz[bad[0]])})"
                    if bad.size
                    else ""
                ),
            )
        )
    return out


def _segment_entries(v: _PlanView) -> np.ndarray:
    """Real entries as a sortable (grow, gcol, val-bits) record array."""
    slot = np.arange(v.cap)[None, :]
    real = slot < np.clip(v.nnz_in_tile, 0, v.cap)[:, None]
    grow = (v.tile_row[:, None] * v.tile + v.rows)[real]
    gcol = (v.tile_col[:, None] * v.tile + v.cols)[real]
    bits = v.vals[real].astype(np.float32).view(np.uint32).astype(np.int64)
    rec = np.stack([grow.astype(np.int64), gcol.astype(np.int64), bits], 1)
    return rec[np.lexsort((rec[:, 2], rec[:, 1], rec[:, 0]))]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def _validate_single(
    v: _PlanView,
    loc: dict,
    require_full_coverage: bool = True,
) -> list[InvariantResult]:
    checks = _check_shape_aux(v, loc)
    if not checks[0].ok:  # malformed shapes: later vectorized checks would throw
        return checks
    checks += _check_bounds(v, loc)
    checks += _check_cap(v, loc)
    checks += _check_packing(v, loc)
    checks += _check_order(v, loc)
    checks += _check_coverage(v, loc, require_full=require_full_coverage)
    return checks


def _validate_reassembly(
    views: list[_PlanView], coo: COOMatrix, loc: dict
) -> list[InvariantResult]:
    """The plan's real entries byte-match the source COO multiset."""
    got = (
        np.concatenate([_segment_entries(v) for v in views])
        if views
        else np.zeros((0, 3), np.int64)
    )
    got = got[np.lexsort((got[:, 2], got[:, 1], got[:, 0]))]
    bits = np.asarray(coo.vals, np.float32).view(np.uint32).astype(np.int64)
    want = np.stack(
        [np.asarray(coo.rows, np.int64), np.asarray(coo.cols, np.int64), bits], 1
    )
    want = want[np.lexsort((want[:, 2], want[:, 1], want[:, 0]))]
    ok = got.shape == want.shape and bool(np.array_equal(got, want))
    detail = ""
    if not ok:
        if got.shape[0] != want.shape[0]:
            detail = f"plan holds {got.shape[0]} entries, COO has {want.shape[0]}"
        else:
            first = int(np.flatnonzero((got != want).any(1))[0])
            detail = (
                f"entry multiset mismatch at sorted position {first}: "
                f"plan {got[first].tolist()} vs coo {want[first].tolist()}"
            )
    return [InvariantResult("reassembly", ok, detail=detail, **loc)]


def validate_plan(
    obj: Any,
    coo: Optional[COOMatrix] = None,
) -> ValidationReport:
    """Verify the full invariant chain of any plan-like object.

    Accepts :class:`SCVTiles`, :class:`SCVPlan`, :class:`SCVBucketedPlan`,
    ``core.exec.ShardedPlan``, ``models.gnn.Graph`` and
    ``models.gnn.BatchedGraph`` (serve composites).  With ``coo`` given,
    additionally checks the plan's real entries byte-match the source COO.
    Pure and host-side; returns a :class:`ValidationReport` (use
    ``.raise_if_failed()`` at admission boundaries).
    """
    # local import: core.exec imports partition/scv; keep validate leaf-light
    from repro.core.exec import ShardedPlan

    checks: list[InvariantResult] = []

    if hasattr(obj, "graph"):  # BatchedGraph composite
        inner = validate_plan(obj.graph, coo=coo)
        return ValidationReport(kind="batched-graph", checks=inner.checks)
    if hasattr(obj, "plan") and hasattr(obj, "n_nodes"):  # models.gnn.Graph
        inner = validate_plan(obj.plan, coo=coo)
        return ValidationReport(kind="graph", checks=inner.checks)

    if isinstance(obj, SCVTiles):
        v = _PlanView.of(obj)
        checks += _validate_single(v, {}, require_full_coverage=False)
        checks += _check_perm_bijection([(v, {})], {})
        if coo is not None:
            checks += _validate_reassembly([v], coo, {})
        return ValidationReport(kind="tiles", checks=tuple(checks))

    if isinstance(obj, SCVPlan):
        v = _PlanView.of(obj)
        checks += _validate_single(v, {})
        checks += _check_perm_bijection([(v, {})], {})
        if coo is not None:
            checks += _validate_reassembly([v], coo, {})
        return ValidationReport(kind="plan", checks=tuple(checks))

    if isinstance(obj, SCVBucketedPlan):
        views = []
        for j, seg in enumerate(obj.segments):
            v = _PlanView.of(seg)
            views.append((v, {"segment": j}))
            # coverage-free chaining: only the FIRST segment's launch
            # zero-defines the output, so only it owes full coverage —
            # later segments chain through the accumulator and may visit
            # any subset of block-rows (contiguity still required)
            checks += _validate_single(
                v, {"segment": j}, require_full_coverage=(j == 0)
            )
        checks += _check_ladder(obj)
        checks += _check_perm_bijection(views, {})
        if coo is not None:
            checks += _validate_reassembly([v for v, _ in views], coo, {})
        return ValidationReport(kind="bucketed", checks=tuple(checks))

    if isinstance(obj, ShardedPlan):
        return _validate_sharded(obj, coo)

    raise TypeError(
        f"validate_plan: unsupported object {type(obj).__name__}; expected "
        "SCVTiles / SCVPlan / SCVBucketedPlan / ShardedPlan / Graph / "
        "BatchedGraph"
    )


def _validate_sharded(sp, coo: Optional[COOMatrix]) -> ValidationReport:
    checks: list[InvariantResult] = []
    tp = sp.decision.tile_parts
    views: list[tuple[_PlanView, dict]] = []
    covered: dict[int, set] = {}
    for j, seg in enumerate(sp.segments):
        leading = _np(seg.tile_row).shape[0]
        if leading != tp:
            checks.append(
                InvariantResult(
                    "shard-span", False, segment=j,
                    detail=(
                        f"leading device axis {leading} != decision.tile_parts "
                        f"{tp}"
                    ),
                )
            )
            continue
        for p in range(tp):
            span = SCVPlan(
                tile_row=_np(seg.tile_row)[p],
                tile_col=_np(seg.tile_col)[p],
                rows=_np(seg.rows)[p],
                cols=_np(seg.cols)[p],
                vals=_np(seg.vals)[p],
                nnz_in_tile=_np(seg.nnz_in_tile)[p],
                perm=None if seg.perm is None else _np(seg.perm)[p],
                tile=seg.tile, cap=seg.cap, shape=seg.shape, order=seg.order,
            )
            v = _PlanView.of(span)
            loc = {"segment": j, "part": p}
            views.append((v, loc))
            # a span covers only the rows its tiles visit
            checks += _validate_single(v, loc, require_full_coverage=False)
            covered.setdefault(j, set()).update(
                int(r) for r in np.unique(v.tile_row)
            )
    # the spans of the FIRST segment must jointly cover every block-row:
    # coverage dummies live only there (coverage-free chaining), and the
    # sharded launch chains each device's segments from an explicit zero
    # accumulator — so later segments may visit any subset of rows, but
    # the plan-level contract stays "segment 0 defines the whole output"
    if sp.segments:
        rows = covered.get(0, set())
        seg0 = sp.segments[0]
        nb = seg0.padded_shape[0] // seg0.tile
        missing = sorted(set(range(nb)) - rows)
        checks.append(
            InvariantResult(
                "shard-coverage",
                not missing,
                segment=0,
                offending=tuple(missing),
                detail=(
                    f"{len(missing)} block-row(s) unvisited by every span"
                    if missing
                    else ""
                ),
            )
        )
    checks += _check_perm_bijection(views, {})
    if coo is not None:
        checks += _validate_reassembly([v for v, _ in views], coo, {})
    return ValidationReport(kind="sharded", checks=tuple(checks))
