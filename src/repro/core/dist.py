"""Distributed SCV aggregation over a device mesh (paper §V-G at scale).

The Z-Morton curve is cut into equal-nnz spans (core/partition.py); each
device aggregates its span into a local PS buffer with the SCV kernel (or
the jnp reference), and boundary block-rows shared between spans are
merged with a single ``psum`` — the collective realization of the paper's
shared-memory PS merge.  The curve's locality means each span touches a
narrow band of Z rows and PS strips, so per-device traffic stays local
even though the code below keeps the dense Z replicated (graph features
are small next to LM weights; Z-sharding is a further lever, noted in
DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
try:
    from jax import shard_map
except ImportError:  # older jax keeps it under experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.partition import Partition, shard_plan, split_equal_nnz
from repro.core.scv import SCVPlan, SCVTiles, plan_from_tiles


@dataclasses.dataclass
class DistributedGraph:
    """Tiles re-packed with a leading device axis for shard_map."""

    arrays: dict  # each leaf: [n_devices, tiles_per_device, ...]
    tile: int
    n_rows_padded: int
    n_rows: int
    n_parts: int
    imbalance: float


def distribute_plan(plan: SCVPlan, n_parts: int) -> DistributedGraph:
    """Split an SCVPlan pytree into P equal-nnz tile spans for shard_map.

    The span gather happens on device (``partition.shard_plan``); only the
    span boundaries are computed host-side from the nnz histogram.
    """
    from repro.core.scv import SCVBucketedPlan

    if isinstance(plan, SCVBucketedPlan):
        raise TypeError(
            "distribute_plan takes a single-cap SCVPlan; bucketed plans "
            "shard per segment (core.partition.split_equal_nnz/shard_plan) "
            "but the shard_map wiring for them is not built yet (ROADMAP)"
        )
    part = split_equal_nnz(plan, n_parts)
    stacked = shard_plan(plan, part)
    width = part.part_tiles.shape[1]

    def dev(a):
        return a.reshape((n_parts, width) + a.shape[1:])

    arrays = {
        "tile_row": dev(stacked.tile_row),
        "tile_col": dev(stacked.tile_col),
        "rows": dev(stacked.rows),
        "cols": dev(stacked.cols),
        "vals": dev(stacked.vals),
        "nnz_in_tile": dev(stacked.nnz_in_tile),
    }
    from repro.core.partition import load_imbalance

    return DistributedGraph(
        arrays=arrays,
        tile=plan.tile,
        n_rows_padded=plan.padded_shape[0],
        n_rows=plan.shape[0],
        n_parts=n_parts,
        imbalance=load_imbalance(part),
    )


def distribute_tiles(tiles: SCVTiles, n_parts: int) -> DistributedGraph:
    """Host-object compatibility wrapper: lift to a plan pytree and shard
    that.  Coverage dummies are unnecessary here — the per-span reference
    kernel (segment_sum) zero-defines unvisited rows on its own."""
    return distribute_plan(
        plan_from_tiles(tiles, ensure_coverage=False, with_perm=False), n_parts
    )


def aggregate_distributed(
    g: DistributedGraph, z: jnp.ndarray, mesh: Mesh, axis: str = "data"
) -> jnp.ndarray:
    """out = Â Z with the tile spans sharded over ``axis`` of ``mesh``.

    Per-device partial PS buffers are psum-merged (one collective per
    aggregation — the paper's end-of-pass merge, §V-G).
    """
    from repro.kernels.scv_spmm.ref import scv_spmm_reference

    n_rows_p = g.n_rows_padded
    tile = g.tile

    def local(arr, z_full):
        out = scv_spmm_reference(
            arr["tile_row"][0], arr["tile_col"][0], arr["rows"][0],
            arr["cols"][0], arr["vals"][0], z_full,
            tile=tile, n_rows=n_rows_p, nnz_in_tile=arr["nnz_in_tile"][0],
        )
        return jax.lax.psum(out, axis)[None]

    specs_in = jax.tree.map(lambda _: P(axis), g.arrays)
    fn = shard_map(
        partial(local),
        mesh=mesh,
        in_specs=(specs_in, P()),
        out_specs=P(axis),
    )
    out = fn(g.arrays, z)
    # every shard now holds the merged PS; take shard 0's copy
    return out[0, : g.n_rows]
