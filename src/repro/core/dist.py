"""Distributed SCV aggregation over a device mesh (paper §V-G at scale).

Compatibility façade over :mod:`repro.core.exec` — the executor owns
device placement now (mesh axes, span splitting, the shard_map launch,
the single boundary-PS ``psum``).  This module keeps the historical names:

* :data:`DistributedGraph` — alias of :class:`repro.core.exec.ShardedPlan`
  (the generalization: a registered pytree of per-segment sharded spans,
  so nnz-bucketed plans distribute too).
* :func:`distribute_plan` — tile-axis placement of an ``SCVPlan`` **or**
  ``SCVBucketedPlan`` onto ``n_parts`` devices.
* :func:`distribute_tiles` — host-object wrapper (lift to a plan, place).
* :func:`aggregate_distributed` — execute a placed plan.

The Z-Morton curve is cut into equal-nnz spans (core/partition.py); each
device aggregates its span into a local PS buffer and boundary block-rows
shared between spans are merged with a single ``psum`` — the collective
realization of the paper's shared-memory PS merge.  The curve's locality
means each span touches a narrow band of Z rows and PS strips, so
per-device traffic stays local.  Z itself is replicated here (tile-axis
placement); feature-axis (Z-)sharding and 2-D placement are the
executor's other decisions — see ``core/exec.py`` / DESIGN.md §5.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.exec import (
    PlanExecutor,
    ShardedPlan,
    ShardingDecision,
    aggregate_sharded,
)
from repro.core.scv import SCVBucketedPlan, SCVPlan, SCVTiles, plan_from_tiles

#: The historical name: tiles re-packed with a leading device axis for
#: shard_map.  Now the executor's ShardedPlan (per-segment spans, so
#: bucketed plans distribute; feature/2-D placements use the same type).
DistributedGraph = ShardedPlan


def distribute_plan(
    plan: Union[SCVPlan, SCVBucketedPlan],
    n_parts: int,
    devices: Optional[tuple] = None,
) -> ShardedPlan:
    """Split a plan pytree into P equal-nnz tile spans for shard_map.

    Accepts both the single-cap ``SCVPlan`` and the nnz-bucketed
    ``SCVBucketedPlan`` (each capacity segment is cut into its own spans
    along the same Z curve; all segments of one part land on one device).
    The span gather happens on device (``partition.shard_plan``); only the
    span boundaries are computed host-side from the nnz histogram.

    Placement now happens here (the result carries its mesh), so
    ``n_parts`` devices must exist — pass ``devices=`` or force host
    devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    To inspect span balance without devices, use
    ``partition.split_equal_nnz`` + ``load_imbalance`` directly.
    """
    ex = PlanExecutor(devices=tuple(devices or jax.devices()[:n_parts]))
    # kind="tiles" even for n_parts == 1 (a degenerate 1-span placement):
    # callers get the uniform DistributedGraph interface either way
    return ex.prepare(
        plan, decision=ShardingDecision(kind="tiles", tile_parts=n_parts)
    )


def distribute_tiles(tiles: SCVTiles, n_parts: int) -> ShardedPlan:
    """Host-object compatibility wrapper: lift to a plan pytree and place
    that.  Coverage dummies are unnecessary here — the per-span reference
    kernel (segment_sum) zero-defines unvisited rows on its own."""
    return distribute_plan(
        plan_from_tiles(tiles, ensure_coverage=False, with_perm=False), n_parts
    )


def aggregate_distributed(
    g: ShardedPlan,
    z: jnp.ndarray,
    mesh=None,
    axis: str = "tiles",
    *,
    backend: str = "jnp",
) -> jnp.ndarray:
    """out = Â Z over a placed plan (one shard_map, one boundary ``psum``).

    ``mesh`` / ``axis`` are legacy parameters: the placement now lives in
    the plan itself (``g.mesh``, axes ``("tiles", "features")``).  A mesh
    argument is accepted for source compatibility but must match the
    plan's device count.
    """
    if mesh is not None and mesh.devices.size != g.mesh.devices.size:
        raise ValueError(
            f"mesh has {mesh.devices.size} devices but the plan was placed "
            f"on {g.mesh.devices.size}; re-place with distribute_plan"
        )
    del axis  # the plan's own axis names apply
    return aggregate_sharded(g, z, backend=backend)
