"""scvcheck leg 2: jit-retrace / trace-hazard analysis.

The serving story depends on a compile-time discipline: the whole GNN
forward runs under ONE ``jax.jit`` and retraces at most once per padding
bucket (``models.gnn.gnn_forward_jit``; the serving engine's node/tile
buckets exist to bound the signature set).  That discipline decays
silently — an unhashable aux object, a weak-typed scalar promoting leaf
dtypes, a float64 constant leaking in, or a new entry point skipping the
plan pytree contract each mint extra traces (or crash at dispatch) with
no test failing until someone counts.

This module turns the hand-written "retraces <= 1 per padding bucket"
test into a reusable analysis any entry point gets for free:

* :func:`check_static_aux` — walk a plan/graph pytree, flag aux data
  that is unhashable (jit dispatch would raise) or array-valued (jit
  would key on object identity and retrace every call).
* :func:`check_leaf_dtypes` — flag float64 leaves (the x64 flag is off:
  a f64 leaf means a host array skipped the f32 conversion and will
  promote everything it touches) and weak-typed leaves (two calls whose
  only difference is weak typing get two traces).
* :func:`eval_shape_hazards` — run a forward under ``jax.eval_shape``
  (no FLOPs, no compile) and flag f64 / weak-type / non-float32 outputs.
* :class:`RetraceCounter` — a jit wrapper whose Python body counts how
  often it is traced; :func:`trace_check` drives it over example graphs
  for each model kind, groups calls by their *expected* trace signature
  (leaf shapes + static aux — i.e. the padding bucket), and reports any
  bucket traced more than ``max_retraces_per_bucket`` times.

Everything reports into a machine-readable :class:`TraceReport`
mirroring ``core.validate.ValidationReport`` — `scripts/ci.sh` gates on
both through the tests in ``tests/test_tracecheck.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# report types
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceHazard:
    kind: str  # "unhashable-aux" | "array-aux" | "float64-leak"
    #           | "weak-type" | "bad-output-dtype" | "retrace-bound"
    #           | "trace-error"
    where: str  # pytree path / model name / bucket signature
    detail: str


@dataclasses.dataclass(frozen=True)
class TraceReport:
    """Outcome of :func:`trace_check` (or the standalone checkers)."""

    hazards: tuple[TraceHazard, ...]
    #: ((model, bucket_signature), traces) — one entry per distinct
    #: expected trace signature exercised.
    retraces: tuple[tuple[tuple[str, str], int], ...] = ()

    @property
    def ok(self) -> bool:
        return not self.hazards

    def of_kind(self, kind: str) -> tuple[TraceHazard, ...]:
        return tuple(h for h in self.hazards if h.kind == kind)

    def summary(self) -> str:
        lines = []
        if self.retraces:
            worst = max(n for _, n in self.retraces)
            lines.append(
                f"{len(self.retraces)} trace bucket(s), worst {worst} trace(s)"
            )
        if not self.hazards:
            lines.append("no trace hazards")
        for h in self.hazards:
            lines.append(f"  {h.kind} @ {h.where}: {h.detail}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# static aux / leaf hazards
# ---------------------------------------------------------------------------
def _is_arraylike(x: Any) -> bool:
    return isinstance(x, (np.ndarray, jax.Array))


def check_static_aux(tree: Any, where: str = "plan") -> list[TraceHazard]:
    """Flag pytree aux data jit cannot key on.

    Recurses through registered pytree nodes via their own
    ``tree_flatten`` (plans, graphs, sharded plans and the builtin
    containers all qualify).  An aux that fails ``hash()`` makes jit
    dispatch raise; an aux *containing an array* hashes by object
    identity, so every freshly-built plan would retrace even when its
    content is identical.
    """
    out: list[TraceHazard] = []

    def walk(obj: Any, path: str) -> None:
        if _is_arraylike(obj) or obj is None:
            return
        if isinstance(obj, (list, tuple)):
            for i, c in enumerate(obj):
                walk(c, f"{path}[{i}]")
            return
        if isinstance(obj, dict):
            for k, c in obj.items():
                walk(c, f"{path}[{k!r}]")
            return
        if hasattr(obj, "tree_flatten"):
            children, aux = obj.tree_flatten()
            name = type(obj).__name__
            try:
                hash(aux)
            except TypeError as e:
                out.append(
                    TraceHazard(
                        "unhashable-aux", f"{path}:{name}",
                        f"aux data is unhashable ({e}); jit dispatch will "
                        "raise on this pytree",
                    )
                )
            def scan_aux(a: Any, apath: str) -> None:
                if _is_arraylike(a):
                    out.append(
                        TraceHazard(
                            "array-aux", f"{path}:{name}{apath}",
                            "array in static aux: jit keys on object "
                            "identity, so equal plans retrace every build",
                        )
                    )
                elif isinstance(a, (list, tuple)):
                    for i, c in enumerate(a):
                        scan_aux(c, f"{apath}[{i}]")
                elif isinstance(a, dict):
                    for k, c in a.items():
                        scan_aux(c, f"{apath}[{k!r}]")
            scan_aux(aux, ".aux")
            walk(children, path)
            return
        # plain leaf (scalar, string, Mesh, decision dataclass, ...)

    walk(tree, where)
    return out


def check_leaf_dtypes(tree: Any, where: str = "plan") -> list[TraceHazard]:
    """Flag float64 and weak-typed array leaves of a pytree."""
    out: list[TraceHazard] = []
    leaves, _ = jax.tree_util.tree_flatten(tree)
    for i, leaf in enumerate(leaves):
        dt = getattr(leaf, "dtype", None)
        if dt is None:
            continue
        if dt == np.float64:
            out.append(
                TraceHazard(
                    "float64-leak", f"{where}:leaf[{i}]",
                    "float64 leaf (x64 is off — a host array skipped the "
                    "f32 conversion and will promote everything it touches)",
                )
            )
        if getattr(leaf, "weak_type", False):
            out.append(
                TraceHazard(
                    "weak-type", f"{where}:leaf[{i}]",
                    "weak-typed leaf: a strongly-typed twin of the same "
                    "call gets a second trace",
                )
            )
    return out


def eval_shape_hazards(
    fn: Callable, *args, where: str = "forward", **kwargs
) -> list[TraceHazard]:
    """Abstractly evaluate ``fn(*args)`` (``jax.eval_shape`` — no FLOPs,
    no compile) and flag f64 / weak-type / non-float outputs.  Errors
    during abstract evaluation are themselves reported as hazards — a
    forward that cannot even trace is the worst hazard of all."""
    out: list[TraceHazard] = []
    try:
        shapes = jax.eval_shape(fn, *args, **kwargs)
    except Exception as e:  # noqa: BLE001 — any trace failure is the finding
        return [
            TraceHazard(
                "trace-error", where,
                f"{type(e).__name__}: {e}",
            )
        ]
    for i, s in enumerate(jax.tree_util.tree_leaves(shapes)):
        dt = getattr(s, "dtype", None)
        if dt == np.float64:
            out.append(
                TraceHazard(
                    "float64-leak", f"{where}:out[{i}]",
                    "forward output is float64",
                )
            )
        if getattr(s, "weak_type", False):
            out.append(
                TraceHazard(
                    "weak-type", f"{where}:out[{i}]",
                    "forward output is weak-typed",
                )
            )
        if dt is not None and not np.issubdtype(dt, np.floating):
            out.append(
                TraceHazard(
                    "bad-output-dtype", f"{where}:out[{i}]",
                    f"forward output dtype {dt} is not floating",
                )
            )
    return out


# ---------------------------------------------------------------------------
# retrace counting
# ---------------------------------------------------------------------------
class RetraceCounter:
    """``jax.jit`` wrapper whose Python body counts its own traces.

    The wrapped body runs exactly once per distinct jit signature (leaf
    shapes + dtypes + static aux), so ``counter.traces`` is the number of
    XLA programs minted — the quantity the padding buckets bound.
    """

    def __init__(self, fn: Callable, static_argnames=()):
        self.traces = 0

        @functools.wraps(fn)
        def counted(*a, **k):
            self.traces += 1
            return fn(*a, **k)

        self.jitted = jax.jit(counted, static_argnames=static_argnames)

    def __call__(self, *a, **k):
        return self.jitted(*a, **k)


def bucket_signature(*trees: Any) -> str:
    """The *expected* trace signature of a call: leaf shapes + dtypes +
    the treedef (which embeds every static aux repr).  Two calls with
    equal signatures must share one trace — when they don't, something
    (weak types, identity-keyed aux) is minting hidden retraces."""
    leaves, treedef = jax.tree_util.tree_flatten(trees)
    shapes = ";".join(
        f"{getattr(l, 'shape', ())}:{getattr(l, 'dtype', type(l).__name__)}"
        for l in leaves
    )
    return f"{treedef}|{shapes}"


def trace_check(
    models: dict[str, tuple],
    examples: dict[str, list],
    forward: Optional[Callable] = None,
    max_retraces_per_bucket: int = 1,
) -> TraceReport:
    """Run the full trace-hazard analysis over example workloads.

    ``models`` maps a name to ``(params, cfg)`` (the serving engine's
    registry shape); ``examples`` maps the same names to a list of
    ``(graph, x)`` pairs (``models.gnn.Graph`` + feature array).
    ``forward`` defaults to ``models.gnn.gnn_forward``.

    For each model: static-aux and leaf-dtype hazards on every example
    graph, an ``eval_shape`` pass on the first example, then a counted
    jit driven over all examples with calls grouped by
    :func:`bucket_signature`.  Any bucket traced more than
    ``max_retraces_per_bucket`` times becomes a ``retrace-bound`` hazard.
    """
    if forward is None:
        from repro.models.gnn import gnn_forward as forward  # type: ignore

    hazards: list[TraceHazard] = []
    retraces: list[tuple[tuple[str, str], int]] = []
    for name, (params, cfg) in models.items():
        exs = examples.get(name, [])
        if not exs:
            continue
        for i, (g, x) in enumerate(exs):
            hazards += check_static_aux(g, where=f"{name}[{i}]")
            hazards += check_leaf_dtypes((g, x), where=f"{name}[{i}]")
        g0, x0 = exs[0]
        hazards += eval_shape_hazards(
            lambda p, g_, x_: forward(p, cfg, g_, x_),
            params, g0, x0, where=f"{name}:eval_shape",
        )

        counter = RetraceCounter(forward, static_argnames=("cfg",))
        per_bucket: dict[str, int] = {}
        for i, (g, x) in enumerate(exs):
            sig = bucket_signature(g, x)
            before = counter.traces
            try:
                counter(params, cfg, g, x)
            except Exception as e:  # noqa: BLE001 — dispatch failure is the finding
                hazards.append(
                    TraceHazard(
                        "trace-error", f"{name}[{i}]",
                        f"{type(e).__name__}: {e}",
                    )
                )
                continue
            per_bucket[sig] = per_bucket.get(sig, 0) + (counter.traces - before)
        for sig, n in per_bucket.items():
            retraces.append(((name, sig), n))
            if n > max_retraces_per_bucket:
                hazards.append(
                    TraceHazard(
                        "retrace-bound", f"{name}:{sig[:80]}...",
                        f"{n} traces for one padding bucket "
                        f"(bound is {max_retraces_per_bucket}) — equal "
                        "signatures are not sharing a trace",
                    )
                )
    return TraceReport(hazards=tuple(hazards), retraces=tuple(retraces))
