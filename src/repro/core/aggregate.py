"""Aggregation (Eq. (3): H' = Â · Z) with pluggable sparse backends.

This is the framework's first-class entry point for the paper's technique.
``aggregate(A, Z)`` dispatches on the format object:

* ``np.ndarray`` / ``jnp.ndarray``  — dense matmul (oracle / tiny graphs)
* ``CSRMatrix``                     — gather + segment_sum (row-major)
* ``CSCMatrix``                     — gather + scatter-add (column-major)
* ``BCSRMatrix``                    — dense-block einsum
* ``SCVMatrix``                     — logical SCV, executed via tiles
* ``SCVTiles``                      — TPU path: Pallas kernel (or the jnp
                                      reference on CPU / under tests)
* ``SCVPlan``                       — same TPU path, but the plan is a
                                      registered pytree: array leaves +
                                      static aux, so the call (and any
                                      caller up to the whole GNN forward)
                                      sits under a single ``jax.jit``
* ``SCVBucketedPlan``               — nnz-bucketed plan: one kernel launch
                                      per capacity segment, partial outputs
                                      summed (no global-max cap padding)

All backends are numerically equivalent (validated by property tests).
``aggregate_scv_plan`` is the jit-native entry point; the legacy
``aggregate_scv_tiles`` (host object + loose arrays dict) remains for
benchmarks and one-shot experiments and routes through the same kernels.
"""
from __future__ import annotations

import functools
from typing import Any, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSRMatrix, COOMatrix, CSCMatrix, CSRMatrix
from repro.core.scv import (
    SCVBucketedPlan,
    SCVMatrix,
    SCVPlan,
    SCVTiles,
    plan_from_tiles,
    scv_to_tiles,
)


# ---------------------------------------------------------------------------
# device-array bundles (jit-friendly)
# ---------------------------------------------------------------------------
def csr_device_arrays(a: CSRMatrix) -> dict[str, jnp.ndarray]:
    rows = np.repeat(np.arange(a.shape[0], dtype=np.int32), np.diff(a.row_ptr))
    return {
        "rows": jnp.asarray(rows),
        "cols": jnp.asarray(a.col_id),
        "vals": jnp.asarray(a.vals),
    }


def scv_device_arrays(t: SCVTiles, ensure_coverage: bool = True) -> dict[str, jnp.ndarray]:
    """Device bundle; with ``ensure_coverage`` a zero-nnz dummy tile is
    appended for every empty PS block-row so the Pallas kernel defines the
    whole output.  Thin dict view over :func:`plan_from_tiles` — the one
    code path for coverage insertion and perm padding."""
    p = plan_from_tiles(t, ensure_coverage=ensure_coverage, with_perm=False)
    return {
        "tile_row": p.tile_row,
        "tile_col": p.tile_col,
        "rows": p.rows,
        "cols": p.cols,
        "vals": p.vals,
        "nnz_in_tile": p.nnz_in_tile,
    }


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_rows",))
def aggregate_coo_segsum(
    rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray, z: jnp.ndarray, n_rows: int
) -> jnp.ndarray:
    """Row-major (CSR-style) aggregation: gather Z rows, weighted
    segment-sum into output rows.  XLA's bread-and-butter SpMM."""
    gathered = z[cols] * vals[:, None].astype(z.dtype)
    return jax.ops.segment_sum(gathered, rows, num_segments=n_rows)


@functools.partial(jax.jit, static_argnames=("n_rows",))
def aggregate_coo_scatter(
    rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray, z: jnp.ndarray, n_rows: int
) -> jnp.ndarray:
    """Column-major (CSC-style) aggregation: scatter-add partial sums."""
    out = jnp.zeros((n_rows, z.shape[1]), z.dtype)
    return out.at[rows].add(z[cols] * vals[:, None].astype(z.dtype))


def aggregate_dense(a: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    return jnp.asarray(a, z.dtype) @ z


def aggregate_bcsr(a: BCSRMatrix, z: jnp.ndarray) -> jnp.ndarray:
    """Dense-block path: every stored block does a full B x B @ B x F —
    BCSR's storage liability becomes a compute liability (paper §II-B.3)."""
    B = a.block_size
    m, n = a.shape
    mp = -(-m // B) * B
    np_ = -(-n // B) * B
    zp = jnp.zeros((np_, z.shape[1]), z.dtype).at[: z.shape[0]].set(z)
    ztiles = zp.reshape(np_ // B, B, z.shape[1])
    blk_rows = np.repeat(
        np.arange(len(a.row_ptr) - 1, dtype=np.int32), np.diff(a.row_ptr)
    )
    prod = jnp.einsum(
        "kij,kjf->kif", jnp.asarray(a.blocks, z.dtype), ztiles[jnp.asarray(a.col_id)]
    )
    out = jax.ops.segment_sum(prod, jnp.asarray(blk_rows), num_segments=mp // B)
    return out.reshape(mp, z.shape[1])[:m]


def aggregate_scv_tiles(
    t: SCVTiles,
    z: jnp.ndarray,
    *,
    backend: str = "auto",
    feature_block: int = 128,
    arrays: dict[str, jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """SCV aggregation over the device tile layout.

    backend:
      * "jnp"     — vectorized jnp reference (runs anywhere, used as oracle)
      * "pallas"  — the TPU kernel (interpret=True on CPU)
      * "auto"    — pallas on TPU, jnp elsewhere
    """
    from repro.kernels.scv_spmm import ops as scv_ops  # local import: keep core light
    from repro.kernels.scv_spmm import ref as scv_ref

    arr = arrays if arrays is not None else scv_device_arrays(t)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        out = scv_ref.scv_spmm_reference(
            arr["tile_row"], arr["tile_col"], arr["rows"], arr["cols"], arr["vals"],
            z, tile=t.tile, n_rows=t.padded_shape[0],
            nnz_in_tile=arr.get("nnz_in_tile"),
        )
    elif backend in ("pallas", "pallas_interpret"):
        out = scv_ops.scv_spmm(
            arr["tile_row"], arr["tile_col"], arr["rows"], arr["cols"], arr["vals"],
            z, tile=t.tile, n_rows=t.padded_shape[0],
            nnz_in_tile=arr.get("nnz_in_tile"),
            feature_block=feature_block,
            interpret=(backend == "pallas_interpret" or jax.default_backend() != "tpu"),
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out[: t.shape[0]]


def aggregate_scv_plan(
    p: "Union[SCVPlan, SCVBucketedPlan, ShardedPlan]",
    z: jnp.ndarray,
    *,
    backend: str = "auto",
    feature_block: int = 128,
) -> jnp.ndarray:
    """SCV aggregation over a plan pytree — the jit-native path.

    Accepts the single-cap :class:`SCVPlan`, the nnz-bucketed
    :class:`SCVBucketedPlan` (one kernel launch per capacity segment,
    partial outputs summed), and the mesh-placed
    :class:`repro.core.exec.ShardedPlan` (the executor's shard_map
    launch — one boundary ``psum``, feature slabs collective-free).
    Every array the computation reads is a pytree leaf of ``p`` and every
    piece of static configuration (tile, padded row count, bucket ladder,
    placement mesh + decision, backend selection) comes from the plan's
    aux data, so this function — and any caller threading plans around,
    up to ``models.gnn.gnn_forward`` — can sit under one outer
    ``jax.jit`` with zero host round-trips per layer.
    """
    from repro.kernels.scv_spmm import ops as scv_ops  # local import: keep core light
    from repro.kernels.scv_spmm import ref as scv_ref

    from repro.core.exec import ShardedPlan, aggregate_sharded

    if isinstance(p, ShardedPlan):
        return aggregate_sharded(
            p, z, backend=backend, feature_block=feature_block
        )
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        out = scv_ref.scv_spmm_reference_plan(p, z)
    elif backend in ("pallas", "pallas_interpret"):
        out = scv_ops.scv_spmm_plan(
            p, z, feature_block=feature_block,
            interpret=(backend == "pallas_interpret" or jax.default_backend() != "tpu"),
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out[: p.shape[0]]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
Format = Union[np.ndarray, jnp.ndarray, COOMatrix, CSRMatrix, CSCMatrix, BCSRMatrix, SCVMatrix, SCVTiles, SCVPlan, SCVBucketedPlan, "ShardedPlan"]


def aggregate(a: Format, z: jnp.ndarray, **kw: Any) -> jnp.ndarray:
    """H' = Â Z for any supported adjacency format."""
    n_rows = a.shape[0]
    if isinstance(a, (np.ndarray, jnp.ndarray)):
        return aggregate_dense(a, z)
    if isinstance(a, COOMatrix):
        return aggregate_coo_segsum(
            jnp.asarray(a.rows), jnp.asarray(a.cols), jnp.asarray(a.vals), z, n_rows
        )
    if isinstance(a, CSRMatrix):
        d = csr_device_arrays(a)
        return aggregate_coo_segsum(d["rows"], d["cols"], d["vals"], z, n_rows)
    if isinstance(a, CSCMatrix):
        cols = np.repeat(np.arange(a.shape[1], dtype=np.int32), np.diff(a.col_ptr))
        return aggregate_coo_scatter(
            jnp.asarray(a.row_id), jnp.asarray(cols), jnp.asarray(a.vals), z, n_rows
        )
    if isinstance(a, BCSRMatrix):
        return aggregate_bcsr(a, z)
    if isinstance(a, SCVMatrix):
        return aggregate_scv_tiles(scv_to_tiles(a), z, **kw)
    if isinstance(a, SCVTiles):
        return aggregate_scv_tiles(a, z, **kw)
    from repro.core.exec import ShardedPlan

    if isinstance(a, (SCVPlan, SCVBucketedPlan, ShardedPlan)):
        return aggregate_scv_plan(a, z, **kw)
    raise TypeError(f"unsupported adjacency format: {type(a)}")


def aggregate_hybrid(
    t: SCVTiles, z: jnp.ndarray, *, backend: str = "jnp", **kw
) -> jnp.ndarray:
    """Beyond-paper hybrid: MXU-densified tiles + SCV gather tiles
    (DESIGN.md §2; measured in benchmarks/kernel_roofline.py)."""
    from repro.core.scv import split_hybrid

    sparse, dense = split_hybrid(t)
    out = aggregate_scv_tiles(sparse, z, backend=backend, **kw)
    if dense.n_tiles:
        T = dense.tile
        np_cols = -(-t.shape[1] // T) * T
        zp = jnp.zeros((np_cols, z.shape[1]), z.dtype).at[: z.shape[0]].set(z)
        ztiles = zp.reshape(np_cols // T, T, z.shape[1])
        prod = jnp.einsum(
            "kij,kjf->kif",
            jnp.asarray(dense.blocks, z.dtype),
            ztiles[jnp.asarray(dense.tile_col)],
        ).astype(jnp.float32)
        upd = jax.ops.segment_sum(
            prod, jnp.asarray(dense.tile_row), num_segments=t.padded_shape[0] // T
        )
        out = out + upd.reshape(-1, z.shape[1])[: out.shape[0]]
    return out
