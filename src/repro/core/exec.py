"""Plan executor: the one place where SCV plans meet devices (DESIGN.md §5).

Every consumer of an aggregation plan — ``core.aggregate.aggregate``, the
jitted GNN forward (``models.gnn.gnn_forward_jit``) and the serving engine
(``serve.graph_engine``) — dispatches through this module.  The paper's
scalability story (§V-G: equal-nnz Z-Morton spans keep per-device traffic
local; shared PS block-rows merge cheaply) and the feature-parallel axis
the Computing-GNNs taxonomy pairs with it compose here as **one mesh** with
two named axes:

* ``"tiles"``   — graph-parallel: the Z-ordered tile sequence is cut into
  equal-nnz spans (``core.partition.split_equal_nnz``), one span per mesh
  row; boundary PS block-rows are merged with a single ``psum``.
* ``"features"`` — feature-parallel (Z-sharding): each device holds the
  feature slab ``Z[:, f0:f1]``; the kernel's feature-block grid axis maps
  onto this mesh axis (disjoint output columns — no collective at all).

The two axes multiply: a ``(tp, fp)`` mesh runs ``tp * fp`` devices with
one ``psum`` over ``"tiles"`` only.

Three pieces:

* :class:`ShardingDecision` — the placement choice (kind + axis sizes),
  hashable, part of the pytree aux (and therefore of jit trace signatures
  and serving cache keys).
* :class:`ShardedPlan` — a registered pytree holding **per-segment**
  sharded spans: each ``SCVPlan`` segment's leaves carry a leading
  ``tile_parts`` device axis.  Bucketed plans shard segment-by-segment;
  the single ``shard_map`` launch below runs one kernel launch per
  capacity bucket on each device and merges all segments' boundary PS
  rows with **one** ``psum`` (not one per segment).
* :class:`PlanExecutor` — owns the device set and the decision rule
  (``decide_sharding``: tile-span, feature, or 2-D sharding from plan nnz,
  feature width and device count), prepares plans (host-side span split +
  on-device gather), and executes them (``aggregate``).

A prepared :class:`ShardedPlan` is itself just another plan format: it
carries its mesh + decision as static aux, so ``aggregate_scv_plan``
dispatches on it, ``reweighted`` re-gathers per-edge values through the
sharded perm leaves (GAT), and the serving engine caches it — a hot
oversized composite reuses its sharded layout with zero placement work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

# jax >= 0.6 re-homes shard_map to jax.*; the installed 0.4.x only has the
# experimental location, so the first branch is forward-compat, not live.
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.partition import nnz_imbalance, shard_plan, split_equal_nnz
from repro.core.scv import SCVBucketedPlan, SCVPlan

#: Mesh axis names — the executor contract (DESIGN.md §5).
TILE_AXIS = "tiles"
FEATURE_AXIS = "features"

#: Decision-rule floors: sharding an axis must leave each device at least
#: this much work, otherwise collective + padding overhead dominates.
MIN_NNZ_PER_PART = 4096
#: One full kernel feature block (TPU lane width x f32 packing): a slab
#: narrower than 128 columns is padded back up to 128 inside ``scv_spmm``,
#: so splitting below this floor multiplies total work instead of
#: dividing it.
MIN_FEATURES_PER_PART = 128
#: Fallback output-row estimate when the caller only knows nnz: the SCV
#: target regime is sparse power-law graphs with average degree around 8
#: (paper §V datasets), so ``n_rows ~ nnz / 8``.  Pass ``n_rows``
#: explicitly for an exact byte model.
EST_AVG_DEGREE = 8


# ---------------------------------------------------------------------------
# the sharding decision
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardingDecision:
    """How a plan meets the mesh.  Hashable: rides in pytree aux (jit trace
    signatures) and in serving cache-key salts (``signature``)."""

    kind: str  # "replicated" | "tiles" | "features" | "2d"
    tile_parts: int = 1
    feature_parts: int = 1

    def __post_init__(self):
        kinds = ("replicated", "tiles", "features", "2d")
        if self.kind not in kinds:
            raise ValueError(f"kind must be one of {kinds}, got {self.kind!r}")
        if self.tile_parts < 1 or self.feature_parts < 1:
            raise ValueError("axis sizes must be >= 1")
        tp, fp = self.tile_parts, self.feature_parts
        ok = {
            "replicated": (tp, fp) == (1, 1),
            "tiles": fp == 1,  # tp == 1 allowed: degenerate 1-span placement
            "features": tp == 1,
            "2d": tp > 1 and fp > 1,
        }[self.kind]
        if not ok:
            raise ValueError(
                f"kind {self.kind!r} inconsistent with axes "
                f"(tile_parts={tp}, feature_parts={fp})"
            )

    @property
    def n_devices(self) -> int:
        return self.tile_parts * self.feature_parts

    @property
    def signature(self) -> str:
        """Stable string for cache-key salts (serving)."""
        return f"{self.kind}:t{self.tile_parts}f{self.feature_parts}"


def placement_bytes(
    nnz: int,
    n_features: int,
    tile_parts: int,
    feature_parts: int,
    *,
    n_rows: Optional[int] = None,
    machine=None,
    n_slots: Optional[int] = None,
) -> dict:
    """Per-device byte model of a ``(tile_parts, feature_parts)`` placement.

    The model charges each device for what it must hold (VMEM residency)
    and move (HBM traffic); ``simul.machine.MachineConfig`` supplies the
    element width and DRAM bandwidth — one shared set of hardware
    constants between the cycle simulator and the executor.

    Resident bytes (what a device's slabs occupy):

    * ``plan``   — the span's COO triples (rows, cols, vals): the tile
      axis splits nnz, so ``3 * nnz * B / tp``; replicated across the
      feature axis.
    * ``z_slab`` — the feature slab ``Z[:, f0:f1]``: split by the feature
      axis, replicated across the tile axis.
    * ``out``    — the output accumulator slab, same split as ``z_slab``
      (every tile span writes the full row range of its feature slab).

    Traffic bytes (what the aggregation streams):

    * ``z_gather``   — the kernel reads one Z row per nonzero entry:
      ``(nnz / tp) * (F / fp) * B``.  This is the dominant sparse term
      and the one the tile axis actually divides; the slab-resident view
      alone would make tile sharding look free-of-benefit.
    * ``collective`` — ring-allreduce traffic of the boundary ``psum``
      over the tile axis: ``2 * (tp - 1) / tp`` of the out slab; zero at
      ``tp == 1`` (the executor skips the psum entirely).

    Returns a dict with those components plus ``resident`` (plan +
    z_slab + out — the VMEM budget number), ``total`` (plan + z_gather +
    out + collective — the cost :func:`decide_sharding` minimizes) and
    ``est_seconds`` (total bits over ``dram_gbps``).  ``n_rows`` defaults
    to ``nnz // EST_AVG_DEGREE`` when the caller only knows nnz.

    ``n_slots`` — the plan's *launched* capacity slots (padding and
    coverage dummies included; ``repro.tune.plan_launched_slots`` of a
    built plan, or ``core.scv.launched_slots`` from a histogram).  When
    given, the plan triple is priced at slots instead of logical nnz —
    the shipped arrays really are slot-shaped, and BENCH_dist measured
    the nnz-priced model 1.11-3.79x optimistic against placed plans.
    This is the same pricing the autotuner's stage-1 model uses
    (``repro.tune.cost``), so placement and plan tuning charge padding
    identically.  Omitted, the legacy nnz pricing applies (callers that
    predate any plan, e.g. the serving admission estimate).
    """
    if machine is None:
        from repro.simul.machine import MachineConfig

        machine = MachineConfig()
    b = machine.bytes_per_elem
    rows = max(int(n_rows) if n_rows is not None else nnz // EST_AVG_DEGREE, 1)
    tp, fp = tile_parts, feature_parts
    if n_slots is None:
        plan = 3 * nnz * b / tp
    else:
        from repro.tune.cost import plan_slot_bytes

        plan = plan_slot_bytes(n_slots, machine) / tp
    z_slab = rows * n_features * b / fp
    out = rows * n_features * b / fp
    z_gather = (nnz / tp) * (n_features / fp) * b
    collective = 2 * (tp - 1) / tp * out
    total = plan + z_gather + out + collective
    return {
        "plan": plan,
        "z_slab": z_slab,
        "out": out,
        "z_gather": z_gather,
        "collective": collective,
        "resident": plan + z_slab + out,
        "total": total,
        "est_seconds": total * 8 / (machine.dram_gbps * 1e9),
    }


def decide_sharding(
    nnz: int,
    n_features: int,
    n_devices: int,
    *,
    n_rows: Optional[int] = None,
    machine=None,
    min_nnz_per_part: int = MIN_NNZ_PER_PART,
    min_features_per_part: int = MIN_FEATURES_PER_PART,
    n_slots: Optional[int] = None,
) -> ShardingDecision:
    """Pick tile-span, feature, or 2-D sharding by byte cost (DESIGN.md §5).

    Candidate meshes are every power-of-two ``(tp, fp)`` with
    ``tp * fp <= n_devices`` that respects the per-device work floors
    (``min_nnz_per_part`` nonzeros per span, ``min_features_per_part``
    columns per slab — splitting below either floor multiplies padded
    work instead of dividing real work).  Each candidate is priced with
    :func:`placement_bytes` and the cheapest per-device byte total wins.

    The model encodes the real trade-off the old grow-tiles-first rule
    missed: the tile axis divides the O(nnz) gather traffic but adds
    ring-allreduce traffic proportional to the out slab, whereas the
    feature axis divides the per-entry width and the slabs collective-
    free.  The optimum balances the two instead of greedily maxing one
    axis — e.g. at nnz=1e6, F=256 on 8 devices the old rule picked
    t8f1 while t4f2 moves ~45% fewer bytes per device.  Ties break
    toward more tile spans (graph parallelism is the paper's lever),
    then toward fewer devices (a half-idle mesh beats all-devices-
    underfed).
    """
    if n_devices < 1:
        raise ValueError("n_devices must be >= 1")
    tps = [1]
    while tps[-1] * 2 <= n_devices and nnz // (tps[-1] * 2) >= min_nnz_per_part:
        tps.append(tps[-1] * 2)
    fps = [1]
    while (
        fps[-1] * 2 <= n_devices
        and n_features // (fps[-1] * 2) >= min_features_per_part
    ):
        fps.append(fps[-1] * 2)
    best = None
    for tp in tps:
        for fp in fps:
            if tp * fp > n_devices:
                continue
            cost = placement_bytes(
                nnz, n_features, tp, fp,
                n_rows=n_rows, machine=machine, n_slots=n_slots,
            )["total"]
            key = (cost, -tp, tp * fp)
            if best is None or key < best[0]:
                best = (key, tp, fp)
    _, tp, fp = best
    kind = (
        "replicated" if (tp, fp) == (1, 1)
        else "tiles" if fp == 1
        else "features" if tp == 1
        else "2d"
    )
    return ShardingDecision(kind=kind, tile_parts=tp, feature_parts=fp)


# ---------------------------------------------------------------------------
# the sharded plan pytree
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """A plan placed on a mesh: per-segment equal-nnz spans, stacked.

    Leaves: each segment is an :class:`SCVPlan` whose array leaves carry a
    leading ``decision.tile_parts`` device axis (``[tp, span_width, ...]``;
    span-padded slots are zero-nnz tiles, perm slots ``-1``).  Static aux:
    the mesh and the decision — jit specializes on placement exactly like
    it specializes on a plan's ``cap``.

    The generalization of the old ``core.dist.DistributedGraph`` (a plain
    dict of single-cap arrays): bucketed plans shard per segment, and the
    feature axis exists.  ``core.dist`` keeps the old names as aliases.
    """

    segments: tuple[SCVPlan, ...]
    mesh: Mesh
    decision: ShardingDecision

    def tree_flatten(self):
        return (tuple(self.segments),), (self.mesh, self.decision)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children[0]), *aux)

    # -- aux delegated to the segments (SCVPlan aux survives sharding) -----
    @property
    def tile(self) -> int:
        return self.segments[0].tile

    @property
    def shape(self) -> tuple[int, int]:
        return self.segments[0].shape

    @property
    def order(self) -> str:
        return self.segments[0].order

    @property
    def caps(self) -> tuple[int, ...]:
        return tuple(s.cap for s in self.segments)

    @property
    def padded_shape(self) -> tuple[int, int]:
        return self.segments[0].padded_shape

    @property
    def n_parts(self) -> int:
        return self.decision.tile_parts

    @property
    def perm(self):
        perms = [s.perm for s in self.segments]
        return None if any(p is None for p in perms) else perms

    def reweighted(self, edge_vals) -> "ShardedPlan":
        """Per-edge re-weighting (GAT) through the sharded perm leaves:
        each span's perm still indexes the *global* edge array (sharding
        gathers tiles, not entries), so the re-gather is unchanged —
        span-padding slots carry ``perm == -1`` and pull the appended
        zero."""
        return dataclasses.replace(
            self, segments=tuple(s.reweighted(edge_vals) for s in self.segments)
        )

    # -- host-side introspection (not part of the trace signature) ---------
    def _segment_nnz_per_part(self, seg: SCVPlan) -> np.ndarray:
        tp = self.decision.tile_parts
        return np.asarray(seg.nnz_in_tile).astype(np.int64).reshape(tp, -1).sum(1)

    def nnz_per_part(self) -> np.ndarray:
        """int64[tile_parts] — nonzeros per device span, summed across
        capacity segments (all segments of one part run on one device)."""
        return sum(
            (self._segment_nnz_per_part(s) for s in self.segments),
            np.zeros(self.decision.tile_parts, np.int64),
        )

    @property
    def imbalance(self) -> float:
        """max/mean nnz over the tile spans (1.0 = perfect balance)."""
        return nnz_imbalance(self.nnz_per_part())

    @property
    def imbalance_per_segment(self) -> tuple[float, ...]:
        """One max/mean ratio per capacity segment (matches
        ``partition.load_imbalance(part, per_segment=True)``)."""
        return tuple(
            nnz_imbalance(self._segment_nnz_per_part(s)) for s in self.segments
        )


# ---------------------------------------------------------------------------
# the sharded aggregation launch
# ---------------------------------------------------------------------------
def _segment_local(seg: SCVPlan) -> SCVPlan:
    """Drop the leading device axis of a span-stacked segment (inside the
    shard_map body each leaf arrives as ``[1, width, ...]``)."""
    return jax.tree.map(lambda a: a[0], seg)


def aggregate_sharded(
    sp: ShardedPlan,
    z: jnp.ndarray,
    *,
    backend: str = "auto",
    feature_block: int = 128,
) -> jnp.ndarray:
    """out = Â Z over a placed plan: ONE ``shard_map`` launch.

    Inside the body each device chains one kernel launch per capacity
    bucket over its tile span through a zero-initialized accumulator
    (``scv_spmm_plan(init="zeros")``): spans carry no per-span coverage
    dummies, and the aliased-accumulator chain leaves unvisited strips at
    their accumulator value — zero — so no post-launch masking and no
    partial-output sum tree.  Boundary PS block-rows merge with a
    **single** ``psum`` over the ``"tiles"`` axis — across all segments,
    not one collective per segment, and skipped entirely when the tile
    axis has one part (pure feature sharding writes disjoint output
    columns and needs no collective at all).  Z is padded to the slab
    grid **once**, outside the mesh body (rows to the tile grid, columns
    to the slab multiple) — per-device per-segment re-padding was two
    full slab copies per call.

    Returns the full (unpadded-row) ``[n_rows, F]`` output, matching
    ``aggregate_scv_plan``.
    """
    from repro.kernels.scv_spmm import ops as scv_ops
    from repro.kernels.scv_spmm import ref as scv_ref

    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    fp = sp.decision.feature_parts
    tp = sp.decision.tile_parts
    n, f = z.shape
    f_pad = -(-f // fp) * fp  # feature slabs must tile the mesh axis
    n_pad = sp.padded_shape[1]  # pad rows once, not per device per segment
    if (n_pad, f_pad) != (n, f):
        z = jnp.zeros((n_pad, f_pad), z.dtype).at[:n, :f].set(z)

    def local(sp_local: ShardedPlan, z_local: jnp.ndarray) -> jnp.ndarray:
        if backend == "jnp":
            out = None
            for seg in sp_local.segments:  # one launch per bucket
                part = scv_ref.scv_spmm_reference_plan(
                    _segment_local(seg), z_local
                )
                out = part if out is None else out + part
        else:
            # chain the per-bucket launches through one accumulator,
            # starting from explicit zeros: a span covers only the rows
            # its tiles visit, and the chain passes unvisited strips
            # through — zero — so the output is defined everywhere
            # without per-span coverage dummies or masking.
            segs = tuple(_segment_local(s) for s in sp_local.segments)
            local_plan = segs[0] if len(segs) == 1 else SCVBucketedPlan(segs)
            out = scv_ops.scv_spmm_plan(
                local_plan, z_local, feature_block=feature_block,
                interpret=(backend == "pallas_interpret"
                           or jax.default_backend() != "tpu"),
                init="zeros",
            )
        if tp == 1:
            return out  # no boundary rows to merge — skip the collective
        return jax.lax.psum(out, TILE_AXIS)  # the §V-G PS merge — once

    specs = jax.tree.map(lambda _: P(TILE_AXIS), sp)
    fn = shard_map(
        local,
        mesh=sp.mesh,
        in_specs=(specs, P(None, FEATURE_AXIS)),
        out_specs=P(None, FEATURE_AXIS),  # psum leaves "tiles" replicated
        # pallas_call has no replication rule (jax 0.4.x): skip the static
        # check there — the psum above makes the output replicated either
        # way; the jnp path keeps the check as a safety net (not at
        # tp == 1, where the psum is skipped and the axis is trivial)
        check_rep=(backend == "jnp" and tp > 1),
    )
    return fn(sp, z)[: sp.shape[0], :f]


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanExecutor:
    """Owns device placement for SCV plans.

    ``devices`` is the flat device pool (defaults to ``jax.devices()`` at
    construction); ``decide`` picks an axis factorization of (a prefix of)
    it, ``prepare`` places a plan, ``aggregate`` executes any plan kind.
    Frozen + hashable so an executor can ride in static argument positions.
    """

    devices: tuple = ()
    min_nnz_per_part: int = MIN_NNZ_PER_PART
    min_features_per_part: int = MIN_FEATURES_PER_PART
    backend: str = "auto"

    def __post_init__(self):
        if not self.devices:
            object.__setattr__(self, "devices", tuple(jax.devices()))

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def mesh_for(self, decision: ShardingDecision) -> Mesh:
        """(tile_parts, feature_parts) mesh over a prefix of the pool."""
        d = decision.n_devices
        if d > self.n_devices:
            raise ValueError(
                f"decision needs {d} devices, executor has {self.n_devices}"
            )
        grid = np.array(self.devices[:d]).reshape(
            decision.tile_parts, decision.feature_parts
        )
        return Mesh(grid, (TILE_AXIS, FEATURE_AXIS))

    def decide_for(
        self, nnz: int, n_features: int, n_rows: Optional[int] = None,
        n_slots: Optional[int] = None,
    ) -> ShardingDecision:
        """Decision from known workload numbers (the serving engine sums
        member adjacency nnz before any plan exists); ``n_slots`` prices
        the plan triple at launched capacity slots when the caller knows
        the plan layout."""
        return decide_sharding(
            nnz, n_features, self.n_devices,
            n_rows=n_rows,
            min_nnz_per_part=self.min_nnz_per_part,
            min_features_per_part=self.min_features_per_part,
            n_slots=n_slots,
        )

    def decide(
        self, plan: Union[SCVPlan, SCVBucketedPlan], n_features: int
    ) -> ShardingDecision:
        """Decision from a plan's (host-read) nnz + a feature width.

        With a built plan in hand the launched slot count is exact (static
        aux only), so the byte model prices the real padded arrays — the
        autotuner's pricing — rather than the logical-nnz lower bound.
        """
        from repro.tune.cost import plan_launched_slots

        segs = getattr(plan, "segments", (plan,))
        nnz = int(sum(np.asarray(s.nnz_in_tile, np.int64).sum() for s in segs))
        return self.decide_for(
            nnz, n_features, n_rows=plan.shape[0],
            n_slots=plan_launched_slots(plan),
        )

    def prepare(
        self,
        plan: Union[SCVPlan, SCVBucketedPlan],
        n_features: Optional[int] = None,
        decision: Optional[ShardingDecision] = None,
    ) -> Union[SCVPlan, SCVBucketedPlan, ShardedPlan]:
        """Place a plan: equal-nnz span split (host reads the nnz
        histogram once) + on-device span gather, per capacity segment.

        A ``replicated`` decision returns the plan unchanged — single-
        device execution needs no placement.  Pass either ``decision``
        (explicit) or ``n_features`` (let ``decide`` pick).
        """
        if decision is None:
            if n_features is None:
                raise ValueError("prepare needs a decision or n_features")
            decision = self.decide(plan, n_features)
        if decision.kind == "replicated":
            return plan
        mesh = self.mesh_for(decision)
        tp = decision.tile_parts
        part = split_equal_nnz(plan, tp)
        stacked = shard_plan(plan, part)
        segs = getattr(stacked, "segments", (stacked,))
        parts = part if isinstance(part, tuple) else (part,)

        def dev(seg: SCVPlan, p) -> SCVPlan:
            width = p.part_tiles.shape[1]
            seg = jax.tree.map(
                lambda a: a.reshape((tp, width) + a.shape[1:]), seg
            )
            # Span-padding tiles (shard_plan fills coordinates with 0) must
            # repeat the span's LAST real tile coordinates instead: the
            # Pallas kernel zero-initializes a PS strip whenever tile_row
            # changes, so a trailing pad at block-row 0 would wipe the
            # span's real row-0 output (same hazard — and same fix — as
            # the serving assembler's tile-count padding).  An all-pad
            # span keeps row 0: it zero-defines the strip and adds
            # nothing.  nnz == 0 keeps every other leaf inert.
            k = (p.part_tiles >= 0).sum(1)  # real tiles per span (prefix)
            src = np.minimum(np.arange(width)[None, :], np.maximum(k - 1, 0)[:, None])
            src = jnp.asarray(np.where(k[:, None] > 0, src, np.arange(width)[None, :]))
            return dataclasses.replace(
                seg,
                tile_row=jnp.take_along_axis(seg.tile_row, src, axis=1),
                tile_col=jnp.take_along_axis(seg.tile_col, src, axis=1),
            )

        return ShardedPlan(
            segments=tuple(dev(s, p) for s, p in zip(segs, parts)),
            mesh=mesh,
            decision=decision,
        )

    def aggregate(
        self,
        plan: Union[SCVPlan, SCVBucketedPlan, ShardedPlan],
        z: jnp.ndarray,
        **kw,
    ) -> jnp.ndarray:
        """Execute any plan kind: sharded plans launch the mesh path,
        unplaced plans run single-device (``aggregate_scv_plan``)."""
        kw.setdefault("backend", self.backend)
        if isinstance(plan, ShardedPlan):
            return aggregate_sharded(plan, z, **kw)
        from repro.core.aggregate import aggregate_scv_plan

        return aggregate_scv_plan(plan, z, **kw)

    # -- whole-model convenience (serving + examples) ----------------------
    def prepare_graph(self, g, n_features: Optional[int] = None,
                      decision: Optional[ShardingDecision] = None):
        """Place a ``models.gnn.Graph``'s plan; edge arrays stay replicated
        (GAT's softmax is per-edge host math, tiny next to Z)."""
        placed = self.prepare(g.plan, n_features=n_features, decision=decision)
        if placed is g.plan:
            return g
        return dataclasses.replace(g, plan=placed)
