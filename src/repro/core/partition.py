"""Z-curve partitioning for multi-device aggregation (paper §III-C, §V-G).

The paper's scalability argument: any contiguous subsequence of the Z-Morton
tile order preserves locality, and tiles are fine-grained enough to split
the nonzeros evenly — unlike whole-row (CSR) or whole-column (CSC)
partitioning.  We realize this as:

1. ``split_equal_nnz`` — cut the Z-ordered tile list into P spans with
   near-equal nonzero counts (the paper's static split, §V-G: "each
   processor handles roughly an equal number of adjacency non-zeros").

2. ``pad_parts_uniform`` — pad every span to the same tile count so the
   result stacks into one leading device axis for ``shard_map``.
   ``shard_tiles`` materializes the stacked copy from the host object;
   ``shard_plan`` does the same gather on an ``SCVPlan`` pytree's device
   leaves (no host round-trip), which is what ``core.dist`` and the
   serving path use.

3. ``aggregate_sharded`` — each device aggregates its span into a *local*
   PS buffer, then partial results for boundary block-rows are merged with
   a single ``psum`` / ``psum_scatter`` — the paper's multi-processor PS
   merge (§V-G), realized as a collective instead of a shared-memory buffer
   region.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

from repro.core.scv import SCVBucketedPlan, SCVPlan, SCVTiles


@dataclasses.dataclass(frozen=True)
class Partition:
    """Indices of tiles assigned to each of P parts (equal lengths after
    padding; padded slots replicate a zero-nnz dummy tile)."""

    part_tiles: np.ndarray  # int32[P, tiles_per_part] — indices into SCVTiles
    nnz_per_part: np.ndarray  # int64[P]
    n_parts: int


def split_equal_nnz(
    tiles: Union[SCVTiles, SCVPlan, SCVBucketedPlan], n_parts: int
) -> Union[Partition, tuple[Partition, ...]]:
    """Greedy prefix split of the (already Z-ordered) tile sequence into
    spans of ~equal nnz.  Never reorders tiles — locality of the curve is
    exactly what the paper relies on.  Accepts the host ``SCVTiles`` or a
    device ``SCVPlan`` (its ``nnz_in_tile`` leaf is read back once).  An
    nnz-bucketed plan partitions per capacity segment (one ``Partition``
    each — segments are separate kernel launches, so each is cut into its
    own equal-nnz spans along the same curve)."""
    if isinstance(tiles, SCVBucketedPlan):
        return tuple(split_equal_nnz(s, n_parts) for s in tiles.segments)
    nnz = np.asarray(tiles.nnz_in_tile).astype(np.int64)
    total = int(nnz.sum())
    target = total / max(n_parts, 1)
    bounds = [0]
    acc = 0
    for i, k in enumerate(nnz):
        acc += int(k)
        if acc >= target * len(bounds) and len(bounds) < n_parts:
            bounds.append(i + 1)
    while len(bounds) < n_parts:
        bounds.append(tiles.n_tiles)
    bounds.append(tiles.n_tiles)

    spans = [np.arange(bounds[p], bounds[p + 1], dtype=np.int32) for p in range(n_parts)]
    width = max((len(s) for s in spans), default=1)
    width = max(width, 1)
    part_tiles = np.full((n_parts, width), -1, dtype=np.int32)
    for p, s in enumerate(spans):
        part_tiles[p, : len(s)] = s
    nnz_per_part = np.array(
        [int(nnz[s].sum()) for s in spans], dtype=np.int64
    )
    return Partition(part_tiles, nnz_per_part, n_parts)


def shard_tiles(tiles: SCVTiles, part: Partition) -> SCVTiles:
    """Materialize a stacked copy: part-padded slots become zero tiles
    (tile_row/col 0, nnz 0 — they contribute nothing).  Output arrays have
    leading dim P * tiles_per_part, ready to reshape to (P, ...) for
    shard_map."""
    idx = part.part_tiles.ravel()
    pad = idx < 0
    idx = np.where(pad, 0, idx)

    def take(a, fill=0):
        if a.shape[0] == 0:
            # coverage-free ladders can leave later buckets with zero
            # tiles; every span slot is then part-padding
            return np.full((len(idx),) + a.shape[1:], fill, a.dtype)
        out = a[idx].copy()
        out[pad] = fill
        return out

    return SCVTiles(
        tile_row=take(tiles.tile_row),
        tile_col=take(tiles.tile_col),
        rows=take(tiles.rows),
        cols=take(tiles.cols),
        vals=take(tiles.vals),
        nnz_in_tile=take(tiles.nnz_in_tile),
        tile=tiles.tile,
        cap=tiles.cap,
        shape=tiles.shape,
        order=tiles.order,
    )


def shard_plan(
    plan: Union[SCVPlan, SCVBucketedPlan],
    part: Union[Partition, tuple[Partition, ...]],
) -> Union[SCVPlan, SCVBucketedPlan]:
    """Shard the plan *pytree*: gather each part's tile span out of the
    device arrays (part-padded slots become zero tiles, perm slots ``-1``).

    A bucketed plan shards segment-by-segment with the matching tuple of
    partitions from :func:`split_equal_nnz`; the result is again a
    bucketed plan whose per-segment leaves carry the stacked span copies.

    The result is still one ``SCVPlan`` whose leaves have leading dim
    ``P * tiles_per_part`` — reshape to ``(P, tiles_per_part, ...)`` for
    ``shard_map`` (``core.exec.PlanExecutor.prepare`` does exactly that).  The
    gather runs on device; the host only computes the index vector, so the
    tiles never round-trip back to numpy the way ``shard_tiles`` requires.
    """
    if isinstance(plan, SCVBucketedPlan):
        if not isinstance(part, tuple) or len(part) != len(plan.segments):
            raise ValueError(
                "bucketed plan needs one Partition per segment "
                f"({len(plan.segments)}), got {part!r}"
            )
        return SCVBucketedPlan(
            tuple(shard_plan(s, p) for s, p in zip(plan.segments, part))
        )
    import jax.numpy as jnp

    idx = part.part_tiles.ravel()
    pad = idx < 0
    idx_j = jnp.asarray(np.where(pad, 0, idx))
    pad_j = jnp.asarray(pad)

    def take(a, fill=0):
        if a is None:
            return None
        a = jnp.asarray(a)
        if a.shape[0] == 0:
            # zero-tile segment (empty bucket of a coverage-free ladder):
            # nothing to gather, every span slot is part-padding
            return jnp.full((idx_j.shape[0],) + a.shape[1:], fill, a.dtype)
        out = a[idx_j]
        mask = pad_j.reshape((-1,) + (1,) * (out.ndim - 1))
        return jnp.where(mask, jnp.asarray(fill, out.dtype), out)

    return dataclasses.replace(
        plan,
        tile_row=take(plan.tile_row),
        tile_col=take(plan.tile_col),
        rows=take(plan.rows),
        cols=take(plan.cols),
        vals=take(plan.vals),
        nnz_in_tile=take(plan.nnz_in_tile),
        perm=take(plan.perm, fill=-1),
    )


def nnz_imbalance(per_part: np.ndarray) -> float:
    """max/mean ratio of a per-part nnz vector (1.0 = perfect balance;
    empty or all-zero input reports 1.0).  The one definition shared by
    ``load_imbalance`` and ``core.exec.ShardedPlan``."""
    per_part = np.asarray(per_part)
    mean = per_part.mean() if len(per_part) else 0.0
    return float(per_part.max() / mean) if mean else 1.0


def load_imbalance(
    part: Union[Partition, tuple[Partition, ...]],
    per_segment: bool = False,
) -> Union[float, tuple[float, ...]]:
    """max/mean nnz ratio — 1.0 is perfect balance.  The paper's fine-grain
    claim is that this stays near 1 even for power-law graphs.  For a
    bucketed plan's partition tuple the per-part nnz is summed across
    segments (all segments of one part run on the same device);
    ``per_segment=True`` instead reports one ratio per capacity segment —
    the breakdown that matters when one bucket's hub tiles skew a span
    even though the flattened aggregate looks balanced."""
    if isinstance(part, tuple):
        if per_segment:
            return tuple(load_imbalance(p) for p in part)
        return nnz_imbalance(sum(p.nnz_per_part for p in part))
    if per_segment:
        return (load_imbalance(part),)
    return nnz_imbalance(part.nnz_per_part)
