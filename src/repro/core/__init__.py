"""Core: the paper's contribution — SCV/SCV-Z sparse aggregation."""
from repro.core.aggregate import (
    aggregate,
    aggregate_bcsr,
    aggregate_coo_scatter,
    aggregate_coo_segsum,
    aggregate_dense,
    aggregate_scv_plan,
    aggregate_scv_tiles,
)
from repro.core.formats import (
    BCSRMatrix,
    COOMatrix,
    CSBMatrix,
    CSCMatrix,
    CSRMatrix,
    block_diag_coo,
    coo_from_dense,
    coo_to_bcsr,
    coo_to_csb,
    coo_to_csc,
    coo_to_csr,
    csc_to_coo,
    csr_to_coo,
)
from repro.core.exec import (
    PlanExecutor,
    ShardedPlan,
    ShardingDecision,
    aggregate_sharded,
    decide_sharding,
    placement_bytes,
)
from repro.core.morton import morton_decode, morton_encode, morton_order, zcurve_tiles
from repro.core.partition import (
    Partition,
    load_imbalance,
    shard_plan,
    shard_tiles,
    split_equal_nnz,
)
from repro.core.scv import (
    MXU_VPU_RATIO,
    ROW_MAJOR,
    ZMORTON,
    SCVBucketedPlan,
    SCVMatrix,
    SCVPlan,
    SCVTiles,
    bucket_caps_for,
    bucket_tiles,
    coo_to_scv,
    coo_to_scv_tiles,
    dense_tile_threshold,
    plan_from_tiles,
    plan_from_tiles_bucketed,
    scv_to_tiles,
    tile_nnz_histogram,
)

__all__ = [k for k in dir() if not k.startswith("_")]
