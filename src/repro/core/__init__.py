"""Core: the paper's contribution — SCV/SCV-Z sparse aggregation."""
from repro.core.aggregate import (
    aggregate,
    aggregate_bcsr,
    aggregate_coo_scatter,
    aggregate_coo_segsum,
    aggregate_dense,
    aggregate_scv_plan,
    aggregate_scv_tiles,
)
from repro.core.formats import (
    BCSRMatrix,
    COOMatrix,
    CSBMatrix,
    CSCMatrix,
    CSRMatrix,
    block_diag_coo,
    coo_from_dense,
    coo_to_bcsr,
    coo_to_csb,
    coo_to_csc,
    coo_to_csr,
    csc_to_coo,
    csr_to_coo,
)
from repro.core.morton import morton_decode, morton_encode, morton_order, zcurve_tiles
from repro.core.partition import (
    Partition,
    load_imbalance,
    shard_plan,
    shard_tiles,
    split_equal_nnz,
)
from repro.core.scv import (
    ROW_MAJOR,
    ZMORTON,
    SCVMatrix,
    SCVPlan,
    SCVTiles,
    coo_to_scv,
    coo_to_scv_tiles,
    plan_from_tiles,
    scv_to_tiles,
)

__all__ = [k for k in dir() if not k.startswith("_")]
