"""Baseline sparse formats (paper §II-B): COO, CSR, CSC, BCSR, CSB, and the
multipass (MP) schedule.

These are the *reference* formats SCV is evaluated against.  Each carries
enough structure for (a) numerically-exact aggregation in JAX and (b) the
cycle/traffic simulator (`repro.simul`) to replay its access pattern.

Construction is host-side numpy (static preprocessing, as in the paper);
the device-facing arrays are plain ndarrays convertible with jnp.asarray.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# COO
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class COOMatrix:
    """Coordinate format: one (row, col, val) tuple per nonzero."""

    rows: np.ndarray  # int32[nnz]
    cols: np.ndarray  # int32[nnz]
    vals: np.ndarray  # f32[nnz]
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / float(m * n) if m and n else 0.0

    def dedup(self) -> "COOMatrix":
        """Sum duplicate coordinates (canonicalization)."""
        m, n = self.shape
        keys = self.rows.astype(np.int64) * n + self.cols
        order = np.argsort(keys, kind="stable")
        keys_s = keys[order]
        vals_s = self.vals[order]
        uniq, start = np.unique(keys_s, return_index=True)
        sums = np.add.reduceat(vals_s, start) if len(start) else vals_s[:0]
        return COOMatrix(
            (uniq // n).astype(np.int32),
            (uniq % n).astype(np.int32),
            sums.astype(self.vals.dtype),
            self.shape,
        )

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, (self.rows, self.cols), self.vals)
        return out.astype(self.vals.dtype)


def coo_from_dense(a: np.ndarray) -> COOMatrix:
    rows, cols = np.nonzero(a)
    return COOMatrix(
        rows.astype(np.int32), cols.astype(np.int32), a[rows, cols], a.shape
    )


def block_diag_coo(
    mats: Sequence["COOMatrix"],
    pad_shape: Optional[tuple[int, int]] = None,
) -> tuple["COOMatrix", np.ndarray, np.ndarray]:
    """Compose matrices into one block-diagonal COO.

    The i-th input occupies rows ``row_off[i]:row_off[i+1]`` and columns
    ``col_off[i]:col_off[i+1]`` of the composite; no cross-block entries
    exist, so aggregation over the composite is exactly the per-matrix
    aggregation stacked (the batching identity the serving engine relies
    on).  ``pad_shape`` grows the composite to at least that shape with
    structurally-empty trailing rows/cols (padding-bucket support).

    Returns ``(composite, row_off, col_off)`` with offset arrays of length
    ``len(mats) + 1``.
    """
    k = len(mats)
    row_off = np.zeros(k + 1, np.int64)
    col_off = np.zeros(k + 1, np.int64)
    for i, a in enumerate(mats):
        row_off[i + 1] = row_off[i] + a.shape[0]
        col_off[i + 1] = col_off[i] + a.shape[1]
    m, n = int(row_off[-1]), int(col_off[-1])
    if pad_shape is not None:
        if pad_shape[0] < m or pad_shape[1] < n:
            raise ValueError(f"pad_shape {pad_shape} smaller than composite ({m}, {n})")
        m, n = int(pad_shape[0]), int(pad_shape[1])
    if k:
        rows = np.concatenate(
            [a.rows.astype(np.int64) + row_off[i] for i, a in enumerate(mats)]
        ).astype(np.int32)
        cols = np.concatenate(
            [a.cols.astype(np.int64) + col_off[i] for i, a in enumerate(mats)]
        ).astype(np.int32)
        vals = np.concatenate([a.vals for a in mats])
    else:
        rows = np.zeros(0, np.int32)
        cols = np.zeros(0, np.int32)
        vals = np.zeros(0, np.float32)
    return COOMatrix(rows, cols, vals, (m, n)), row_off, col_off


# ---------------------------------------------------------------------------
# CSR / CSC
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    row_ptr: np.ndarray  # int32[m+1]
    col_id: np.ndarray  # int32[nnz]
    vals: np.ndarray  # f32[nnz]
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.col_id.shape[0])


@dataclasses.dataclass(frozen=True)
class CSCMatrix:
    col_ptr: np.ndarray  # int32[n+1]
    row_id: np.ndarray  # int32[nnz]
    vals: np.ndarray  # f32[nnz]
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.row_id.shape[0])


def coo_to_csr(a: COOMatrix) -> CSRMatrix:
    m, n = a.shape
    order = np.argsort(a.rows.astype(np.int64) * n + a.cols, kind="stable")
    rows = a.rows[order]
    row_ptr = np.zeros(m + 1, dtype=np.int32)
    np.add.at(row_ptr, rows + 1, 1)
    row_ptr = np.cumsum(row_ptr, dtype=np.int64).astype(np.int32)
    return CSRMatrix(row_ptr, a.cols[order], a.vals[order], a.shape)


def coo_to_csc(a: COOMatrix) -> CSCMatrix:
    m, n = a.shape
    order = np.argsort(a.cols.astype(np.int64) * m + a.rows, kind="stable")
    cols = a.cols[order]
    col_ptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(col_ptr, cols + 1, 1)
    col_ptr = np.cumsum(col_ptr, dtype=np.int64).astype(np.int32)
    return CSCMatrix(col_ptr, a.rows[order], a.vals[order], a.shape)


def csr_to_coo(a: CSRMatrix) -> COOMatrix:
    rows = np.repeat(
        np.arange(a.shape[0], dtype=np.int32), np.diff(a.row_ptr)
    )
    return COOMatrix(rows, a.col_id.copy(), a.vals.copy(), a.shape)


def csc_to_coo(a: CSCMatrix) -> COOMatrix:
    cols = np.repeat(
        np.arange(a.shape[1], dtype=np.int32), np.diff(a.col_ptr)
    )
    return COOMatrix(a.row_id.copy(), cols, a.vals.copy(), a.shape)


# ---------------------------------------------------------------------------
# BCSR — blocked CSR with dense B x B blocks (paper §II-B.3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BCSRMatrix:
    row_ptr: np.ndarray  # int32[n_blk_rows+1], in units of blocks
    col_id: np.ndarray  # int32[n_blocks] — block-column of each stored block
    blocks: np.ndarray  # f32[n_blocks, B, B] — dense storage (the liability)
    block_size: int
    shape: tuple[int, int]

    @property
    def n_blocks(self) -> int:
        return int(self.col_id.shape[0])

    @property
    def stored_values(self) -> int:
        """Dense storage footprint — the BCSR overhead the paper calls out."""
        return self.n_blocks * self.block_size * self.block_size


def coo_to_bcsr(a: COOMatrix, block_size: int) -> BCSRMatrix:
    m, n = a.shape
    B = block_size
    nbr = -(-m // B)
    nbc = -(-n // B)
    brow = a.rows // B
    bcol = a.cols // B
    keys = brow.astype(np.int64) * nbc + bcol
    order = np.argsort(keys, kind="stable")
    keys_s = keys[order]
    uniq, start = np.unique(keys_s, return_index=True)
    blocks = np.zeros((len(uniq), B, B), dtype=a.vals.dtype)
    # scatter entries into their dense block
    blk_of_entry = np.searchsorted(uniq, keys_s)
    np.add.at(
        blocks,
        (blk_of_entry, a.rows[order] % B, a.cols[order] % B),
        a.vals[order],
    )
    ubrow = (uniq // nbc).astype(np.int32)
    ubcol = (uniq % nbc).astype(np.int32)
    row_ptr = np.zeros(nbr + 1, dtype=np.int32)
    np.add.at(row_ptr, ubrow + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    return BCSRMatrix(row_ptr, ubcol, blocks, B, a.shape)


# ---------------------------------------------------------------------------
# CSB — compressed sparse blocks (paper §III-A): sparse B x B tiles with
# relative (log2 B-bit) coordinates.  SCV == CSB with block width 1.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CSBMatrix:
    blk_ptr: np.ndarray  # int32[n_blocks+1] into vals
    blk_row: np.ndarray  # int32[n_blocks] — block-row coordinate
    blk_col: np.ndarray  # int32[n_blocks] — block-col coordinate
    row_id: np.ndarray  # int32[nnz] — row offset *within* block
    col_id: np.ndarray  # int32[nnz] — col offset *within* block
    vals: np.ndarray  # f32[nnz]
    block_h: int
    block_w: int
    shape: tuple[int, int]

    @property
    def n_blocks(self) -> int:
        return int(self.blk_row.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.vals.shape[0])


def coo_to_csb(
    a: COOMatrix,
    block_h: int,
    block_w: int,
    block_order: Optional[np.ndarray] = None,
) -> CSBMatrix:
    """Tile into block_h x block_w sparse blocks.

    Within a block, entries are stored column-major (column-vector order —
    the SCV processing discipline, §III-A "we propose using a column-major
    storage format").  Block order defaults to row-major over the block
    grid; pass a permutation of block indices (e.g. from Z-Morton) to
    reorder — §III-C.
    """
    m, n = a.shape
    nbc = -(-n // block_w)
    brow = (a.rows // block_h).astype(np.int64)
    bcol = (a.cols // block_w).astype(np.int64)
    bkey = brow * nbc + bcol
    # column-major within block: sort by (block, local col, local row)
    lrow = (a.rows % block_h).astype(np.int64)
    lcol = (a.cols % block_w).astype(np.int64)
    within = lcol * block_h + lrow
    order = np.argsort(bkey * (block_h * block_w) + within, kind="stable")
    bkey_s = bkey[order]
    uniq, start = np.unique(bkey_s, return_index=True)
    counts = np.diff(np.append(start, len(bkey_s)))
    ubrow = (uniq // nbc).astype(np.int32)
    ubcol = (uniq % nbc).astype(np.int32)
    if block_order is not None:
        assert len(block_order) == len(uniq)
        perm = np.asarray(block_order)
        # reorder blocks; entries regrouped accordingly
        entry_order = np.concatenate(
            [np.arange(start[b], start[b] + counts[b]) for b in perm]
        ) if len(uniq) else np.arange(0)
        ubrow, ubcol, counts = ubrow[perm], ubcol[perm], counts[perm]
    else:
        entry_order = np.arange(len(order))
    order = order[entry_order.astype(np.int64)] if len(order) else order
    blk_ptr = np.concatenate(
        [[0], np.cumsum(counts)]
    ).astype(np.int32)
    return CSBMatrix(
        blk_ptr,
        ubrow,
        ubcol,
        (a.rows[order] % block_h).astype(np.int32),
        (a.cols[order] % block_w).astype(np.int32),
        a.vals[order],
        block_h,
        block_w,
        a.shape,
    )
