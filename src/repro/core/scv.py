"""Sparse Compressed Vectors — the paper's contribution (§III).

Two representations are provided:

* :class:`SCVMatrix` — the *logical* format of Fig. 1(d): fixed-height
  column vectors, per-entry within-vector row offsets (``blk_id``), vector
  pointer array (``blk_ptr``), vectors enumerated row-major over the block
  grid (SCV) or along a Z-Morton curve over B x B vector groups (SCV-Z).
  This is what the cycle/traffic simulator replays and what matches the
  paper bit-for-bit.

* :class:`SCVTiles` — the *TPU device* layout consumed by the Pallas kernel
  (see DESIGN.md §2): the same entries regrouped into T x T tiles (a tile =
  one Z-Morton vector-group = T column vectors), each tile padded to a fixed
  entry capacity so shapes are static.  Within a tile, entries keep the SCV
  column-vector order (sorted by local column, then local row).  Tiles are
  scheduled so that all tiles of one PS block-row are consecutive — the
  Pallas analogue of "partial sums reused before eviction".

* :class:`SCVPlan` — the *executable* plan: the SCVTiles arrays on device
  (coverage dummies appended, perm padded), registered as a jax pytree so
  a whole GNN forward over it can sit under one ``jax.jit``.  Array fields
  are pytree **leaves**; ``tile`` / ``cap`` / ``shape`` / ``order`` are
  **static aux data**, so jit specializes on them (and on leaf shapes)
  exactly once per padding bucket.

* :class:`SCVBucketedPlan` — the nnz-bucketed variant (DESIGN.md §2): one
  ``SCVPlan`` segment per entry-capacity bucket so a single hub tile no
  longer sets the padded capacity of every tile; the kernel runs one
  launch per segment and sums the partials.

Construction is host-side preprocessing ("statically generated from the COO
format ... nearly equivalent to creating a CSR or CSC matrix" — §III-C);
``coo_to_scv_tiles`` emits tiles with vectorized numpy scatter, so the cost
really is a couple of sorts plus O(nnz) array ops even at million-edge
scale (``benchmarks/preprocess_bench.py`` gates this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.core import morton
from repro.core.formats import COOMatrix

ROW_MAJOR = "row_major"
ZMORTON = "zmorton"

# ---------------------------------------------------------------------------
# Kernel-model constants (DESIGN.md §2) — the single source of truth shared
# by the Pallas kernel (`kernels/scv_spmm`), the hybrid split below, and the
# roofline model (`benchmarks/kernel_roofline.py` imports these so the model
# and the implementation cannot drift).
# ---------------------------------------------------------------------------
#: VPU FMA-lane rate over MXU MAC rate (v5e: 8x128 lanes vs 128x128 MACs).
MXU_VPU_RATIO = 1.0 / 16.0
#: Entries per vectorized kernel chunk (one scatter/gather matmul pair).
DEFAULT_CHUNK = 128
#: Geometric ratio between adjacent capacity buckets.
BUCKET_RATIO = 4
#: Maximum number of capacity buckets a plan is split into.
MAX_BUCKETS = 4
#: Smallest per-tile entry capacity (TPU sublane count).
MIN_BUCKET_CAP = 8
#: Default tile size T (block row/column extent of an SCV tile).
DEFAULT_TILE = 64
#: Default single-bucket per-tile capacity when bucketing is disabled.
DEFAULT_CAP = 64
#: Default serving capacity ladder — the measured ladder A/B winner on the
#: sparse 131k-node pool (serve_bench ``ladder_ab``; 3-deep won the PR 10
#: re-run and serve_bench now *fails* if a recorded winner beats the
#: default past the ladder slack band, so this constant tracks the
#: measurement instead of drifting stale).  Per-regime overrides come
#: from ``repro.tune.TunedConfig``; scvlint SCV002 rejects re-declared
#: tile/cap/ladder literals outside this module and ``tune/config.py``.
DEFAULT_LADDER = (8, 32, 128)


def dense_tile_threshold(tile: int) -> int:
    """nnz above which a T x T tile is cheaper as a dense MXU matmul than
    as per-entry gather-FMA work on the VPU:

        T*T*F / MXU_rate < nnz * F / VPU_rate  =>  nnz > T^2 * VPU/MXU
    """
    return int(tile * tile * MXU_VPU_RATIO)


def bucket_caps_for(
    counts: np.ndarray,
    tile: int,
    max_buckets: int = MAX_BUCKETS,
    ratio: int = BUCKET_RATIO,
) -> tuple[int, ...]:
    """Ascending power-of-two capacity ladder covering ``counts``.

    The largest cap is the smallest power of two holding the heaviest tile
    (clamped to T^2 — a tile cannot exceed its dense size); smaller caps
    descend geometrically by ``ratio`` down to ``MIN_BUCKET_CAP``.  The
    ladder is a pure function of (max count, tile), so two graphs with
    similar hub sizes share plan aux — and therefore jit traces.
    """
    hi = int(counts.max()) if len(counts) else 1
    hi = max(MIN_BUCKET_CAP, min(hi, tile * tile))
    cap = MIN_BUCKET_CAP
    while cap < hi:
        cap *= 2
    caps = [cap]
    while len(caps) < max_buckets and caps[-1] // ratio >= MIN_BUCKET_CAP:
        caps.append(caps[-1] // ratio)
    return tuple(sorted(caps))


def launched_slots(
    counts: np.ndarray,
    tile: int,
    caps: tuple[int, ...],
    n_row_blocks: int = 0,
) -> int:
    """Capacity slots a bucketed plan *launches* for a tile-nnz histogram.

    Mirrors the ``coo_to_scv_tiles(cap=caps[-1])`` +
    :func:`plan_from_tiles_bucketed` layout arithmetic without building the
    plan: a logical tile with ``k`` entries chain-splits at the top cap —
    ``k // caps[-1]`` full chunks occupy top-cap slot rows and the
    remainder lands in the smallest cap holding it.  ``n_row_blocks``
    (when given) adds one ``caps[0]`` slot row per output block row as the
    first-segment coverage-dummy bound — an upper bound, since block rows
    already covered by a first-segment tile need no dummy.

    This is the number the byte model must price (``3 * slots * B`` for
    the rows/cols/vals triple), not logical nnz: BENCH_dist measured the
    nnz-priced model 1.11-3.79x optimistic against placed plans.
    """
    caps_arr = np.asarray(sorted(int(c) for c in caps), dtype=np.int64)
    if caps_arr.size == 0:
        raise ValueError("caps must be non-empty")
    counts_arr = np.asarray(counts, dtype=np.int64)
    counts_arr = counts_arr[counts_arr > 0]
    top = int(caps_arr[-1])
    slots = int(n_row_blocks) * int(caps_arr[0])
    if counts_arr.size == 0:
        return slots
    slots += int((counts_arr // top).sum()) * top
    rem = counts_arr % top
    rem = rem[rem > 0]
    if rem.size:
        slots += int(caps_arr[np.searchsorted(caps_arr, rem)].sum())
    return slots


def tile_nnz_histogram(a: COOMatrix, tile: int) -> np.ndarray:
    """Per-logical-tile entry counts — the input to ``bucket_caps_for``
    when deriving a ladder *before* tiles are built (chain-splitting at
    the ladder's largest cap needs the ladder first)."""
    T = int(tile)
    nbc = -(-a.shape[1] // T)
    key = (a.rows // T).astype(np.int64) * nbc + (a.cols // T)
    _, counts = np.unique(key, return_counts=True)
    return counts


# ---------------------------------------------------------------------------
# Logical SCV (paper Fig. 1(d))
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SCVMatrix:
    blk_ptr: np.ndarray  # int32[n_vectors+1] — start of each vector in vals
    vec_row_blk: np.ndarray  # int32[n_vectors] — block-row of each vector
    vec_col: np.ndarray  # int32[n_vectors] — matrix column of each vector
    blk_id: np.ndarray  # int32[nnz] — row offset within vector (< B)
    vals: np.ndarray  # f32[nnz]
    vector_height: int  # B
    order: str  # ROW_MAJOR (SCV) or ZMORTON (SCV-Z)
    shape: tuple[int, int]

    @property
    def n_vectors(self) -> int:
        return int(self.vec_col.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def index_bits_per_entry(self) -> int:
        """log2(B) bits per entry — the storage advantage over COO's
        log2(N) (§III-A)."""
        return max(1, int(np.ceil(np.log2(self.vector_height))))

    def to_coo(self) -> COOMatrix:
        counts = np.diff(self.blk_ptr)
        vrow = np.repeat(self.vec_row_blk, counts).astype(np.int64)
        vcol = np.repeat(self.vec_col, counts).astype(np.int32)
        rows = (vrow * self.vector_height + self.blk_id).astype(np.int32)
        return COOMatrix(rows, vcol, self.vals.copy(), self.shape)


def coo_to_scv(
    a: COOMatrix,
    vector_height: int,
    order: str = ZMORTON,
) -> SCVMatrix:
    """Build SCV/SCV-Z from COO.

    Vectors (non-empty column strips of height B) are enumerated either
    row-major over the (block_row, column) grid — plain SCV, Fig. 2(d) —
    or along a Z-Morton curve over B x B vector *groups* with column order
    inside a group — SCV-Z, Fig. 2(e).
    """
    if order not in (ROW_MAJOR, ZMORTON):
        raise ValueError(f"unknown order {order!r}")
    B = int(vector_height)
    if B <= 0:
        raise ValueError("vector_height must be positive")
    m, n = a.shape

    row_blk = (a.rows // B).astype(np.int64)
    blk_id = (a.rows % B).astype(np.int64)
    col = a.cols.astype(np.int64)

    if order == ROW_MAJOR:
        # vectors ordered (block_row, col); entries within vector by row
        vkey = row_blk * n + col
        entry_key = vkey * B + blk_id
    else:
        # Z-curve over (block_row, col // B) groups, columns in order
        # inside a group, rows in order inside a vector.
        grp = morton.morton_encode(row_blk, col // B).astype(np.uint64)
        # combined key: (zcurve group, local col, local row)
        local_col = (col % B).astype(np.uint64)
        entry_key = (grp * np.uint64(B) + local_col) * np.uint64(B) + blk_id.astype(
            np.uint64
        )
        vkey = grp * np.uint64(B) + local_col  # unique per vector, curve order

    eorder = np.argsort(entry_key, kind="stable")
    vkey_s = np.asarray(vkey)[eorder]
    uniq, start = np.unique(vkey_s, return_index=True)
    counts = np.diff(np.append(start, len(vkey_s)))
    blk_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    first = eorder[start]  # one representative entry per vector
    return SCVMatrix(
        blk_ptr=blk_ptr,
        vec_row_blk=row_blk[first].astype(np.int32),
        vec_col=col[first].astype(np.int32),
        blk_id=blk_id[eorder].astype(np.int32),
        vals=a.vals[eorder],
        vector_height=B,
        order=order,
        shape=a.shape,
    )


# ---------------------------------------------------------------------------
# Device tile layout for the Pallas kernel
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SCVTiles:
    """Static-shape tiled SCV for `kernels/scv_spmm`.

    ``tile_row/tile_col`` give each tile's block coordinates (scalar-
    prefetched on TPU to steer the Z and PS BlockSpec index maps).  Entry
    arrays are padded to ``cap`` per tile; padding entries have val == 0 and
    row == col == 0 (they add zero — no masking needed in the kernel).
    Heavy tiles are split into chains of logical tiles sharing coordinates.

    Schedule invariant: tiles with equal ``tile_row`` are consecutive, and
    ``tile_row`` is non-decreasing **within each partition span** — the
    Pallas output window then moves monotonically and each PS strip is
    written back exactly once per span (paper's PS-reuse property).
    """

    tile_row: np.ndarray  # int32[nt]
    tile_col: np.ndarray  # int32[nt]
    rows: np.ndarray  # int32[nt, cap] — local row within tile
    cols: np.ndarray  # int32[nt, cap] — local col within tile
    vals: np.ndarray  # f32[nt, cap]
    nnz_in_tile: np.ndarray  # int32[nt]
    tile: int  # T (== SCV vector height == vector-group side)
    cap: int
    shape: tuple[int, int]  # original (unpadded) matrix shape
    order: str
    perm: Optional[np.ndarray] = None  # int64[nt, cap]: source COO entry of each slot (-1 pad)

    @property
    def n_tiles(self) -> int:
        return int(self.tile_row.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.nnz_in_tile.sum())

    @property
    def padded_shape(self) -> tuple[int, int]:
        T = self.tile
        m, n = self.shape
        return (-(-m // T) * T, -(-n // T) * T)

    @property
    def padding_fraction(self) -> float:
        tot = self.n_tiles * self.cap
        return 1.0 - self.nnz / tot if tot else 0.0

    def to_coo(self) -> COOMatrix:
        T = self.tile
        rows = (
            self.tile_row[:, None].astype(np.int64) * T + self.rows
        ).ravel()
        cols = (
            self.tile_col[:, None].astype(np.int64) * T + self.cols
        ).ravel()
        vals = self.vals.ravel()
        keep = np.arange(self.cap)[None, :] < self.nnz_in_tile[:, None]
        keep = keep.ravel()
        return COOMatrix(
            rows[keep].astype(np.int32),
            cols[keep].astype(np.int32),
            vals[keep],
            self.shape,
        )


def _auto_cap(counts: np.ndarray, tile: int) -> int:
    """Pick the per-tile entry capacity minimizing padded slots.

    Splitting a tile with k entries under cap c costs ceil(k/c)*c slots; we
    scan caps (multiples of 8 — TPU sublane count) and take the argmin.
    """
    if len(counts) == 0:
        return 8
    cands = []
    hi = int(min(counts.max(), tile * tile))
    c = 8
    while c < hi * 2:
        cands.append(c)
        c *= 2
    cands.append(max(8, hi))
    best, best_slots = cands[0], None
    for c in cands:
        slots = int((-(-counts // c) * c).sum())
        if best_slots is None or slots < best_slots:
            best, best_slots = c, slots
    return int(best)


def _tile_sort(a: COOMatrix, tile: int, order: str):
    """Shared prologue of the tile builders: sort entries into SCV
    column-vector order within tiles and schedule the tiles.

    Returns ``(utrow, utcol, start, counts, sched, eorder, lrow_s, lcol_s,
    vals_s)`` — per-unique-tile coordinates / entry spans plus the sorted
    entry arrays.
    """
    T = int(tile)
    m, n = a.shape
    nbc = -(-n // T)
    trow = (a.rows // T).astype(np.int64)
    tcol = (a.cols // T).astype(np.int64)
    lrow = (a.rows % T).astype(np.int64)
    lcol = (a.cols % T).astype(np.int64)
    tkey = trow * nbc + tcol
    # SCV discipline within a tile: column-vector order (local col, row)
    eorder = np.argsort(tkey * (T * T) + lcol * T + lrow, kind="stable")
    tkey_s = tkey[eorder]
    # run-starts on the sorted keys (np.unique would sort a second time)
    if len(tkey_s):
        start = np.flatnonzero(np.r_[True, tkey_s[1:] != tkey_s[:-1]])
    else:
        start = np.zeros(0, np.int64)
    uniq = tkey_s[start]
    counts = np.diff(np.append(start, len(tkey_s))).astype(np.int64)
    utrow = (uniq // nbc).astype(np.int64)
    utcol = (uniq % nbc).astype(np.int64)

    # Tile schedule: group by block-row (consecutive PS windows); within a
    # block-row, Z order degenerates to ascending column — the cross-row
    # locality of the full 2-D curve is exploited at the *partition* level
    # (core/partition.py splits the true Z curve across devices).
    if order == ZMORTON:
        zkey = morton.morton_encode(utrow, utcol)
        sched = np.lexsort((zkey, utrow))
    elif order == ROW_MAJOR:
        sched = np.lexsort((utcol, utrow))
    else:
        raise ValueError(f"unknown order {order!r}")
    return utrow, utcol, start, counts, sched, eorder, lrow[eorder], lcol[eorder], a.vals[eorder]


def coo_to_scv_tiles(
    a: COOMatrix,
    tile: int,
    cap: Optional[int] = None,
    order: str = ZMORTON,
) -> SCVTiles:
    """COO -> device tile layout (see class docstring).

    Heavy tiles (more than ``cap`` entries) split into chains of logical
    tiles sharing coordinates.  Emission is vectorized numpy scatter: each
    output slot ``(chunk, s)`` with ``s < nnz_in_tile[chunk]`` pulls sorted
    entry ``start[tile(chunk)] + chunk_local * cap + s`` — no Python loop
    over tiles, so plan construction stays a few sorts + O(nnz) array ops
    at million-edge scale (``_coo_to_scv_tiles_loop`` keeps the scalar
    emitter as the equivalence/benchmark reference).
    """
    T = int(tile)
    utrow, utcol, start, counts, sched, eorder, lrow_s, lcol_s, vals_s = _tile_sort(
        a, T, order
    )
    if cap is None:
        cap = _auto_cap(counts, T)
    cap = int(cap)

    # chunks (logical output tiles) in schedule order
    nu = len(counts)
    n_chunks = (-(-counts // cap)).astype(np.int64)
    cc = n_chunks[sched]  # chunks per scheduled tile
    nt = int(cc.sum()) if len(cc) else 0
    chunk_tile = np.repeat(sched, cc)  # unique-tile index of each chunk
    first = np.cumsum(cc) - cc  # first chunk slot of each scheduled tile
    chunk_local = np.arange(nt, dtype=np.int64) - np.repeat(first, cc)

    tile_row = utrow[chunk_tile].astype(np.int32)
    tile_col = utcol[chunk_tile].astype(np.int32)
    nnz_out = np.minimum(
        cap, counts[chunk_tile] - chunk_local * cap
    ).astype(np.int32) if nt else np.zeros(0, np.int32)

    # per-entry destination slot: sorted entry j of tile t lands in chunk
    # ``chunk_first[t] + j // cap``, slot ``j % cap`` — an O(nnz) flat
    # scatter with no [nt, cap] index intermediates
    nnz = eorder.shape[0]
    rank = np.empty(nu, np.int64)
    rank[sched] = np.arange(nu, dtype=np.int64)
    chunk_first = first[rank]  # first output chunk of each unique tile
    inv = np.repeat(np.arange(nu, dtype=np.int64), counts)  # tile of entry
    pos = np.arange(nnz, dtype=np.int64) - np.repeat(start, counts)
    dst = (chunk_first[inv] + pos // cap) * cap + pos % cap
    rows_out = np.zeros(nt * cap, np.int32)
    cols_out = np.zeros(nt * cap, np.int32)
    vals_out = np.zeros(nt * cap, a.vals.dtype)
    perm_out = np.full(nt * cap, -1, np.int64)
    rows_out[dst] = lrow_s
    cols_out[dst] = lcol_s
    vals_out[dst] = vals_s
    perm_out[dst] = eorder
    rows_out = rows_out.reshape(nt, cap)
    cols_out = cols_out.reshape(nt, cap)
    vals_out = vals_out.reshape(nt, cap)
    perm_out = perm_out.reshape(nt, cap)
    return SCVTiles(
        tile_row=tile_row,
        tile_col=tile_col,
        rows=rows_out,
        cols=cols_out,
        vals=vals_out,
        nnz_in_tile=nnz_out,
        tile=T,
        cap=cap,
        shape=a.shape,
        order=order,
        perm=perm_out,
    )


def _coo_to_scv_tiles_loop(
    a: COOMatrix,
    tile: int,
    cap: Optional[int] = None,
    order: str = ZMORTON,
) -> SCVTiles:
    """Scalar per-tile emission loop — the pre-vectorization construction,
    kept as the byte-identical reference for tests and
    ``benchmarks/preprocess_bench.py``."""
    T = int(tile)
    utrow, utcol, start, counts, sched, eorder, lrow_s, lcol_s, vals_s = _tile_sort(
        a, T, order
    )
    if cap is None:
        cap = _auto_cap(counts, T)
    cap = int(cap)

    n_chunks = (-(-counts // cap)).astype(np.int64)
    nt = int(n_chunks.sum()) if len(n_chunks) else 0
    tile_row = np.zeros(nt, np.int32)
    tile_col = np.zeros(nt, np.int32)
    rows_out = np.zeros((nt, cap), np.int32)
    cols_out = np.zeros((nt, cap), np.int32)
    vals_out = np.zeros((nt, cap), a.vals.dtype)
    nnz_out = np.zeros(nt, np.int32)
    perm_out = np.full((nt, cap), -1, np.int64)

    out = 0
    for b in sched:
        s, k = int(start[b]), int(counts[b])
        for off in range(0, k, cap):
            take = min(cap, k - off)
            sl = slice(s + off, s + off + take)
            tile_row[out] = utrow[b]
            tile_col[out] = utcol[b]
            rows_out[out, :take] = lrow_s[sl]
            cols_out[out, :take] = lcol_s[sl]
            vals_out[out, :take] = vals_s[sl]
            perm_out[out, :take] = eorder[sl]
            nnz_out[out] = take
            out += 1
    assert out == nt
    return SCVTiles(
        tile_row=tile_row,
        tile_col=tile_col,
        rows=rows_out,
        cols=cols_out,
        vals=vals_out,
        nnz_in_tile=nnz_out,
        tile=T,
        cap=cap,
        shape=a.shape,
        order=order,
        perm=perm_out,
    )


def scv_to_tiles(a: SCVMatrix, cap: Optional[int] = None) -> SCVTiles:
    return coo_to_scv_tiles(a.to_coo(), a.vector_height, cap=cap, order=a.order)


# ---------------------------------------------------------------------------
# Executable plan pytree (device arrays + static aux; jit end-to-end)
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SCVPlan:
    """First-class jit-able SCV aggregation plan.

    Pytree contract (the whole point of this class):

    * **Leaves** — the device arrays ``tile_row``, ``tile_col``, ``rows``,
      ``cols``, ``vals``, ``nnz_in_tile``, ``perm``.  They trace through
      ``jax.jit`` / ``shard_map`` / ``jax.grad`` like any other argument.
      ``perm`` may be ``None`` (plans that never re-weight edges).
    * **Static aux data** — ``tile``, ``cap``, ``shape``, ``order``.  jit
      specializes on them (plus leaf shapes); two plans with equal aux and
      equal array shapes share one trace, which is what bounds recompiles
      to one per padding bucket.

    Unlike :class:`SCVTiles` (the host-side construction output), a plan
    always carries its coverage dummy tiles — one zero-nnz tile per
    otherwise-unvisited PS block-row, so the Pallas kernel defines the
    whole output — and its ``perm`` is padded to the covered tile count
    with ``-1`` ("no source entry"; consumers append a zero to the edge
    array so ``-1`` gathers it).
    """

    tile_row: Any  # i32[nt] (coverage dummies included)
    tile_col: Any  # i32[nt]
    rows: Any  # i32[nt, cap] local row within tile
    cols: Any  # i32[nt, cap] local col within tile
    vals: Any  # f32[nt, cap] (0 in padding slots)
    nnz_in_tile: Any  # i32[nt]
    perm: Any  # i32[nt, cap] source COO entry per slot (-1 pad), or None
    tile: int  # T — static
    cap: int  # static
    shape: tuple[int, int]  # original (unpadded) matrix shape — static
    order: str  # static

    def tree_flatten(self):
        return (
            (self.tile_row, self.tile_col, self.rows, self.cols, self.vals,
             self.nnz_in_tile, self.perm),
            (self.tile, self.cap, self.shape, self.order),
        )

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def n_tiles(self) -> int:
        return int(self.tile_row.shape[0])

    @property
    def padded_shape(self) -> tuple[int, int]:
        T = self.tile
        m, n = self.shape
        return (-(-m // T) * T, -(-n // T) * T)

    @property
    def n_row_blocks(self) -> int:
        return self.padded_shape[0] // self.tile

    def with_vals(self, vals) -> "SCVPlan":
        """Same plan, re-weighted entry values (GAT's per-edge attention)."""
        return dataclasses.replace(self, vals=vals)

    def reweighted(self, edge_vals) -> "SCVPlan":
        """Same plan, tile values re-gathered from a per-edge array through
        the ``perm`` leaf (GAT's attention weights).  Padding slots carry
        ``perm == -1`` and gather the appended zero."""
        if self.perm is None:
            raise ValueError(
                "per-edge re-weighting needs the plan's perm leaf; this plan "
                "was built without it (with_edges/with_perm disabled)"
            )
        import jax.numpy as jnp

        ev = jnp.concatenate([edge_vals, jnp.zeros((1,), edge_vals.dtype)])
        return self.with_vals(ev[self.perm].astype(self.vals.dtype))


def plan_from_tiles(
    t: SCVTiles, ensure_coverage: bool = True, with_perm: bool = True
) -> SCVPlan:
    """SCVTiles (host) -> SCVPlan (device pytree).

    The single code path for coverage-dummy insertion and perm padding:
    every consumer (single-graph ``build_graph``, the serving engine's
    composite assembly, ``scv_device_arrays``) builds plans here, so the
    "dummy rows carry perm == -1" invariant lives in exactly one place.
    """
    import jax.numpy as jnp

    tr, tc, rs, cs, vs, nz = (
        t.tile_row, t.tile_col, t.rows, t.cols, t.vals, t.nnz_in_tile,
    )
    if ensure_coverage:
        from repro.kernels.scv_spmm.ops import ensure_row_coverage

        tr, tc, rs, cs, vs, nz = ensure_row_coverage(
            tr, tc, rs, cs, vs, nz, t.padded_shape[0] // t.tile
        )
    perm = None
    if with_perm and t.perm is not None:
        if t.nnz >= 2**31:  # device perm is i32; refuse to wrap silently
            raise ValueError(
                f"entry count {t.nnz} overflows the int32 perm leaf"
            )
        pp = np.full((len(tr), t.cap), -1, np.int32)
        pp[: t.perm.shape[0]] = t.perm.astype(np.int32)
        perm = jnp.asarray(pp)
    return SCVPlan(
        tile_row=jnp.asarray(tr),
        tile_col=jnp.asarray(tc),
        rows=jnp.asarray(rs),
        cols=jnp.asarray(cs),
        vals=jnp.asarray(vs),
        nnz_in_tile=jnp.asarray(nz),
        perm=perm,
        tile=t.tile,
        cap=t.cap,
        shape=t.shape,
        order=t.order,
    )


# ---------------------------------------------------------------------------
# nnz-bucketed capacity (DESIGN.md §2): per-bucket segments, per-segment cap
# ---------------------------------------------------------------------------
def bucket_tiles(t: SCVTiles, caps) -> tuple[SCVTiles, ...]:
    """Split tiles into capacity buckets: each tile goes to the smallest
    ``cap`` holding its nnz, and the entry arrays are truncated to that cap
    (entries are front-packed, so the truncation drops only structural
    padding).  One ``SCVTiles`` per cap, tiles in original schedule order —
    a subsequence of a block-row-grouped schedule keeps equal block-rows
    consecutive, so the kernel's PS-reuse invariant holds per bucket.
    """
    caps = tuple(sorted(int(c) for c in caps))
    if len(set(caps)) != len(caps) or not caps:
        raise ValueError(f"caps must be non-empty and distinct, got {caps}")
    nnz = t.nnz_in_tile.astype(np.int64)
    if len(nnz) and int(nnz.max()) > caps[-1]:
        raise ValueError(
            f"heaviest tile has {int(nnz.max())} entries > largest bucket "
            f"cap {caps[-1]}; build tiles with cap <= caps[-1] first"
        )
    which = np.searchsorted(caps, nnz)  # nnz == cap lands in that bucket

    def fit(a: np.ndarray, cap: int, fill) -> np.ndarray:
        """Truncate (or, for ladder caps above the build cap, pad) the
        entry axis to ``cap`` — truncation drops only structural padding
        because entries are front-packed."""
        if a.shape[1] >= cap:
            return a[:, :cap]
        out = np.full((a.shape[0], cap), fill, a.dtype)
        out[:, : a.shape[1]] = a
        return out

    def subset(mask: np.ndarray, cap: int) -> SCVTiles:
        return SCVTiles(
            tile_row=t.tile_row[mask],
            tile_col=t.tile_col[mask],
            rows=fit(t.rows[mask], cap, 0),
            cols=fit(t.cols[mask], cap, 0),
            vals=fit(t.vals[mask], cap, 0),
            nnz_in_tile=t.nnz_in_tile[mask],
            tile=t.tile,
            cap=cap,
            shape=t.shape,
            order=t.order,
            perm=fit(t.perm[mask], cap, -1) if t.perm is not None else None,
        )

    return tuple(subset(which == b, cap) for b, cap in enumerate(caps))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SCVBucketedPlan:
    """Executable SCV plan split into capacity-bucket segments.

    Each segment is an :class:`SCVPlan` holding the tiles whose nnz fits
    its (static) cap — so one hub tile no longer inflates the padded entry
    arrays of every other tile the way a single global cap does.  The
    kernel runs one ``pallas_call`` per segment, chained through a single
    aliased accumulator (``ops.scv_spmm_plan``): the first launch
    zero-defines the whole output (coverage dummies live in the first
    segment only), later launches seed visited strips from the running
    accumulator and pass unvisited strips through.

    Pytree contract: the segment tuple is the only child (each segment is
    itself a pytree whose aux carries its cap), so jit specializes on the
    ladder ``caps`` + per-segment leaf shapes — the bucket layout is part
    of the trace signature exactly like a single plan's ``cap``.
    """

    segments: tuple[SCVPlan, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("SCVBucketedPlan needs at least one segment")
        caps = [s.cap for s in self.segments]
        if sorted(set(caps)) != caps:
            raise ValueError(f"segment caps must be ascending and distinct: {caps}")
        s0 = self.segments[0]
        for s in self.segments[1:]:
            if (s.tile, s.shape, s.order) != (s0.tile, s0.shape, s0.order):
                raise ValueError("segments disagree on tile/shape/order")

    def tree_flatten(self):
        return (tuple(self.segments), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(tuple(children))

    # -- aux delegated to the segments (validated equal across them) -------
    @property
    def tile(self) -> int:
        return self.segments[0].tile

    @property
    def shape(self) -> tuple[int, int]:
        return self.segments[0].shape

    @property
    def order(self) -> str:
        return self.segments[0].order

    @property
    def caps(self) -> tuple[int, ...]:
        return tuple(s.cap for s in self.segments)

    @property
    def n_tiles(self) -> int:
        return sum(s.n_tiles for s in self.segments)

    @property
    def padded_shape(self) -> tuple[int, int]:
        return self.segments[0].padded_shape

    @property
    def n_row_blocks(self) -> int:
        return self.segments[0].n_row_blocks

    @property
    def perm(self):
        """Whether the plan supports per-edge re-weighting (all segments
        carry perm); exposed for feature tests, not for direct indexing."""
        perms = [s.perm for s in self.segments]
        return None if any(p is None for p in perms) else perms

    def reweighted(self, edge_vals) -> "SCVBucketedPlan":
        """Per-edge re-weighting, delegated to each segment (the segment
        perms all index the same global edge array)."""
        return SCVBucketedPlan(
            tuple(s.reweighted(edge_vals) for s in self.segments)
        )


def plan_from_tiles_bucketed(
    t: SCVTiles,
    caps=None,
    ensure_coverage: bool = True,
    with_perm: bool = True,
    config=None,
) -> SCVBucketedPlan:
    """SCVTiles (host) -> nnz-bucketed device plan.

    ``caps`` defaults to :func:`bucket_caps_for` over the tile nnz
    histogram; a ``repro.tune.TunedConfig`` may be passed as ``config``
    instead, in which case its ladder (or its single ``cap`` when the
    ladder is empty) supplies the caps.  Coverage dummies are emitted
    **once per plan**, in the first segment only (where zero nnz buckets
    them anyway — the smallest cap): the first kernel launch zero-defines
    the whole output and every later launch chains through it in
    accumulate mode (``ops.scv_spmm_plan``), so higher-cap segments never
    pay ``n_row_blocks * cap`` dummy slots again.
    """
    if config is not None:
        if caps is not None:
            raise ValueError("pass caps or config, not both")
        caps = tuple(config.bucket_caps) or (int(config.cap),)
    if caps is None:
        caps = bucket_caps_for(t.nnz_in_tile, t.tile)
    segs = bucket_tiles(t, caps)
    return SCVBucketedPlan(
        tuple(
            plan_from_tiles(
                s,
                ensure_coverage=(ensure_coverage and j == 0),
                with_perm=with_perm,
            )
            for j, s in enumerate(segs)
        )
    )


# ---------------------------------------------------------------------------
# Hybrid dense-tile split (beyond-paper; DESIGN.md §2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DenseTiles:
    """Logical tiles dense enough for the MXU (nnz > T^2 * VPU/MXU)."""

    tile_row: np.ndarray  # int32[nd]
    tile_col: np.ndarray  # int32[nd]
    blocks: np.ndarray  # f32[nd, T, T] densified
    tile: int
    shape: tuple[int, int]

    @property
    def n_tiles(self) -> int:
        return int(self.tile_row.shape[0])


def split_hybrid(
    tiles: SCVTiles, vpu_mxu_ratio: float = MXU_VPU_RATIO
) -> tuple[SCVTiles, DenseTiles]:
    """Partition logical tiles by density: tiles with
    nnz > T^2 * vpu_mxu_ratio run as dense T x T matmuls on the MXU
    (cheaper there than per-entry gather-FMA on the VPU); the ultra-sparse
    rest keeps the SCV gather path (``dense_tile_threshold`` is the same
    rule the Pallas kernel applies per tile in-kernel).  v5e: MXU 16384
    MAC/cyc vs VPU 1024 lane/cyc -> ratio 1/16."""
    T = tiles.tile
    key = tiles.tile_row.astype(np.int64) * (2**32) + tiles.tile_col
    uniq, inv = np.unique(key, return_inverse=True)
    tot = np.zeros(len(uniq), np.int64)
    np.add.at(tot, inv, tiles.nnz_in_tile.astype(np.int64))
    dense_logical = tot > (T * T) * vpu_mxu_ratio
    is_dense = dense_logical[inv]

    def subset(mask):
        return SCVTiles(
            tile_row=tiles.tile_row[mask],
            tile_col=tiles.tile_col[mask],
            rows=tiles.rows[mask],
            cols=tiles.cols[mask],
            vals=tiles.vals[mask],
            nnz_in_tile=tiles.nnz_in_tile[mask],
            tile=T,
            cap=tiles.cap,
            shape=tiles.shape,
            order=tiles.order,
            perm=tiles.perm[mask] if tiles.perm is not None else None,
        )

    sparse = subset(~is_dense)
    dpart = subset(is_dense)
    # densify the dense part (grouped by logical tile)
    dkey = dpart.tile_row.astype(np.int64) * (2**32) + dpart.tile_col
    duniq, dinv = np.unique(dkey, return_inverse=True)
    blocks = np.zeros((len(duniq), T, T), np.float32)
    slot = np.arange(dpart.cap)[None, :]
    keep = slot < dpart.nnz_in_tile[:, None]
    ti = np.repeat(dinv, dpart.cap)[keep.ravel()]
    np.add.at(
        blocks,
        (ti, dpart.rows[keep], dpart.cols[keep]),
        dpart.vals[keep],
    )
    dtiles = DenseTiles(
        tile_row=(duniq >> 32).astype(np.int32),
        tile_col=(duniq & 0xFFFFFFFF).astype(np.int32),
        blocks=blocks,
        tile=T,
        shape=tiles.shape,
    )
    return sparse, dtiles
