"""Z-Morton ordering utilities (paper §III-C).

Z-Morton maps 2-D block coordinates to a 1-D curve position by bit
interleaving, recursively visiting top-left, top-right, bottom-left,
bottom-right quadrants.  The paper uses a *modified* Z-Morton where a set of
column vectors (one B x B tile worth) forms a single curve element; we expose
both the raw interleave and the tile-level ordering.

All functions are pure numpy (format construction is host-side
preprocessing, exactly as the paper's "statically generated from the COO
format" — §III-C) with jnp-compatible variants where needed on device.
"""
from __future__ import annotations

import numpy as np

_PART_MASKS_64 = (
    (0x0000_0000_FFFF_FFFF, 32),
    (0x0000_FFFF_0000_FFFF, 16),
    (0x00FF_00FF_00FF_00FF, 8),
    (0x0F0F_0F0F_0F0F_0F0F, 4),
    (0x3333_3333_3333_3333, 2),
    (0x5555_5555_5555_5555, 1),
)


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of x so there is a 0 bit between each bit."""
    x = x.astype(np.uint64) & np.uint64(0x0000_0000_FFFF_FFFF)
    # descending shifts, each mask paired with its own shift
    for mask, shift in _PART_MASKS_64[1:]:
        x = (x | (x << np.uint64(shift))) & np.uint64(mask)
    return x


def _compact1by1(x: np.ndarray) -> np.ndarray:
    """Inverse of _part1by1: gather every other bit into the low half."""
    x = x.astype(np.uint64) & np.uint64(0x5555_5555_5555_5555)
    # ascending shifts; mask of level i pairs with shift of level i-1
    pairs = [
        (0x3333_3333_3333_3333, 1),
        (0x0F0F_0F0F_0F0F_0F0F, 2),
        (0x00FF_00FF_00FF_00FF, 4),
        (0x0000_FFFF_0000_FFFF, 8),
        (0x0000_0000_FFFF_FFFF, 16),
    ]
    for mask, shift in pairs:
        x = (x | (x >> np.uint64(shift))) & np.uint64(mask)
    return x


def morton_encode(row: np.ndarray, col: np.ndarray) -> np.ndarray:
    """Interleave (row, col) -> Z-curve key.  row occupies the odd bits so
    that the curve sweeps top-left, top-right, bottom-left, bottom-right —
    matching the paper's Fig. 2(e) traversal."""
    row = np.asarray(row)
    col = np.asarray(col)
    return (_part1by1(row) << np.uint64(1)) | _part1by1(col)


def morton_decode(key: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    key = np.asarray(key, dtype=np.uint64)
    row = _compact1by1(key >> np.uint64(1))
    col = _compact1by1(key)
    return row.astype(np.int64), col.astype(np.int64)


def morton_order(rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """argsort of (rows, cols) along the Z curve (stable)."""
    return np.argsort(morton_encode(rows, cols), kind="stable")


def zcurve_tiles(n_tile_rows: int, n_tile_cols: int) -> np.ndarray:
    """Enumerate all (tile_row, tile_col) pairs in Z order.

    Returns an int64 array of shape (n_tile_rows * n_tile_cols, 2).
    Handles non-square / non-power-of-two grids by generating the curve on
    the enclosing power-of-two square and filtering — the standard approach.
    """
    side = 1 << int(np.ceil(np.log2(max(n_tile_rows, n_tile_cols, 1))))
    keys = np.arange(side * side, dtype=np.uint64)
    r, c = morton_decode(keys)
    keep = (r < n_tile_rows) & (c < n_tile_cols)
    return np.stack([r[keep], c[keep]], axis=1)
