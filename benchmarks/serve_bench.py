"""Serving benchmark: batched engine + plan cache vs the naive per-graph loop.

Workload: a mixed stream of requests drawn from a small pool of hot graphs
(the serving regime the plan cache targets).  The naive baseline rebuilds
the SCV plan and runs one forward per request — exactly what a caller of
``build_graph`` + ``gnn_forward`` would write today.  The engine amortizes
preprocessing through the content-addressed plan cache and fuses each wave
into one block-diagonal launch.

Prints ``name,us_per_call,derived`` CSV rows (matching benchmarks/run.py)
and a human summary; exits non-zero if the engine fails to beat the naive
loop or the cache never hits (the PR's acceptance gate).

    PYTHONPATH=src python benchmarks/serve_bench.py
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph


def make_stream(rng, pool, n_requests, d_in):
    stream = []
    for rid in range(n_requests):
        adj = pool[int(rng.integers(len(pool)))]
        x = rng.standard_normal((adj.shape[0], d_in)).astype(np.float32)
        stream.append((rid, adj, x))
    return stream


def run_naive(params, cfg, stream, tile, cap):
    outs = {}
    t0 = time.perf_counter()
    for rid, adj, x in stream:
        g = build_graph(adj, tile=tile, backend_cap=cap)
        outs[rid] = np.asarray(gnn_forward(params, cfg, g, np.asarray(x)))
    return time.perf_counter() - t0, outs


def run_engine(params, cfg, stream, ecfg, wave=16):
    engine = GraphServeEngine({cfg.kind: (params, cfg)}, ecfg)
    t0 = time.perf_counter()
    for i, (rid, adj, x) in enumerate(stream):
        engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model=cfg.kind))
        if (i + 1) % wave == 0:
            engine.run()
    engine.run()
    elapsed = time.perf_counter() - t0
    return elapsed, {r.rid: r.out for r in engine.completed}, engine.metrics()


def main() -> int:
    rng = np.random.default_rng(0)
    d_in, n_requests, tile, cap = 32, 96, 64, 64
    pool = [
        gcn_normalize(powerlaw_graph(n, 4 * n, seed=i))
        for i, n in enumerate([60, 90, 120, 150, 200, 250])
    ]
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=d_in, d_hidden=64,
                    n_classes=8, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    stream = make_stream(rng, pool, n_requests, d_in)
    ecfg = GraphEngineConfig(max_batch_graphs=16, max_batch_nodes=4096,
                             tile=tile, cap=cap)

    # warmup both paths (jit compilation out of the timed region)
    run_naive(params, cfg, stream[:4], tile, cap)
    run_engine(params, cfg, stream[:4], ecfg)

    t_naive, out_naive = run_naive(params, cfg, stream, tile, cap)
    t_engine, out_engine, metrics = run_engine(params, cfg, stream, ecfg)

    err = max(
        float(np.abs(out_naive[rid] - out_engine[rid]).max())
        for rid in out_naive
    )
    naive_gps = n_requests / t_naive
    engine_gps = n_requests / t_engine
    speedup = t_naive / t_engine
    hit_rate = metrics["plan_cache_hit_rate"]

    print("name,us_per_call,derived")
    print(f"serve_naive_loop,{t_naive / n_requests * 1e6:.1f},"
          f"{naive_gps:.1f} graphs/s")
    print(f"serve_engine_batched,{t_engine / n_requests * 1e6:.1f},"
          f"{engine_gps:.1f} graphs/s")
    print(f"serve_speedup,{0.0:.1f},x{speedup:.2f}")
    print()
    print(f"stream: {n_requests} requests over {len(pool)} hot graphs")
    print(f"naive loop   : {naive_gps:8.1f} graphs/s")
    print(f"engine       : {engine_gps:8.1f} graphs/s  (x{speedup:.2f}, "
          f"{metrics['launches']} launches)")
    print(f"plan cache   : hit rate {hit_rate:.0%} "
          f"({metrics['plan_cache_hits']} hits / "
          f"{metrics['plan_cache_misses']} misses, "
          f"{metrics['plan_cache_bytes'] / 1024:.0f} KiB)")
    print(f"max |engine - naive| = {err:.2e}")

    ok = speedup > 1.0 and hit_rate > 0.0 and err < 1e-4
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
