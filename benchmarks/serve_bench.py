"""Serving benchmark: batched engine + plan cache vs the naive per-graph loop,
plus the bucketed-vs-single-cap engine A/B that gates the
``GraphEngineConfig.bucket_caps`` default.

Workload: a mixed stream of requests drawn from a small pool of hot graphs
(the serving regime the plan cache targets).  The naive baseline rebuilds
the SCV plan and runs one forward per request — exactly what a caller of
``build_graph`` + ``gnn_forward`` would write today.  The engine amortizes
preprocessing through the content-addressed plan cache and fuses each wave
into one block-diagonal launch.

Three timed configurations:

* ``naive``      — per-request build + forward (no engine)
* ``single_cap`` — engine with ``bucket_caps=()`` (legacy single-cap plans)
* ``bucketed``   — engine with the default capacity ladder

Prints ``name,us_per_call,derived`` CSV rows (matching benchmarks/run.py),
writes the A/B record to ``BENCH_serve.json``, and exits non-zero if the
engine fails to beat the naive loop, the cache never hits, outputs
diverge, or the bucketed engine regresses the single-cap engine by more
than ``AB_SLACK`` (the no-regression gate for the flipped default).

    PYTHONPATH=src python benchmarks/serve_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph

#: The bucketed engine may not fall below this fraction of the single-cap
#: engine's throughput (timer noise allowance; bucketed wins on padded
#: slots, which only pays off at scale — the gate is "no regression").
AB_SLACK = 0.85

#: Ladder-depth A/B: with accumulator-chained launches the marginal cost
#: of a deeper ladder is one kernel launch (coverage dummies exist once
#: per plan, not once per segment at that segment's cap), so deeper
#: ladders that used to lose on dummy padding get re-measured here.  The
#: default ``GraphEngineConfig.bucket_caps`` must stay within AB_SLACK of
#: the measured winner.
LADDERS = {
    "2deep": (8, 32),
    "3deep": (8, 32, 128),
    "4deep": (8, 32, 128, 512),
}


def make_stream(rng, pool, n_requests, d_in):
    stream = []
    for rid in range(n_requests):
        adj = pool[int(rng.integers(len(pool)))]
        x = rng.standard_normal((adj.shape[0], d_in)).astype(np.float32)
        stream.append((rid, adj, x))
    return stream


def run_naive(params, cfg, stream, tile, cap):
    outs = {}
    t0 = time.perf_counter()
    for rid, adj, x in stream:
        g = build_graph(adj, tile=tile, backend_cap=cap)
        outs[rid] = np.asarray(gnn_forward(params, cfg, g, np.asarray(x)))
    return time.perf_counter() - t0, outs


def run_engine(params, cfg, stream, ecfg, wave=16):
    engine = GraphServeEngine({cfg.kind: (params, cfg)}, ecfg)
    t0 = time.perf_counter()
    for i, (rid, adj, x) in enumerate(stream):
        engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model=cfg.kind))
        if (i + 1) % wave == 0:
            engine.run()
    engine.run()
    elapsed = time.perf_counter() - t0
    return elapsed, {r.rid: r.out for r in engine.completed}, engine.metrics()


def main() -> int:
    rng = np.random.default_rng(0)
    d_in, n_requests, tile, cap = 32, 96, 64, 64
    # sparse power-law pool — the regime the capacity ladder targets: a
    # hub tile forces single-cap padding on every near-empty tile, while
    # the ladder sends those to cap 8 (BENCH_kernel.json `sparse_graph`
    # measures the same effect at 1M edges)
    pool = [
        gcn_normalize(powerlaw_graph(n, 3 * n, seed=i))
        for i, n in enumerate([600, 900, 1200, 1500, 2000, 2500])
    ]
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=d_in, d_hidden=64,
                    n_classes=8, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    stream = make_stream(rng, pool, n_requests, d_in)
    base = dict(max_batch_graphs=16, max_batch_nodes=8192, tile=tile, cap=cap,
                node_buckets=(2048, 4096, 8192))
    ecfg_single = GraphEngineConfig(**base, bucket_caps=())
    ecfg_bucketed = GraphEngineConfig(**base)  # default ladder

    # warmup all paths over the FULL stream: a serving process is
    # long-lived, so the steady state (every padding-bucket shape already
    # traced — retraces are bounded by design) is the regime that matters;
    # engine instances are fresh per run but jit caches are process-wide
    run_naive(params, cfg, stream, tile, cap)
    run_engine(params, cfg, stream, ecfg_single)
    run_engine(params, cfg, stream, ecfg_bucketed)

    # best-of-REPS timing: the A/B ratio is a CI gate, and a one-shot
    # measurement flakes on a single GC pause or scheduler hiccup
    REPS = 2
    t_naive, out_naive = min(
        (run_naive(params, cfg, stream, tile, cap) for _ in range(REPS)),
        key=lambda r: r[0],
    )
    t_single, out_single, m_single = min(
        (run_engine(params, cfg, stream, ecfg_single) for _ in range(REPS)),
        key=lambda r: r[0],
    )
    t_bucketed, out_bucketed, m_bucketed = min(
        (run_engine(params, cfg, stream, ecfg_bucketed) for _ in range(REPS)),
        key=lambda r: r[0],
    )

    # ladder-depth A/B (coverage-free launches)
    ladder_gps = {}
    for name, caps in LADDERS.items():
        ecfg_l = GraphEngineConfig(**base, bucket_caps=caps)
        run_engine(params, cfg, stream, ecfg_l)  # warm jit for this ladder
        t_l, out_l, _ = min(
            (run_engine(params, cfg, stream, ecfg_l) for _ in range(REPS)),
            key=lambda r: r[0],
        )
        ladder_gps[name] = n_requests / t_l
        err_l = max(
            float(np.abs(out_naive[rid] - out_l[rid]).max())
            for rid in out_naive
        )
        assert err_l < 1e-4, (name, err_l)
    ladder_winner = max(ladder_gps, key=ladder_gps.get)

    err = max(
        max(float(np.abs(out_naive[rid] - out_single[rid]).max()),
            float(np.abs(out_naive[rid] - out_bucketed[rid]).max()))
        for rid in out_naive
    )
    naive_gps = n_requests / t_naive
    single_gps = n_requests / t_single
    bucketed_gps = n_requests / t_bucketed
    speedup = t_naive / t_bucketed
    ab_ratio = bucketed_gps / single_gps
    hit_rate = m_bucketed["plan_cache_hit_rate"]

    print("name,us_per_call,derived")
    print(f"serve_naive_loop,{t_naive / n_requests * 1e6:.1f},"
          f"{naive_gps:.1f} graphs/s")
    print(f"serve_engine_single_cap,{t_single / n_requests * 1e6:.1f},"
          f"{single_gps:.1f} graphs/s")
    print(f"serve_engine_bucketed,{t_bucketed / n_requests * 1e6:.1f},"
          f"{bucketed_gps:.1f} graphs/s")
    print(f"serve_speedup,{0.0:.1f},x{speedup:.2f}")
    print(f"serve_bucketed_vs_single,{0.0:.1f},x{ab_ratio:.2f}")
    for name, gps in ladder_gps.items():
        print(f"serve_ladder_{name},{n_requests / gps / n_requests * 1e6:.1f},"
              f"{gps:.1f} graphs/s")
    print()
    print(f"stream: {n_requests} requests over {len(pool)} hot graphs")
    print(f"naive loop        : {naive_gps:8.1f} graphs/s")
    print(f"engine single-cap : {single_gps:8.1f} graphs/s")
    print(f"engine bucketed   : {bucketed_gps:8.1f} graphs/s  (x{speedup:.2f} "
          f"vs naive, {m_bucketed['launches']} launches)")
    print(f"A/B bucketed/single-cap throughput: x{ab_ratio:.2f} "
          f"(gate: >= {AB_SLACK})")
    for name, gps in sorted(ladder_gps.items()):
        mark = " <- winner" if name == ladder_winner else ""
        print(f"ladder {name} {LADDERS[name]}: {gps:8.1f} graphs/s{mark}")
    default_vs_winner = bucketed_gps / ladder_gps[ladder_winner]
    print(f"default ladder vs winner: x{default_vs_winner:.2f} "
          f"(gate: >= {AB_SLACK})")
    print(f"plan cache   : hit rate {hit_rate:.0%} "
          f"({m_bucketed['plan_cache_hits']} hits / "
          f"{m_bucketed['plan_cache_misses']} misses, "
          f"{m_bucketed['plan_cache_bytes'] / 1024:.0f} KiB)")
    print(f"max |engine - naive| = {err:.2e}")

    record = {
        "n_requests": n_requests,
        "naive_graphs_per_s": naive_gps,
        "single_cap_graphs_per_s": single_gps,
        "bucketed_graphs_per_s": bucketed_gps,
        "bucketed_vs_single_cap": ab_ratio,
        "ab_slack": AB_SLACK,
        "bucket_caps": list(ecfg_bucketed.bucket_caps),
        "ladder_ab": {
            name: {"caps": list(LADDERS[name]), "graphs_per_s": gps}
            for name, gps in ladder_gps.items()
        },
        "ladder_winner": ladder_winner,
        "hit_rate": hit_rate,
        "max_abs_err": err,
    }
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    out_path.write_text(json.dumps(record, indent=2) + "\n")
    print(f"wrote {out_path}")

    ok = (
        speedup > 1.0
        and hit_rate > 0.0
        and err < 1e-4
        and ab_ratio >= AB_SLACK
        and default_vs_winner >= AB_SLACK
    )
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
