"""Serving benchmark: batched engine + plan cache vs the naive per-graph loop,
plus the bucketed-vs-single-cap engine A/B that gates the
``GraphEngineConfig.bucket_caps`` default.

Workload: a mixed stream of requests drawn from a small pool of hot graphs
(the serving regime the plan cache targets).  The naive baseline rebuilds
the SCV plan and runs one forward per request — exactly what a caller of
``build_graph`` + ``gnn_forward`` would write today.  The engine amortizes
preprocessing through the content-addressed plan cache and fuses each wave
into one block-diagonal launch.

Three timed configurations:

* ``naive``      — per-request build + forward (no engine)
* ``single_cap`` — engine with ``bucket_caps=()`` (legacy single-cap plans)
* ``bucketed``   — engine with the default capacity ladder

A fourth section drives the engine under **Poisson open-loop load**
(``repro.launch.graph_serve``): arrivals follow an exponential clock that
does not wait for completions, so queueing delay is measured instead of
hidden.  The async scheduler loop (continuous batching, mid-flight
coalescing) is compared against the synchronous wave drain at two
offered-load points derived from a capacity probe — equal load below
saturation (latency gate: async p99 must not exceed sync p99) and well
past saturation (throughput gate: async must hold ``OPEN_LOOP_SAT_SLACK``
of sync graphs/s).

Prints ``name,us_per_call,derived`` CSV rows (matching benchmarks/run.py),
writes the A/B record to ``BENCH_serve.json``, and exits non-zero if the
engine fails to beat the naive loop, the cache never hits, outputs
diverge (closed- or open-loop), the bucketed engine regresses the
single-cap engine by more than ``AB_SLACK``, the measured ladder winner
beats the default ladder by more than ``LADDER_AB_SLACK``, or an
open-loop gate fails.

    PYTHONPATH=src python benchmarks/serve_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.launch.graph_serve import (
    make_requests,
    poisson_arrivals,
    run_open_loop,
)
from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph

#: The bucketed engine may not fall below this fraction of the single-cap
#: engine's throughput (timer noise allowance; bucketed wins on padded
#: slots, which only pays off at scale — the gate is "no regression").
AB_SLACK = 0.85

#: Ladder-depth A/B: with accumulator-chained launches the marginal cost
#: of a deeper ladder is one kernel launch (coverage dummies exist once
#: per plan, not once per segment at that segment's cap), so deeper
#: ladders that used to lose on dummy padding get re-measured here.  The
#: default ``DEFAULT_LADDER`` is expected to *be* the measured winner —
#: this slack band only absorbs timer noise when two depths are within a
#: few percent of each other.  A winner that beats the default by more
#: than this band means the recorded default has gone stale: fail and
#: flip the default (core/scv.py) to the measured winner.
LADDER_AB_SLACK = 0.9
#: Interleaved timing rounds for the ladder sweep (best-of per depth).
LADDER_REPS = 5
LADDERS = {
    "2deep": (8, 32),
    "3deep": (8, 32, 128),
    "4deep": (8, 32, 128, 512),
}

#: Open-loop gate: at saturation the async loop must hold at least this
#: fraction of the sync drain's graphs/s (both modes form node-budget-full
#: waves under deep backlog, so this is a no-regression bound; the async
#: headline is the latency gate at equal offered load, which has no slack).
OPEN_LOOP_SAT_SLACK = 0.9
#: Interleaved measurement rounds per mode per load point; gates read the
#: per-mode best (min p99 / max graphs/s) so one contended round on a
#: shared box cannot flip a gate.
OPEN_LOOP_ROUNDS = 3


def make_stream(rng, pool, n_requests, d_in):
    stream = []
    for rid in range(n_requests):
        adj = pool[int(rng.integers(len(pool)))]
        x = rng.standard_normal((adj.shape[0], d_in)).astype(np.float32)
        stream.append((rid, adj, x))
    return stream


def run_naive(params, cfg, stream, tile, cap):
    outs = {}
    t0 = time.perf_counter()
    for rid, adj, x in stream:
        g = build_graph(adj, tile=tile, backend_cap=cap)
        outs[rid] = np.asarray(gnn_forward(params, cfg, g, np.asarray(x)))
    return time.perf_counter() - t0, outs


def run_engine(params, cfg, stream, ecfg, wave=16):
    engine = GraphServeEngine({cfg.kind: (params, cfg)}, ecfg)
    t0 = time.perf_counter()
    for i, (rid, adj, x) in enumerate(stream):
        engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model=cfg.kind))
        if (i + 1) % wave == 0:
            engine.run()
    engine.run()
    elapsed = time.perf_counter() - t0
    return elapsed, {r.rid: r.out for r in engine.completed}, engine.metrics()


def open_loop_ab(params, cfg, base, pool, d_in, n_requests, seed=7):
    """Sync-vs-async A/B under Poisson open-loop load.

    Rates are derived from a pre-queued capacity probe so the same two
    regimes appear on any machine: ``equal`` offers half the probed
    capacity (both modes admit everything; the gate is latency) and
    ``sat`` offers 3x capacity (deep backlog; the gate is throughput).
    Each mode runs one off-the-clock warmup per load point (traces the
    regime's wave shapes) and then ``OPEN_LOOP_ROUNDS`` interleaved
    measured rounds; gates read the per-mode best round.
    """
    import dataclasses

    models = {cfg.kind: (params, cfg)}
    ecfg_sync = GraphEngineConfig(**base)
    # the async mode gets a real absorb window: coalescing arrivals into
    # fuller waves is the continuous-batching lever (sync has no knob).
    # 25ms spans a few inter-arrival gaps at the equal-load rate, so a
    # forming wave absorbs ~2-3 extra members instead of snapshotting 1-2
    ecfg_async = dataclasses.replace(ecfg_sync, max_wave_delay_ms=25.0)

    def probe():
        eng = GraphServeEngine(models, ecfg_sync)
        reqs = make_requests(
            np.random.default_rng(seed), pool, n_requests, d_in
        )
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        return n_requests / (time.perf_counter() - t0)

    probe()  # warm
    capacity = max(probe(), probe())

    def one_run(mode, rate):
        eng = GraphServeEngine(
            models, ecfg_async if mode == "async" else ecfg_sync
        )
        reqs = make_requests(
            np.random.default_rng(seed), pool, n_requests, d_in
        )
        arr = poisson_arrivals(
            np.random.default_rng(seed + 1), n_requests, rate
        )
        return run_open_loop(eng, reqs, arr, mode=mode)

    results = {"capacity_graphs_per_s": capacity}
    parity_outputs = None
    # equal load sits at 0.7x the pre-queued probe: live arrivals cost
    # more than a drain (pacing, shallow-queue waves), so this lands just
    # past the sync knee — snapshot waves backlog while coalesced waves
    # keep up, which is exactly the regime continuous batching exists for
    for tag, rate in (("equal", 0.7 * capacity), ("sat", 3.0 * capacity)):
        rounds = {"sync": [], "async": []}
        for mode in ("sync", "async"):
            one_run(mode, rate)  # warm this regime's wave shapes
        for _ in range(OPEN_LOOP_ROUNDS):
            for mode in ("sync", "async"):
                rounds[mode].append(one_run(mode, rate))
        results[tag] = {"rate_hz": rate}
        for mode in ("sync", "async"):
            best_gps = max(rounds[mode], key=lambda s: s["graphs_per_s"])
            best_p99 = min(rounds[mode], key=lambda s: s["p99_ms"])
            results[tag][mode] = {
                "graphs_per_s": best_gps["graphs_per_s"],
                "p50_ms": best_p99["p50_ms"],
                "p99_ms": best_p99["p99_ms"],
                "completed": best_gps["completed"],
            }
        if tag == "equal":
            parity_outputs = rounds["async"][-1]["outputs"]

    # exact-output parity: every async open-loop output against the
    # unbatched per-graph forward (fresh build, no engine)
    reqs = make_requests(np.random.default_rng(seed), pool, n_requests, d_in)
    err = 0.0
    for rid, out in parity_outputs.items():
        g = build_graph(reqs[rid].adj, tile=base["tile"],
                        backend_cap=base["cap"])
        ref = np.asarray(gnn_forward(params, cfg, g, reqs[rid].x))
        err = max(err, float(np.abs(out - ref).max()))
    results["max_abs_err"] = err
    return results


def main() -> int:
    rng = np.random.default_rng(0)
    d_in, n_requests, tile, cap = 32, 96, 64, 64
    # sparse power-law pool — the regime the capacity ladder targets: a
    # hub tile forces single-cap padding on every near-empty tile, while
    # the ladder sends those to cap 8 (BENCH_kernel.json `sparse_graph`
    # measures the same effect at 1M edges)
    pool = [
        gcn_normalize(powerlaw_graph(n, 3 * n, seed=i))
        for i, n in enumerate([600, 900, 1200, 1500, 2000, 2500])
    ]
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=d_in, d_hidden=64,
                    n_classes=8, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    stream = make_stream(rng, pool, n_requests, d_in)
    base = dict(max_batch_graphs=16, max_batch_nodes=8192, tile=tile, cap=cap,
                node_buckets=(2048, 4096, 8192))
    ecfg_single = GraphEngineConfig(**base, bucket_caps=())
    ecfg_bucketed = GraphEngineConfig(**base)  # default ladder

    # warmup all paths over the FULL stream: a serving process is
    # long-lived, so the steady state (every padding-bucket shape already
    # traced — retraces are bounded by design) is the regime that matters;
    # engine instances are fresh per run but jit caches are process-wide
    run_naive(params, cfg, stream, tile, cap)
    run_engine(params, cfg, stream, ecfg_single)
    run_engine(params, cfg, stream, ecfg_bucketed)

    # best-of-REPS timing: the A/B ratio is a CI gate, and a one-shot
    # measurement flakes on a single GC pause or scheduler hiccup
    REPS = 2
    t_naive, out_naive = min(
        (run_naive(params, cfg, stream, tile, cap) for _ in range(REPS)),
        key=lambda r: r[0],
    )
    t_single, out_single, m_single = min(
        (run_engine(params, cfg, stream, ecfg_single) for _ in range(REPS)),
        key=lambda r: r[0],
    )
    t_bucketed, out_bucketed, m_bucketed = min(
        (run_engine(params, cfg, stream, ecfg_bucketed) for _ in range(REPS)),
        key=lambda r: r[0],
    )

    # ladder-depth A/B (coverage-free launches).  The default ladder is
    # measured *inside* the sweep — same stream, same reps, same timer —
    # so default == winner compares a number to itself (ratio exactly 1.0)
    # instead of to a separately-timed run that can drift by noise.
    ladders = dict(LADDERS)
    default_caps = tuple(ecfg_bucketed.bucket_caps)
    default_name = next(
        (n for n, c in ladders.items() if tuple(c) == default_caps), None
    )
    if default_name is None:  # config drift: sweep the default anyway
        default_name = "default"
        ladders[default_name] = default_caps
    # interleaved rounds (config A, B, C, A, B, C, ...) so slow machine
    # phases hit every ladder equally; per-ladder best-of filters the
    # noise floor (observed spread between depths is ~5%, well inside
    # LADDER_AB_SLACK once interleaved)
    ladder_cfgs = {
        name: GraphEngineConfig(**base, bucket_caps=caps)
        for name, caps in ladders.items()
    }
    ladder_t: dict[str, float] = {}
    for name, ecfg_l in ladder_cfgs.items():
        _, out_l, _ = run_engine(params, cfg, stream, ecfg_l)  # warm jit
        err_l = max(
            float(np.abs(out_naive[rid] - out_l[rid]).max())
            for rid in out_naive
        )
        assert err_l < 1e-4, (name, err_l)
    for _ in range(LADDER_REPS):
        for name, ecfg_l in ladder_cfgs.items():
            t_l, _, _ = run_engine(params, cfg, stream, ecfg_l)
            ladder_t[name] = min(ladder_t.get(name, t_l), t_l)
    ladder_gps = {name: n_requests / t for name, t in ladder_t.items()}
    ladder_winner = max(ladder_gps, key=ladder_gps.get)

    err = max(
        max(float(np.abs(out_naive[rid] - out_single[rid]).max()),
            float(np.abs(out_naive[rid] - out_bucketed[rid]).max()))
        for rid in out_naive
    )
    naive_gps = n_requests / t_naive
    single_gps = n_requests / t_single
    bucketed_gps = n_requests / t_bucketed
    speedup = t_naive / t_bucketed
    ab_ratio = bucketed_gps / single_gps
    hit_rate = m_bucketed["plan_cache_hit_rate"]

    print("name,us_per_call,derived")
    print(f"serve_naive_loop,{t_naive / n_requests * 1e6:.1f},"
          f"{naive_gps:.1f} graphs/s")
    print(f"serve_engine_single_cap,{t_single / n_requests * 1e6:.1f},"
          f"{single_gps:.1f} graphs/s")
    print(f"serve_engine_bucketed,{t_bucketed / n_requests * 1e6:.1f},"
          f"{bucketed_gps:.1f} graphs/s")
    print(f"serve_speedup,{0.0:.1f},x{speedup:.2f}")
    print(f"serve_bucketed_vs_single,{0.0:.1f},x{ab_ratio:.2f}")
    for name, gps in ladder_gps.items():
        print(f"serve_ladder_{name},{n_requests / gps / n_requests * 1e6:.1f},"
              f"{gps:.1f} graphs/s")
    print()
    print(f"stream: {n_requests} requests over {len(pool)} hot graphs")
    print(f"naive loop        : {naive_gps:8.1f} graphs/s")
    print(f"engine single-cap : {single_gps:8.1f} graphs/s")
    print(f"engine bucketed   : {bucketed_gps:8.1f} graphs/s  (x{speedup:.2f} "
          f"vs naive, {m_bucketed['launches']} launches)")
    print(f"A/B bucketed/single-cap throughput: x{ab_ratio:.2f} "
          f"(gate: >= {AB_SLACK})")
    for name, gps in sorted(ladder_gps.items()):
        mark = " <- winner" if name == ladder_winner else ""
        mark += " (default)" if name == default_name else ""
        print(f"ladder {name} {ladders[name]}: {gps:8.1f} graphs/s{mark}")
    default_vs_winner = ladder_gps[default_name] / ladder_gps[ladder_winner]
    print(f"default ladder vs winner: x{default_vs_winner:.2f} "
          f"(gate: >= {LADDER_AB_SLACK})")
    print(f"plan cache   : hit rate {hit_rate:.0%} "
          f"({m_bucketed['plan_cache_hits']} hits / "
          f"{m_bucketed['plan_cache_misses']} misses, "
          f"{m_bucketed['plan_cache_bytes'] / 1024:.0f} KiB)")
    print(f"max |engine - naive| = {err:.2e}")

    # ---- open-loop sync vs async (continuous batching) -------------------
    ol = open_loop_ab(params, cfg, base, pool, d_in, n_requests)
    eq, sat = ol["equal"], ol["sat"]
    print()
    print(f"open-loop: capacity probe {ol['capacity_graphs_per_s']:.1f} "
          f"graphs/s (pre-queued sync drain)")
    for tag, res in (("equal", eq), ("sat", sat)):
        for mode in ("sync", "async"):
            r = res[mode]
            print(f"open-loop {tag:5s} ({res['rate_hz']:5.0f}/s) {mode:5s}: "
                  f"{r['graphs_per_s']:6.1f} graphs/s  "
                  f"p50 {r['p50_ms']:7.1f}ms  p99 {r['p99_ms']:7.1f}ms")
            print(f"serve_open_{tag}_{mode},{0.0:.1f},"
                  f"p99={r['p99_ms']:.1f}ms {r['graphs_per_s']:.1f} graphs/s")
    ol_latency_ok = eq["async"]["p99_ms"] <= eq["sync"]["p99_ms"]
    sat_ratio = (sat["async"]["graphs_per_s"]
                 / sat["sync"]["graphs_per_s"])
    print(f"open-loop p99 async/sync at equal load: "
          f"x{eq['async']['p99_ms'] / eq['sync']['p99_ms']:.2f} (gate: <= 1)")
    print(f"open-loop graphs/s async/sync at saturation: x{sat_ratio:.2f} "
          f"(gate: >= {OPEN_LOOP_SAT_SLACK})")
    print(f"open-loop max |async - naive| = {ol['max_abs_err']:.2e}")

    record = {
        "n_requests": n_requests,
        "naive_graphs_per_s": naive_gps,
        "single_cap_graphs_per_s": single_gps,
        "bucketed_graphs_per_s": bucketed_gps,
        "bucketed_vs_single_cap": ab_ratio,
        "ab_slack": AB_SLACK,
        "ladder_ab_slack": LADDER_AB_SLACK,
        "bucket_caps": list(ecfg_bucketed.bucket_caps),
        "ladder_ab": {
            name: {"caps": list(ladders[name]), "graphs_per_s": gps}
            for name, gps in ladder_gps.items()
        },
        "ladder_winner": ladder_winner,
        "ladder_default": default_name,
        "default_vs_winner": default_vs_winner,
        "hit_rate": hit_rate,
        "max_abs_err": err,
        "open_loop": {
            "capacity_graphs_per_s": ol["capacity_graphs_per_s"],
            "sat_slack": OPEN_LOOP_SAT_SLACK,
            "rounds": OPEN_LOOP_ROUNDS,
            "equal": eq,
            "sat": sat,
            "max_abs_err": ol["max_abs_err"],
        },
    }
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    out_path.write_text(json.dumps(record, indent=2) + "\n")
    print(f"wrote {out_path}")

    ok = (
        speedup > 1.0
        and hit_rate > 0.0
        and err < 1e-4
        and ab_ratio >= AB_SLACK
        and default_vs_winner >= LADDER_AB_SLACK
        and ol_latency_ok
        and sat_ratio >= OPEN_LOOP_SAT_SLACK
        and ol["max_abs_err"] < 1e-4
    )
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
