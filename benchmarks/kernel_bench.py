"""SCV SpMM kernel benchmark: vectorized/bucketed vs scalar-loop body.

The PR gate for the hybrid MXU/VPU kernel rework (DESIGN.md §2): on a
1M-edge power-law graph, the vectorized chunk body (one-hot scatter/gather
matmuls + in-kernel dense-tile densification) over an nnz-bucketed plan
must beat the pre-rework scalar per-entry FMA loop by >= MIN_SPEEDUP x —
measured in Pallas **interpret mode** on CPU, the only execution this
container has.  Interpret mode exaggerates per-op dispatch and mutes MXU
parallelism, so the measured ratio is a *lower bound* on the compiled-TPU
win (the scalar body is serial on real hardware too; the vector body maps
to MXU issue).

Correctness is asserted alongside timing: with integer-valued inputs every
partial sum is exactly representable in f32, so the scalar kernel, the
vectorized bucketed kernel, and the jnp reference must agree **bit for
bit** regardless of accumulation order.

Results land in ``BENCH_kernel.json`` (repo root) and as
``name,us_per_call,derived`` CSV rows matching benchmarks/run.py.

    PYTHONPATH=src python benchmarks/kernel_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np

import jax.numpy as jnp

from repro.core.formats import COOMatrix
from repro.core.scv import (
    SCVBucketedPlan,
    bucket_caps_for,
    bucket_tiles,
    coo_to_scv_tiles,
    plan_from_tiles,
    plan_from_tiles_bucketed,
    tile_nnz_histogram,
)
from repro.simul.datasets import powerlaw_graph
from repro.kernels.scv_spmm import ops as kops
from repro.kernels.scv_spmm import ref as kref

N_NODES = 2048
N_EDGES = 1_000_000
TILE = 64
FEATURES = 128
MIN_SPEEDUP = 3.0
#: Coverage-free accumulator-chained launches vs the pre-rework structure
#: (per-segment coverage dummies, independent zero-init launches,
#: partial-output sum tree).  Interpret mode is systematically unkind to
#: the chain: every accumulate-mode grid step materializes the aliased
#: acc block as a real fetch+copy (~0.2 ms/step here), whereas on
#: compiled TPU that DMA is double-buffered and the chain *removes* HBM
#: traffic (no N partial outputs written + re-read by a sum tree) and the
#: higher-cap segments' dummy slots.  Measured x0.66-0.73 on this host;
#: the gate bounds regression of that ratio (a VJP blowup or an extra
#: copy in the chain would sink it), and the slot gate below asserts the
#: structural win the chain exists for.
CHAIN_GATE = 0.5
ALPHA = 2.1  # Zipf exponent of the degree weights


def powerlaw_edges(n: int, m: int, seed: int = 0) -> COOMatrix:
    """Exactly ``m`` unique edges with Zipf-weighted endpoints.

    ``simul.datasets.powerlaw_graph`` overdraws by a fixed 15% and can fall
    short of ``m`` after dedup on small node sets; the gate needs the edge
    count pinned, so draw in rounds until ``m`` unique pairs exist."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (ALPHA - 1.0))
    rng.shuffle(w)
    p = w / w.sum()
    keys: np.ndarray = np.zeros(0, np.int64)
    while len(keys) < m:
        draw = int((m - len(keys)) * 1.5) + 1024
        src = rng.choice(n, size=draw, p=p)
        dst = rng.choice(n, size=draw, p=p)
        keys = np.unique(np.concatenate([keys, src.astype(np.int64) * n + dst]))
    rng.shuffle(keys)
    keys = keys[:m]
    rows = (keys // n).astype(np.int32)
    cols = (keys % n).astype(np.int32)
    # small integer weights: every partial sum stays exactly representable
    # in f32, so any accumulation order yields identical bits
    vals = rng.integers(1, 4, size=m).astype(np.float32)
    return COOMatrix(rows, cols, vals, (n, n))


def _time(fn, reps: int = 3) -> float:
    fn()  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> int:
    adj = powerlaw_edges(N_NODES, N_EDGES)
    z = jnp.asarray(
        np.random.default_rng(1)
        .integers(-4, 5, size=(N_NODES, FEATURES))
        .astype(np.float32)
    )

    counts = tile_nnz_histogram(adj, TILE)
    caps = bucket_caps_for(counts, TILE)
    tiles = coo_to_scv_tiles(adj, TILE, cap=caps[-1])
    # the pre-rework layout: one global cap (the hub tiles' cap) for all
    mono = plan_from_tiles(tiles, with_perm=False)
    bucketed = plan_from_tiles_bucketed(tiles, caps=caps)
    # the pre-rework bucketed structure: EVERY segment carries coverage
    # dummies at its own cap and runs as an independent zero-init launch,
    # with the outputs combined by a partial-sum tree
    legacy = SCVBucketedPlan(
        tuple(
            plan_from_tiles(s, ensure_coverage=True, with_perm=False)
            for s in bucket_tiles(tiles, caps)
        )
    )

    def scalar_run():
        out = kops.scv_spmm_plan(mono, z, interpret=True, body="scalar")
        out.block_until_ready()
        return out

    def vector_run():
        out = kops.scv_spmm_plan(bucketed, z, interpret=True, body="vector")
        out.block_until_ready()
        return out

    def persum_run():
        out = sum(
            kops.scv_spmm(
                s.tile_row, s.tile_col, s.rows, s.cols, s.vals, z,
                tile=s.tile, n_rows=s.padded_shape[0],
                nnz_in_tile=s.nnz_in_tile, interpret=True, body="vector",
            )
            for s in legacy.segments
        )
        out.block_until_ready()
        return out

    def ref_run():
        out = kref.scv_spmm_reference_plan(bucketed, z)
        out.block_until_ready()
        return out

    # bit-exact equivalence (integer-valued inputs -> order-independent)
    out_scalar = np.asarray(scalar_run())
    out_vector = np.asarray(vector_run())
    out_persum = np.asarray(persum_run())
    out_ref = np.asarray(ref_run())
    assert np.array_equal(out_vector, out_ref), "vector kernel != reference"
    assert np.array_equal(out_scalar, out_ref), "scalar kernel != reference"
    assert np.array_equal(out_persum, out_ref), "per-segment sum != reference"

    t_scalar = _time(scalar_run, reps=1)  # the slow side: one steady rep
    t_vector = _time(vector_run, reps=3)
    t_persum = _time(persum_run, reps=3)
    t_ref = _time(ref_run, reps=3)
    speedup = t_scalar / t_vector
    chain_vs_persum = t_persum / t_vector

    pad_mono = float(mono.n_tiles * mono.cap) / tiles.nnz
    pad_bucket = (
        sum(s.n_tiles * s.cap for s in bucketed.segments) / tiles.nnz
    )

    # Bucketing's host/HBM headline is the *sparse* serving-scale regime
    # (~1 entry per tile, one hub cap inflating everything): measure slot
    # totals there host-side (the kernel timing above stays on the compact
    # graph, where interpret-mode grid overhead doesn't drown the signal).
    sp = powerlaw_graph(1 << 17, N_EDGES, seed=0)
    sp_caps = bucket_caps_for(tile_nnz_histogram(sp, TILE), TILE)
    sp_tiles = coo_to_scv_tiles(sp, TILE, cap=sp_caps[-1])
    sp_mono_slots = sp_tiles.n_tiles * sp_tiles.cap
    sp_bucket_slots = sum(
        s.n_tiles * s.cap for s in bucket_tiles(sp_tiles, sp_caps)
    )
    # padded-slot totals of the plans actually launched (tile slots plus
    # coverage dummies): first-segment-only coverage drops every higher-cap
    # segment's n_row_blocks * cap dummy slots from the old layout
    sp_segs = bucket_tiles(sp_tiles, sp_caps)
    sp_plan_slots = sum(
        p.n_tiles * p.cap
        for p in plan_from_tiles_bucketed(sp_tiles, caps=sp_caps).segments
    )
    sp_legacy_slots = sum(
        plan_from_tiles(s, ensure_coverage=True, with_perm=False).n_tiles
        * s.cap
        for s in sp_segs
    )

    print("name,us_per_call,derived")
    print(
        f"kernel_scalar_1m,{t_scalar * 1e6:.0f},"
        f"{N_EDGES / t_scalar / 1e6:.2f} Medges/s"
    )
    print(
        f"kernel_vector_bucketed_1m,{t_vector * 1e6:.0f},"
        f"{N_EDGES / t_vector / 1e6:.2f} Medges/s"
    )
    print(f"kernel_jnp_ref_1m,{t_ref * 1e6:.0f},{N_EDGES / t_ref / 1e6:.2f} Medges/s")
    print(
        f"kernel_per_segment_sum_1m,{t_persum * 1e6:.0f},"
        f"{N_EDGES / t_persum / 1e6:.2f} Medges/s"
    )
    print(
        f"# speedup {speedup:.2f}x (gate >= {MIN_SPEEDUP}x); "
        f"slot inflation {pad_mono:.2f}x mono -> {pad_bucket:.2f}x bucketed; "
        f"caps={caps} tiles={tiles.n_tiles}"
    )
    print(
        f"# coverage-free chain vs per-segment sum: x{chain_vs_persum:.2f} "
        f"(gate >= {CHAIN_GATE}x)"
    )
    print(
        f"# sparse 131k-node graph: {sp_mono_slots} mono slots -> "
        f"{sp_bucket_slots} bucketed ({sp_mono_slots / sp_bucket_slots:.1f}x "
        f"less padding, caps={sp_caps}); launched plan slots incl coverage "
        f"{sp_legacy_slots} per-segment -> {sp_plan_slots} first-segment-only"
    )

    payload = {
        "n_nodes": N_NODES,
        "n_edges": N_EDGES,
        "tile": TILE,
        "features": FEATURES,
        "bucket_caps": list(caps),
        "n_tiles": tiles.n_tiles,
        "scalar_s": t_scalar,
        "vector_bucketed_s": t_vector,
        "per_segment_sum_s": t_persum,
        "jnp_reference_s": t_ref,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
        "chain_vs_per_segment_sum": chain_vs_persum,
        "chain_gate": CHAIN_GATE,
        "slot_inflation_mono": pad_mono,
        "slot_inflation_bucketed": pad_bucket,
        "bit_exact_vs_reference": True,
        "mode": "pallas_interpret_cpu",
        "sparse_graph": {
            "n_nodes": 1 << 17,
            "n_edges": int(sp_tiles.nnz),
            "bucket_caps": list(sp_caps),
            "mono_slots": int(sp_mono_slots),
            "bucketed_slots": int(sp_bucket_slots),
            "slot_reduction": float(sp_mono_slots / sp_bucket_slots),
            "plan_slots_per_segment_coverage": int(sp_legacy_slots),
            "plan_slots_first_segment_coverage": int(sp_plan_slots),
        },
    }
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernel.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if speedup < MIN_SPEEDUP:
        print(
            f"FAIL: vectorized/bucketed kernel {speedup:.2f}x < {MIN_SPEEDUP}x",
            file=sys.stderr,
        )
        return 1
    # The 0.5 floor (not 1.0) is an interpret-mode emulation artifact:
    # each accumulate-mode grid step pays a real fetch+copy of the
    # aliased accumulator block that compiled TPU double-buffers away,
    # so the chain *loses* wall time here (x0.66-0.73 observed) while
    # structurally removing HBM traffic.  The gate only catches
    # regressions of the emulated ratio; the slot-count gate below is
    # the real structural assertion.
    if chain_vs_persum < CHAIN_GATE:
        print(
            f"FAIL: coverage-free chain {chain_vs_persum:.2f}x < "
            f"{CHAIN_GATE}x vs per-segment sum",
            file=sys.stderr,
        )
        return 1
    if sp_plan_slots >= sp_legacy_slots:
        print(
            f"FAIL: launched plan slots did not drop "
            f"({sp_plan_slots} >= {sp_legacy_slots})",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
