"""Preprocessing benchmark: vectorized SCV tile construction at scale.

The paper's practicality argument (§III-C) is that SCV preprocessing is
"nearly equivalent to creating a CSR or CSC matrix" — a couple of sorts
plus linear passes.  That only holds if tile emission is vectorized: the
scalar per-tile loop (kept as ``repro.core.scv._coo_to_scv_tiles_loop``)
is O(n_tiles) Python and dominates at serving scale.

This benchmark builds a 1M-edge synthetic graph, times both emitters,
verifies they produce byte-identical ``SCVTiles``, and gates the
vectorized path at >= MIN_SPEEDUP x.  Results land in
``BENCH_preprocess.json`` (repo root) and as ``name,us_per_call,derived``
CSV rows matching benchmarks/run.py.

    PYTHONPATH=src python benchmarks/preprocess_bench.py
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import numpy as np

from repro.core.formats import COOMatrix
from repro.core.scv import _coo_to_scv_tiles_loop, coo_to_scv_tiles

N_NODES = 1 << 17  # 131072
N_EDGES = 1_000_000
TILE = 64
MIN_SPEEDUP = 5.0


def synth_graph(rng, n: int, e: int) -> COOMatrix:
    """Uniform random graph — the worst case for the loop emitter (nearly
    every entry lands in its own tile, so n_tiles ~ nnz)."""
    rows = rng.integers(0, n, e).astype(np.int32)
    cols = rng.integers(0, n, e).astype(np.int32)
    vals = rng.standard_normal(e).astype(np.float32)
    return COOMatrix(rows, cols, vals, (n, n))


def check_identical(a, b) -> None:
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert va.dtype == vb.dtype and np.array_equal(va, vb), f.name
        else:
            assert va == vb, f.name


def main() -> int:
    rng = np.random.default_rng(0)
    adj = synth_graph(rng, N_NODES, N_EDGES)

    # warm both paths on a small slice (numpy allocator, imports)
    small = COOMatrix(adj.rows[:1000], adj.cols[:1000], adj.vals[:1000], adj.shape)
    coo_to_scv_tiles(small, TILE)
    _coo_to_scv_tiles_loop(small, TILE)

    # best-of-3 for the (cheap) vectorized side: the gate is a wall-clock
    # ratio and one noisy sample on a loaded CI box must not flake it; the
    # loop side is timed once (it is ~10x the cost and noise only helps it)
    t_vec = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        t_vec_tiles = coo_to_scv_tiles(adj, TILE)
        t_vec = min(t_vec, time.perf_counter() - t0)

    t0 = time.perf_counter()
    t_loop_tiles = _coo_to_scv_tiles_loop(adj, TILE)
    t_loop = time.perf_counter() - t0

    check_identical(t_vec_tiles, t_loop_tiles)
    speedup = t_loop / t_vec

    print("name,us_per_call,derived")
    print(f"preprocess_loop_1m,{t_loop * 1e6:.0f},{N_EDGES / t_loop / 1e6:.2f} Medges/s")
    print(f"preprocess_vectorized_1m,{t_vec * 1e6:.0f},{N_EDGES / t_vec / 1e6:.2f} Medges/s")
    print(f"preprocess_speedup,0,x{speedup:.1f}")
    print()
    print(f"graph: {N_EDGES} edges over {N_NODES} nodes, tile={TILE}, "
          f"{t_vec_tiles.n_tiles} tiles (cap {t_vec_tiles.cap})")
    print(f"loop emitter      : {t_loop:7.3f} s")
    print(f"vectorized emitter: {t_vec:7.3f} s  (x{speedup:.1f}, byte-identical)")

    payload = {
        "edges": N_EDGES,
        "nodes": N_NODES,
        "tile": TILE,
        "n_tiles": t_vec_tiles.n_tiles,
        "t_loop_s": t_loop,
        "t_vectorized_s": t_vec,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_preprocess.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")

    ok = speedup >= MIN_SPEEDUP
    print("PASS" if ok else f"FAIL (speedup {speedup:.1f} < {MIN_SPEEDUP})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
