"""Autotuner benchmark: simulator-pruned plan search vs the hand-picked
default config, on both serving regimes (DESIGN.md §8).

The PR gate for the ``repro.tune`` subsystem: the two-stage search
(analytic prune over the simul/ cost model, then short measured
calibration of the survivors) must

* never lose to the default ``TunedConfig`` — the default is always
  measured in the same calibration loop as the survivors, and the winner
  is measured-best, so tuned/default >= 1.0 holds **by construction**;
  the gate asserts the machinery (default control present, timings
  real),
* beat the default **strictly** on at least one regime (the search has
  to find something — if the hand-picked config were optimal everywhere
  the subsystem would be dead weight),
* hit the on-disk cache on re-tune: a fresh ``Autotuner`` sharing the
  store must resolve both regimes with **zero** new searches, and
* report the stage-1 predicted vs stage-2 measured Spearman rank
  correlation — the number that says whether the analytic prune is
  discarding the right candidates.

Regimes: the 131k-node/1M-edge sparse graph of dist_bench and the
2048-node/1M-edge dense graph of kernel_bench (Zipf 2.1 endpoints) — the
two ends of the tile-occupancy spectrum the ladder exists for.

Results land in ``BENCH_autotune.json`` (repo root) and as
``name,us_per_call,derived`` CSV rows matching benchmarks/run.py.

    PYTHONPATH=src python benchmarks/autotune_bench.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

from repro.core.scv import DEFAULT_LADDER, DEFAULT_TILE
from repro.simul.datasets import powerlaw_graph
from repro.tune import Autotuner, TuneStore

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from kernel_bench import powerlaw_edges  # noqa: E402

FEATURES = 32
TOP_K = 2
CALIB_REPS = 2
#: tuned/default measured-speedup floor per regime.  >= 1.0 holds by
#: construction (see module docstring); anything below means the default
#: control went missing from the calibration set.
MIN_SPEEDUP = 1.0
#: at least one regime must beat the default by strictly more than this.
STRICT_SPEEDUP = 1.0

REGIMES = (
    # (name, builder): opposite ends of the tile-occupancy spectrum
    ("sparse_131k", lambda: powerlaw_graph(1 << 17, 1_000_000, seed=0)),
    ("dense_2048", lambda: powerlaw_edges(2048, 1_000_000, seed=0)),
)


def _default_measured(result) -> float:
    """Seconds of the always-measured default-config control run."""
    for c in result.calibrated:
        if (c.config.tile, c.config.bucket_caps) == (DEFAULT_TILE,
                                                     DEFAULT_LADDER):
            return c.measured_s
    raise AssertionError("default control missing from calibration set")


def main() -> int:
    store_path = pathlib.Path(tempfile.mkdtemp(prefix="scv_tune_")) / "tune.json"
    tuner = Autotuner(store=TuneStore(store_path), top_k=TOP_K,
                      calib_reps=CALIB_REPS)

    rows = []
    print("name,us_per_call,derived")
    for name, build in REGIMES:
        adj = build()
        t0 = time.perf_counter()
        cfg = tuner.tune(adj, n_features=FEATURES)
        search_s = time.perf_counter() - t0
        res = tuner.last_result
        tuned_s = min(c.measured_s for c in res.calibrated)
        default_s = _default_measured(res)
        speedup = default_s / tuned_s
        rows.append({
            "regime": name,
            "nnz": int(adj.nnz),
            "tuned": cfg.to_json(),
            "tuned_seconds": tuned_s,
            "default_seconds": default_s,
            "speedup_vs_default": speedup,
            "rank_correlation": res.rank_correlation,
            "n_candidates": len(res.candidates),
            "n_calibrated": len(res.calibrated),
            "search_seconds": search_s,
            "cache_key": res.key,
        })
        print(f"autotune_{name}_tuned,{tuned_s * 1e6:.0f},"
              f"x{speedup:.2f} vs default; tile {cfg.tile} "
              f"caps {list(cfg.bucket_caps) or [cfg.cap]}; "
              f"rank-corr {res.rank_correlation:.2f}")
        print(f"autotune_{name}_default,{default_s * 1e6:.0f},"
              f"search {search_s:.1f}s over {len(res.candidates)} "
              f"candidates ({len(res.calibrated)} measured)")

    # cache-hit leg: a fresh tuner on the same store must re-resolve both
    # regimes without searching (and without re-measuring anything)
    t2 = Autotuner(store=TuneStore(store_path), top_k=TOP_K,
                   calib_reps=CALIB_REPS)
    t0 = time.perf_counter()
    for (name, build), row in zip(REGIMES, rows):
        assert t2.tune(build(), n_features=FEATURES).to_json() == row["tuned"]
    hit_s = time.perf_counter() - t0
    cache_ok = t2.searches == 0 and t2.cache_hits == len(REGIMES)
    print(f"autotune_cache_hit,{hit_s / len(REGIMES) * 1e6:.0f},"
          f"searches {t2.searches} hits {t2.cache_hits} (graph rebuild "
          f"dominates; the search itself is skipped)")

    payload = {
        "features": FEATURES,
        "top_k": TOP_K,
        "calib_reps": CALIB_REPS,
        "min_speedup_gate": MIN_SPEEDUP,
        "strict_speedup_gate": STRICT_SPEEDUP,
        "regimes": rows,
        "cache_hit": {
            "seconds_per_regime": hit_s / len(REGIMES),
            "searches": t2.searches,
            "cache_hits": t2.cache_hits,
        },
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_autotune.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")

    ok = True
    if not all(r["speedup_vs_default"] >= MIN_SPEEDUP for r in rows):
        print("FAIL: tuned config lost to the default control",
              file=sys.stderr)
        ok = False
    if not any(r["speedup_vs_default"] > STRICT_SPEEDUP for r in rows):
        print("FAIL: search never strictly beat the default", file=sys.stderr)
        ok = False
    if not cache_ok:
        print(f"FAIL: cache miss on re-tune (searches={t2.searches}, "
              f"hits={t2.cache_hits})", file=sys.stderr)
        ok = False
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
