"""Streaming benchmark: delta plan maintenance vs full rebuild, and the
serve-layer update-rate / query-throughput trade-off.

The paper's plan is static ("statically generated from the COO format",
§III-C); ``stream/`` makes it maintainable.  Phase A measures the core
claim at preprocessing scale — a small edge delta patched into the
131k-node / 1M-edge power-law plan via ``stream.apply_delta`` must beat
re-running ``coo_to_scv_tiles`` from scratch by >= MIN_SPEEDUP x, and the
patched tiles must be byte-identical to the from-scratch rebuild of the
mutated COO (the rebuild doubles as the parity reference, so correctness
rides the same measurement).  Phase B runs the ``GraphServeEngine`` over
the same graph and interleaves ``update()`` calls with query waves at
increasing rates: updates must land as plan-cache *revalidations*
(patched + re-keyed entries), never as full misses, and the final served
output must match a fresh build of the post-delta adjacency.

Results land in ``BENCH_stream.json`` (repo root) and as
``name,us_per_call,derived`` CSV rows matching benchmarks/run.py.

    PYTHONPATH=src python benchmarks/stream_bench.py
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.core.scv import coo_to_scv_tiles
from repro.models.gnn import GNNConfig, build_graph, gnn_forward, init_gnn
from repro.serve.graph_engine import (
    GraphEngineConfig,
    GraphRequest,
    GraphServeEngine,
)
from repro.simul.datasets import gcn_normalize, powerlaw_graph
from repro.stream import DeltaBatch, apply_coo, apply_delta

N_NODES = 1 << 17  # 131072
N_EDGES = 1_000_000
TILE = 64
CAP = 128
DELTA_EDGES = 64  # edges touched per streaming delta
MIN_SPEEDUP = 10.0


def value_update_delta(rng, adj, k: int, val: float) -> DeltaBatch:
    """A slack-absorbed delta: re-weight ``k`` existing edges (remove +
    re-insert the same coordinates) — the dominant mutation in a serving
    system that re-normalizes weights, and the one the in-place patch
    path absorbs without any layout change."""
    idx = rng.choice(adj.nnz, size=k, replace=False)
    coords = [(int(adj.rows[i]), int(adj.cols[i])) for i in idx]
    return DeltaBatch.of(
        inserts=[(r, c, val) for r, c in coords],
        removes=coords,
    )


def check_identical(a, b) -> None:
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert va.dtype == vb.dtype and np.array_equal(va, vb), f.name
        else:
            assert va == vb, f.name


# ---------------------------------------------------------------------------
# Phase A: apply_delta vs coo_to_scv_tiles rebuild (gated)
# ---------------------------------------------------------------------------
def phase_a(rng, adj):
    t0 = time.perf_counter()
    tiles = coo_to_scv_tiles(adj, TILE, cap=CAP)
    t_build = time.perf_counter() - t0

    # best-of-3 over three *distinct* deltas (each application is live
    # state, so re-applying one delta would be free-riding on warm caches
    # it doesn't have); the tiles advance with every application
    cur = adj
    t_delta = float("inf")
    t_functional = float("inf")
    for rep in range(3):
        d = value_update_delta(rng, cur, DELTA_EDGES, val=1.0 + rep)
        # functional (alias-holder) path first: returns a fresh object,
        # the live tiles are untouched, so the inplace timing below still
        # applies the delta to exactly the same pre-delta state
        t0 = time.perf_counter()
        apply_delta(tiles, d, check=False)
        t_functional = min(t_functional, time.perf_counter() - t0)
        t0 = time.perf_counter()
        apply_delta(tiles, d, inplace=True, check=False)
        t_delta = min(t_delta, time.perf_counter() - t0)
        cur = apply_coo(cur, d, check=False)

    # the from-scratch rebuild of the final COO is both the baseline cost
    # and the byte-parity reference for the patched tiles
    t0 = time.perf_counter()
    rebuilt = coo_to_scv_tiles(cur, TILE, cap=CAP)
    t_rebuild = time.perf_counter() - t0
    check_identical(tiles, rebuilt)

    # serve-layer patch (bucketed Graph, functional — what the plan cache
    # revalidation runs); reported, not gated: the gate is the tiles path
    g = build_graph(adj, tile=TILE, bucket_caps=(8, 32, 128))
    d = value_update_delta(rng, adj, DELTA_EDGES, val=7.5)
    t0 = time.perf_counter()
    apply_delta(g, d, check=False)
    t_graph = time.perf_counter() - t0

    return t_build, t_delta, t_functional, t_rebuild, t_graph, cur


# ---------------------------------------------------------------------------
# Phase B: engine update-rate vs query-throughput (revalidation, not misses)
# ---------------------------------------------------------------------------
def phase_b(rng, adj):
    d_in = 8
    cfg = GNNConfig(name="gcn", kind="gcn", d_in=d_in, d_hidden=16,
                    n_classes=4, backend="jnp")
    params, _ = init_gnn(jax.random.PRNGKey(0), cfg)
    ecfg = GraphEngineConfig(
        max_batch_graphs=1,
        max_batch_nodes=N_NODES,
        tile=TILE,
        node_buckets=(N_NODES,),
        cache_bytes=4 << 30,
    )
    engine = GraphServeEngine({"gcn": (params, cfg)}, ecfg)
    x = rng.standard_normal((adj.shape[0], d_in)).astype(np.float32)

    # register + warm (member build, composite assembly, jit trace)
    rid = 0
    engine.submit(GraphRequest(rid=rid, adj=adj, x=x, model="gcn",
                               graph_id="g0"))
    engine.run()
    rid += 1

    waves_per_rate = 3
    rates = (0, 1, 4)
    results = []
    for rate in rates:
        t0 = time.perf_counter()
        for _ in range(waves_per_rate):
            for u in range(rate):
                adj_now = engine._graphs["g0"].adj
                engine.update(
                    "g0",
                    value_update_delta(rng, adj_now, DELTA_EDGES,
                                       val=float(rng.standard_normal() + 2)),
                )
            engine.submit(GraphRequest(rid=rid, x=x, model="gcn",
                                       graph_id="g0"))
            engine.run()
            rid += 1
        elapsed = time.perf_counter() - t0
        results.append({
            "updates_per_wave": rate,
            "queries_per_s": waves_per_rate / elapsed,
            "updates_per_s": rate * waves_per_rate / elapsed,
        })

    m = engine.metrics()
    out_last = next(r for r in engine.completed if r.rid == rid - 1).out

    # parity: the last wave must serve the *post-delta* adjacency
    final_adj = engine._graphs["g0"].adj
    g_ref = build_graph(final_adj, tile=TILE, bucket_caps=(8, 32, 128))
    ref = np.asarray(gnn_forward(params, cfg, g_ref, x))
    err = float(np.abs(out_last[: ref.shape[0]] - ref).max())
    return results, m, err


def main() -> int:
    rng = np.random.default_rng(0)
    adj = gcn_normalize(powerlaw_graph(N_NODES, N_EDGES))
    print(f"graph: {adj.nnz} edges over {N_NODES} nodes, tile={TILE}, "
          f"cap={CAP}, delta={DELTA_EDGES} edges")

    t_build, t_delta, t_functional, t_rebuild, t_graph, _ = phase_a(rng, adj)
    speedup = t_rebuild / t_delta

    results, m, err = phase_b(rng, adj)
    n_updates = sum(r["updates_per_wave"] for r in results) * 3

    print()
    print("name,us_per_call,derived")
    print(f"stream_rebuild_1m,{t_rebuild * 1e6:.0f},"
          f"{adj.nnz / t_rebuild / 1e6:.2f} Medges/s")
    print(f"stream_apply_delta_{DELTA_EDGES},{t_delta * 1e6:.0f},"
          f"x{speedup:.0f} vs rebuild")
    print(f"stream_apply_functional_{DELTA_EDGES},{t_functional * 1e6:.0f},"
          f"x{t_functional / t_delta:.1f} vs inplace")
    print(f"stream_graph_patch_{DELTA_EDGES},{t_graph * 1e6:.0f},"
          f"bucketed serve plan")
    for r in results:
        print(f"stream_engine_u{r['updates_per_wave']},"
              f"{1e6 / r['queries_per_s']:.0f},"
              f"{r['queries_per_s']:.2f} q/s @ {r['updates_per_s']:.2f} u/s")
    print()
    print(f"full rebuild        : {t_rebuild:7.3f} s (initial build "
          f"{t_build:.3f} s)")
    print(f"apply_delta (tiles) : {t_delta:7.3f} s  (x{speedup:.0f}, "
          "byte-identical to rebuild)")
    print(f"apply_delta (func)  : {t_functional:7.3f} s  (alias-holder "
          "path: copies written leaves)")
    print(f"apply_delta (graph) : {t_graph:7.3f} s  (bucketed serve plan, "
          "functional)")
    for r in results:
        print(f"engine @ {r['updates_per_wave']} upd/wave : "
              f"{r['queries_per_s']:7.2f} queries/s "
              f"({r['updates_per_s']:.2f} updates/s)")
    print(f"plan cache: {m['plan_cache_revalidated']} revalidated / "
          f"{m['graph_updates']} updates "
          f"(build {m['plan_build_seconds']:.1f} s total)")
    print(f"max |engine - fresh build| = {err:.2e}")

    payload = {
        "edges": int(adj.nnz),
        "nodes": N_NODES,
        "tile": TILE,
        "cap": CAP,
        "delta_edges": DELTA_EDGES,
        "t_rebuild_s": t_rebuild,
        "t_apply_delta_s": t_delta,
        "t_apply_functional_s": t_functional,
        "t_graph_patch_s": t_graph,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
        "engine": results,
        "revalidated": m["plan_cache_revalidated"],
        "graph_updates": m["graph_updates"],
        "max_abs_err": err,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_stream.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")

    ok = (
        speedup >= MIN_SPEEDUP
        # every engine update must revalidate the cached plan (patch +
        # re-key), never degrade to a full rebuild miss
        and m["plan_cache_revalidated"] == n_updates == m["graph_updates"]
        and n_updates > 0
        and err < 1e-4
    )
    print("PASS" if ok else
          f"FAIL (speedup {speedup:.1f} < {MIN_SPEEDUP} or "
          f"revalidated {m['plan_cache_revalidated']} != {n_updates} or "
          f"err {err:.2e})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
