"""Benchmark harness: one function per paper table/figure (+ kernel
microbench and the dry-run roofline table when artifacts exist).

Prints ``name,us_per_call,derived`` CSV rows (simulated cycles at 1 GHz ->
us) and writes the full row dumps to results/benchmarks.json.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _kernel_microbench():
    """Wall-clock of the SCV aggregation backends on CPU (relative numbers
    only — the TPU path is characterized by the dry-run roofline)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import coo_to_scv_tiles, coo_to_csr
    from repro.core.aggregate import (aggregate, aggregate_scv_tiles,
                                      scv_device_arrays)
    from repro.simul.datasets import gcn_normalize, powerlaw_graph

    adj = gcn_normalize(powerlaw_graph(20_000, 100_000, seed=0))
    f = 128
    z = jnp.asarray(np.random.default_rng(0).standard_normal(
        (adj.shape[1], f)).astype(np.float32))
    rows = []
    tiles = coo_to_scv_tiles(adj, 64)
    csr = coo_to_csr(adj)

    def timeit(fn, n=5):
        fn().block_until_ready()
        t0 = time.time()
        for _ in range(n):
            out = fn()
        out.block_until_ready()
        return (time.time() - t0) / n * 1e6

    t_scv = timeit(lambda: aggregate_scv_tiles(tiles, z, backend="jnp"))
    t_csr = timeit(lambda: aggregate(csr, z))
    rows.append({"figure": "kernel", "name": "scv_jnp_cpu", "us_per_call": t_scv,
                 "derived": f"csr/scv={t_csr/t_scv:.2f}"})
    rows.append({"figure": "kernel", "name": "csr_segsum_cpu", "us_per_call": t_csr,
                 "derived": ""})
    return rows


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from benchmarks.figures import ALL_FIGURES

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in ALL_FIGURES.items():
        if only and only not in (name,):
            continue
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        all_rows.extend(rows)
        # emit the headline geomean rows as CSV
        for r in rows:
            if str(r.get("dataset", "")).startswith("geomean"):
                key = [str(r.get(k)) for k in ("baseline", "ours", "height", "width",
                                               "processors", "format", "block")
                       if r.get(k) is not None]
                metric = next((r[k] for k in ("speedup", "reduction",
                                              "improvement_vs_csr",
                                              "speedup_vs_128", "slowdown_vs_w1")
                               if k in r), "")
                us = r.get("total_scv_cycles", r.get("cycles_scv", ""))
                us = f"{us/1e3:.1f}" if us else ""
                print(f"{name}:{r['dataset']}:{':'.join(key)},{us},{metric:.3f}"
                      if metric != "" else f"{name}:{r['dataset']},{us},")
        print(f"# {name} done in {dt:.1f}s ({len(rows)} rows)", flush=True)

    if only is None or only == "kernel":
        for r in _kernel_microbench():
            print(f"{r['figure']}:{r['name']},{r['us_per_call']:.1f},{r['derived']}")
            all_rows.append(r)

    if only is None or only == "kernel_roofline":
        from benchmarks.kernel_roofline import main as kr_main

        print("# SCV kernel roofline / hybrid analysis (EXPERIMENTS §Perf cell K)")
        kr_rows = kr_main()
        all_rows.extend({"figure": "kernel_roofline", **r} for r in kr_rows)

    # roofline table from dry-run artifacts, if present
    path = "results/dryrun_single_pod.json"
    if (only is None or only == "roofline") and os.path.exists(path):
        from benchmarks.roofline import build_table, format_table

        table = build_table(path)
        print(format_table(table))
        for r in table:
            print(f"roofline:{r['arch']}:{r['shape']},,"
                  f"{r['bottleneck']}:{100*r['roofline_fraction']:.1f}%")
        all_rows.extend({"figure": "roofline", **r} for r in table)

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as fh:
        json.dump(all_rows, fh, indent=1, default=str)
    print(f"# wrote results/benchmarks.json ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
