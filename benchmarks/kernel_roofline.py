"""SCV Pallas-kernel roofline + hillclimb (EXPERIMENTS.md §Perf cell K).

The TPU kernel cannot be Mosaic-compiled in this CPU container, so its
roofline is derived structurally from the tile layout — the same
quantities the BlockSpecs move:

  A bytes   = entry payloads (val f32 + 2 x i32 locals, padded to cap)
  Z bytes   = one (T x F) block per tile *minus* Pallas's skip-refetch when
              consecutive tiles share a column block (the SCV reuse the
              paper's Fig. 2(d) arrow shows) — plus cross-row reuse counted
              with a 16 MiB VMEM-resident window model
  PS bytes  = one (T x F) f32 strip write per PS block-row visit
  FLOPs     = 2 x nnz x F (useful) ; MXU-densified tiles pay 2 x T^2 x F

Hybrid dense-tile selection (beyond-paper, DESIGN.md §2): a tile is
cheaper on the MXU than on the VPU gather-FMA path when

  T*T*F / MXU_rate  <  nnz * F / VPU_rate   =>   nnz > T^2 * VPU/MXU

(v5e: MXU 16384 MAC/cycle, VPU 1024 FMA-lane/cycle => nnz > T^2/16).
The threshold and the capacity-bucket ladder are imported from
``core.scv`` — the same constants the Pallas kernel executes with
(``dense_tile_threshold``, ``bucket_caps_for``) — so this model cannot
drift from the implementation.
"""
from __future__ import annotations

import numpy as np

from repro.core import coo_to_scv_tiles
from repro.core.scv import (
    MXU_VPU_RATIO,
    ROW_MAJOR,
    ZMORTON,
    bucket_caps_for,
    dense_tile_threshold,
)
from repro.simul.datasets import gcn_normalize, load, powerlaw_graph

HBM_BW = 819e9
PEAK = 197e12
MXU_RATE = 128 * 128  # MACs/cycle
VPU_RATE = int(MXU_RATE * MXU_VPU_RATIO)  # FMA lanes/cycle (8 * 128 on v5e)


def kernel_traffic(tiles, f: int, vmem_mb: float = 16.0):
    """Returns dict of byte/flop terms for one aggregation pass.

    ``a_bytes`` is reported for both capacity layouts: the single global
    cap every tile pads to, and the nnz-bucketed ladder the kernel
    actually runs (``core.scv.bucket_caps_for`` — per-bucket segments,
    per-segment cap)."""
    T, cap, nt = tiles.tile, tiles.cap, tiles.n_tiles
    a_bytes = nt * cap * (4 + 4 + 4)  # vals + rows + cols (padded, static)
    caps = bucket_caps_for(tiles.nnz_in_tile, T)
    # per-bucket tile counts without materializing the bucketed arrays
    per_bucket = np.bincount(
        np.searchsorted(caps, tiles.nnz_in_tile), minlength=len(caps)
    )
    a_bytes_bucketed = int((per_bucket * np.asarray(caps)).sum()) * (4 + 4 + 4)
    z_block = T * f * 4
    # Pallas skips the Z copy when the next tile's index map is unchanged;
    # beyond that, a VMEM-window model: a Z block is re-fetched only if not
    # among the last W distinct blocks (double-buffered working set)
    w = max(1, int(vmem_mb * 2**20 * 0.5 // z_block))
    recent: dict[int, int] = {}
    fetches = 0
    for i, c in enumerate(tiles.tile_col):
        c = int(c)
        if c not in recent or i - recent[c] > w:
            fetches += 1
        recent[c] = i
    z_bytes = fetches * z_block
    n_strips = len(np.unique(tiles.tile_row))
    ps_bytes = n_strips * T * f * 4
    flops = 2.0 * tiles.nnz * f
    return {
        "a_bytes": a_bytes, "z_bytes": z_bytes, "ps_bytes": ps_bytes,
        "a_bytes_bucketed": a_bytes_bucketed, "bucket_caps": caps,
        "total_bytes": a_bytes + z_bytes + ps_bytes,
        "total_bytes_bucketed": a_bytes_bucketed + z_bytes + ps_bytes,
        "flops": flops, "n_tiles": nt, "cap": cap,
        "pad_frac": tiles.padding_fraction,
    }


def hybrid_split(tiles, f: int):
    """Send dense-ish tiles to the MXU — the rule the kernel implements
    in-kernel (``nnz > core.scv.dense_tile_threshold(T)``; densify + one
    plain matmul).  Density is judged on LOGICAL tiles (cap-splitting
    merged back), since the MXU would consume the whole T x T tile at
    once.  Returns (cycles before, cycles after, fraction densified)."""
    T = tiles.tile
    key = tiles.tile_row.astype(np.int64) * (2**32) + tiles.tile_col
    uniq, inv = np.unique(key, return_inverse=True)
    nnz = np.zeros(len(uniq), np.int64)
    np.add.at(nnz, inv, tiles.nnz_in_tile.astype(np.int64))
    vpu_cycles = nnz * f / VPU_RATE
    mxu_cycles = (T * T * f) / MXU_RATE * np.ones(len(uniq), dtype=float)
    dense = nnz > dense_tile_threshold(T)  # == mxu_cycles < vpu_cycles
    before = float(vpu_cycles.sum())
    after = float(np.where(dense, mxu_cycles, vpu_cycles).sum())
    dense_frac = float(dense.mean())
    return before, after, dense_frac


def main():
    rows = []
    print("dataset       T    cap   bytes(GB) bkt(GB) AI(fl/B) t_mem(ms) pad%  | hybrid: VPU-cyc  mix-cyc  dense%")
    for name in ["arxiv", "cobuy_photo", "proteins"]:
        g = load(name, max_edges=250_000)
        f = 128
        best = None
        for T in [32, 64, 128, 256, 512]:
            tiles = coo_to_scv_tiles(g.adj, T)
            k = kernel_traffic(tiles, f)
            b4, aft, dfrac = hybrid_split(tiles, f)
            t_mem = k["total_bytes"] / HBM_BW * 1e3
            row = dict(dataset=name, T=T, **k, t_mem_ms=t_mem,
                       vpu_cycles=b4, hybrid_cycles=aft, dense_frac=dfrac)
            rows.append(row)
            print(f"{name:12s} {T:4d} {k['cap']:5d} {k['total_bytes']/1e9:9.3f} "
                  f"{k['total_bytes_bucketed']/1e9:7.3f} "
                  f"{k['flops']/k['total_bytes']:8.2f} {t_mem:8.3f} "
                  f"{100*k['pad_frac']:4.0f}  | {b4:12.0f} {aft:8.0f} {100*dfrac:5.1f}%")
            if best is None or k["total_bytes"] < best[1]:
                best = (T, k["total_bytes"])
        print(f"  -> best tile for {name}: T={best[0]}")
        # order ablation: row-major vs zmorton at best T
        for order in (ROW_MAJOR, ZMORTON):
            tiles = coo_to_scv_tiles(g.adj, best[0], order=order)
            k = kernel_traffic(tiles, f)
            print(f"  order={order:9s}: z_bytes={k['z_bytes']/1e9:.3f}GB "
                  f"total={k['total_bytes']/1e9:.3f}GB")
    return rows


if __name__ == "__main__":
    main()
