"""One benchmark per paper table/figure (§V).

Each ``figN_*`` function returns a list of result rows and appends to the
global CSV emitted by ``benchmarks.run`` in the required
``name,us_per_call,derived`` format (us_per_call = simulated cycles at
1 GHz in microseconds; derived = the figure's headline ratio).

Container note (EXPERIMENTS.md §Method): OGB downloads are unavailable, so
graphs are synthetic power-law matches of Table I scaled to ``MAX_EDGES``;
the paper's qualitative claims are asserted by tests/test_simulator.py and
quantified here.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import coo_to_scv_tiles, split_equal_nnz
from repro.core.formats import COOMatrix
from repro.simul import MachineConfig, geomean, load, simulate
from repro.simul.datasets import TABLE_I

MAX_EDGES = 250_000
F_DEFAULT = 128
DATASETS = list(TABLE_I)


def _cat(name):
    return TABLE_I[name].category


def _sim_all(fmts, f=F_DEFAULT, datasets=DATASETS, **kw):
    out = {}
    for name in datasets:
        g = load(name, max_edges=MAX_EDGES)
        out[name] = {fmt: simulate(g.adj, f, fmt, **kw) for fmt in fmts}
    return out


def fig7_compute_cycles():
    """Speedup in computation cycles (no memory stalls) of SCV over
    CSC/CSR/MP.  Paper: 5.03x vs CSR ultra-sparse; 36% vs CSC."""
    res = _sim_all(["csr", "csc", "mp", "scv_z"])
    rows = []
    for name, r in res.items():
        for base in ["csc", "csr", "mp"]:
            rows.append({
                "figure": "fig7", "dataset": name, "category": _cat(name),
                "baseline": base,
                "cycles_scv": r["scv_z"].compute_cycles,
                "speedup": r[base].compute_cycles / max(r["scv_z"].compute_cycles, 1),
            })
    for cat in ("ultra", "highly"):
        for base in ["csc", "csr", "mp"]:
            gs = geomean([x["speedup"] for x in rows
                          if x["category"] == cat and x["baseline"] == base])
            rows.append({"figure": "fig7", "dataset": f"geomean_{cat}",
                         "category": cat, "baseline": base, "speedup": gs})
    return rows


def fig8_idle_cycles():
    """Idle-cycle reduction vs CSR (paper: 327x ultra / 1.65x highly)."""
    res = _sim_all(["csr", "scv_z"])
    rows = []
    for name, r in res.items():
        rows.append({
            "figure": "fig8", "dataset": name, "category": _cat(name),
            "idle_csr": r["csr"].idle_cycles, "idle_scv": r["scv_z"].idle_cycles,
            "reduction": r["csr"].idle_cycles / max(r["scv_z"].idle_cycles, 1.0),
        })
    for cat in ("ultra", "highly"):
        rows.append({"figure": "fig8", "dataset": f"geomean_{cat}", "category": cat,
                     "reduction": geomean([x["reduction"] for x in rows
                                           if x.get("category") == cat])})
    return rows


def fig9_memory_traffic():
    """Processor->cache traffic reduction of SCV/SCV-Z over CSC/CSR/MP
    (paper: 4.37x CSR / 3.29x CSC on highly-sparse)."""
    res = _sim_all(["csr", "csc", "mp", "scv", "scv_z"])
    rows = []
    for name, r in res.items():
        for ours in ["scv", "scv_z"]:
            for base in ["csc", "csr", "mp"]:
                rows.append({
                    "figure": "fig9", "dataset": name, "category": _cat(name),
                    "ours": ours, "baseline": base,
                    "reduction": r[base].traffic_bytes / max(r[ours].traffic_bytes, 1),
                })
    for cat in ("ultra", "highly"):
        for base in ["csc", "csr"]:
            rows.append({
                "figure": "fig9", "dataset": f"geomean_{cat}", "category": cat,
                "ours": "scv_z", "baseline": base,
                "reduction": geomean([
                    x["reduction"] for x in rows
                    if x.get("ours") == "scv_z" and x.get("baseline") == base
                    and x["category"] == cat and not x["dataset"].startswith("geomean")
                ]),
            })
    return rows


def fig10_mat():
    """Mean DRAM access time improvement over CSR (paper: 2.48x highly)."""
    res = _sim_all(["csr", "csc", "mp", "scv_z"])
    rows = []
    for name, r in res.items():
        for fmt in ["csc", "mp", "scv_z"]:
            rows.append({
                "figure": "fig10", "dataset": name, "category": _cat(name),
                "format": fmt, "mat": r[fmt].mat,
                "improvement_vs_csr": r["csr"].mat / max(r[fmt].mat, 1e-9),
            })
    for cat in ("ultra", "highly"):
        rows.append({"figure": "fig10", "dataset": f"geomean_{cat}", "category": cat,
                     "format": "scv_z",
                     "improvement_vs_csr": geomean([
                         x["improvement_vs_csr"] for x in rows
                         if x.get("format") == "scv_z" and x["category"] == cat
                         and not x["dataset"].startswith("geomean")])})
    return rows


def fig11_overall():
    """Overall speedup incl. memory stalls (paper: 7.96x/7.04x/6.51x
    geomean over CSC/CSR/MP)."""
    res = _sim_all(["csr", "csc", "mp", "scv_z"])
    rows = []
    for name, r in res.items():
        for base in ["csc", "csr", "mp"]:
            rows.append({
                "figure": "fig11", "dataset": name, "category": _cat(name),
                "baseline": base,
                "total_scv_cycles": r["scv_z"].total_cycles,
                "speedup": r[base].total_cycles / max(r["scv_z"].total_cycles, 1),
            })
    for base in ["csc", "csr", "mp"]:
        rows.append({"figure": "fig11", "dataset": "geomean_all", "category": "all",
                     "baseline": base,
                     "speedup": geomean([x["speedup"] for x in rows
                                         if x["baseline"] == base
                                         and not x["dataset"].startswith("geomean")])})
    return rows


def fig12_height_sweep():
    """SCV vector height 128..2048 vs 128 (paper: 512/1024 best)."""
    rows = []
    for name in ["arxiv", "pubmed", "cobuy_photo", "cobuy_computer", "citeseer"]:
        g = load(name, max_edges=MAX_EDGES)
        base = simulate(g.adj, F_DEFAULT, "scv_z", height=128).total_cycles
        for h in [128, 256, 512, 1024, 2048]:
            r = simulate(g.adj, F_DEFAULT, "scv_z", height=h)
            rows.append({"figure": "fig12", "dataset": name, "height": h,
                         "speedup_vs_128": base / max(r.total_cycles, 1)})
    for h in [128, 256, 512, 1024, 2048]:
        rows.append({"figure": "fig12", "dataset": "geomean", "height": h,
                     "speedup_vs_128": geomean([x["speedup_vs_128"] for x in rows
                                                if x.get("height") == h
                                                and x["dataset"] != "geomean"])})
    return rows


def fig13_width_sweep():
    """Tile width 1..64 (paper: width 1 wins; losses grow on ultra-sparse)."""
    from repro.simul.dataflows import run_scv_width
    from repro.simul.memory import finish_memory
    from repro.simul.sim import DramConfig

    cfg, dram = MachineConfig(), DramConfig()
    rows = []
    for name in ["arxiv", "citeseer", "cobuy_photo", "proteins"]:
        g = load(name, max_edges=MAX_EDGES)
        totals = {}
        for w in [1, 2, 4, 8, 16, 32, 64]:
            comp, traffic = run_scv_width(g.adj, F_DEFAULT, cfg, height=64, width=w)
            mem = finish_memory(traffic, cfg, dram)
            totals[w] = comp.cycles + mem.stall_cycles
        for w, t in totals.items():
            rows.append({"figure": "fig13", "dataset": name, "category": _cat(name),
                         "width": w, "slowdown_vs_w1": t / totals[1]})
    return rows


def fig14_scalability():
    """2..64 processors: Z-order equal-nnz split; merge overhead from
    shared output tiles (paper: peak at 8-16 for ultra-sparse)."""
    from repro.simul.dataflows import run_scv
    from repro.simul.memory import DramConfig, finish_memory

    cfg, dram = MachineConfig(), DramConfig()
    rows = []
    dram_bw_bytes_per_cycle = 16.0  # fixed DRAM bandwidth across P (paper)
    for name in ["arxiv", "pubmed", "cobuy_photo", "reddit"]:
        g = load(name, max_edges=MAX_EDGES)
        tiles = coo_to_scv_tiles(g.adj, 512)

        def run_parts(p):
            part = split_equal_nnz(tiles, p)
            comp_max, stall_max, dram_bytes, boundary_rows = 0.0, 0.0, 0.0, 0
            seen_rows: dict[int, int] = {}
            width = part.part_tiles.shape[1]
            for i in range(p):
                ids = part.part_tiles[i]
                ids = ids[ids >= 0]
                if len(ids) == 0:
                    continue
                sub = _subset_coo(tiles, ids, g.adj.shape)
                comp, traffic = run_scv(sub, F_DEFAULT, cfg, height=512)
                mem = finish_memory(traffic, cfg, dram)
                comp_max = max(comp_max, comp.cycles)
                stall_max = max(stall_max, mem.stall_cycles)
                dram_bytes += mem.dram_bytes
                for r in np.unique(tiles.tile_row[ids]):
                    seen_rows[r] = seen_rows.get(r, 0) + 1
            merges = sum(v - 1 for v in seen_rows.values())
            merge_cycles = merges * 512 * (F_DEFAULT / cfg.n_pe + 2)
            dram_cycles = dram_bytes / dram_bw_bytes_per_cycle / max(p, 1)
            total = comp_max + stall_max + dram_cycles
            return total + merge_cycles, total
        t1, _ = run_parts(1)
        for p in [2, 4, 8, 16, 32, 64]:
            tp, tp_nomerge = run_parts(p)
            rows.append({"figure": "fig14", "dataset": name, "category": _cat(name),
                         "processors": p, "speedup": t1 / tp,
                         "speedup_no_merge": t1 / tp_nomerge})
    return rows


def _subset_coo(tiles, ids, shape):
    T = tiles.tile
    rows = (tiles.tile_row[ids, None].astype(np.int64) * T + tiles.rows[ids]).ravel()
    cols = (tiles.tile_col[ids, None].astype(np.int64) * T + tiles.cols[ids]).ravel()
    vals = tiles.vals[ids].ravel()
    keep = (np.arange(tiles.cap)[None] < tiles.nnz_in_tile[ids, None]).ravel()
    return COOMatrix(rows[keep].astype(np.int32), cols[keep].astype(np.int32),
                     vals[keep], shape)


def fig15_bcsr_blocks():
    """SCV-Z speedup over BCSR at block sizes 4..64."""
    rows = []
    for name in ["arxiv", "citeseer", "cobuy_photo"]:
        g = load(name, max_edges=MAX_EDGES)
        scv = simulate(g.adj, F_DEFAULT, "scv_z").total_cycles
        for blk in [4, 8, 16, 32, 64]:
            b = simulate(g.adj, F_DEFAULT, "bcsr", block=blk).total_cycles
            rows.append({"figure": "fig15", "dataset": name, "category": _cat(name),
                         "block": blk, "speedup": b / max(scv, 1)})
    return rows


def fig16_accelerators():
    """vs GPU (BCSR-16), AWB-GCN (CSC + ideal balancing), GCNAX (CSR +
    loop-reordered reuse).  Paper: 68.5x / 8.2x / 8.1x geomean.  These are
    processing-order emulations, as in the paper ("we emulate the function
    of the other accelerators to the best of our ability")."""
    rows = []
    for name in DATASETS:
        g = load(name, max_edges=MAX_EDGES)
        scv = simulate(g.adj, F_DEFAULT, "scv_z").total_cycles
        gpu = simulate(g.adj, F_DEFAULT, "bcsr", block=16).total_cycles
        csc = simulate(g.adj, F_DEFAULT, "csc")
        awb = csc.compute.busy / csc.compute.busy * (
            csc.compute.busy / MachineConfig().n_vpe + csc.memory.stall_cycles
        )  # ideal balance: busy/n_vpe compute + CSC memory behaviour
        csr = simulate(g.adj, F_DEFAULT, "csr")
        gcnax = csr.compute.busy / MachineConfig().n_vpe + csr.memory.stall_cycles / 2
        for base, cyc in [("gpu_bcsr16", gpu), ("awb_gcn", awb), ("gcnax", gcnax)]:
            rows.append({"figure": "fig16", "dataset": name, "category": _cat(name),
                         "baseline": base, "speedup": cyc / max(scv, 1)})
    for base in ["gpu_bcsr16", "awb_gcn", "gcnax"]:
        rows.append({"figure": "fig16", "dataset": "geomean_all", "category": "all",
                     "baseline": base,
                     "speedup": geomean([x["speedup"] for x in rows
                                         if x["baseline"] == base
                                         and not x["dataset"].startswith("geomean")])})
    return rows


ALL_FIGURES = {
    "fig7": fig7_compute_cycles,
    "fig8": fig8_idle_cycles,
    "fig9": fig9_memory_traffic,
    "fig10": fig10_mat,
    "fig11": fig11_overall,
    "fig12": fig12_height_sweep,
    "fig13": fig13_width_sweep,
    "fig14": fig14_scalability,
    "fig15": fig15_bcsr_blocks,
    "fig16": fig16_accelerators,
}
