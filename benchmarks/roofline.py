"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links x link_bw)

HLO flops/bytes come from the trip-count-corrected analyzer
(launch/hlocost.py).  MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for
train (2*N*D for single-forward shapes) gives the useful-compute ratio.
"""
from __future__ import annotations

import json

# TPU v5e per-chip constants (task spec)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_LINK_BW = 50e9  # bytes/s per link
ICI_LINKS = 2  # concurrent links per 2-D torus axis pair (stated in table)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}
TRAIN_MULT = {"train_4k": 6}  # fwd+bwd; serve shapes use 2*N*D per token


def model_flops(row: dict) -> float:
    tokens = SHAPE_TOKENS[row["shape"]]
    n = row["n_active_params"]
    mult = TRAIN_MULT.get(row["shape"], 2)
    return float(mult) * n * tokens


def roofline_row(row: dict, n_chips: int = 256) -> dict:
    t_comp = row["flops_per_device"] / PEAK_FLOPS
    t_mem = row["bytes_per_device"] / HBM_BW
    coll = sum(row["collective_bytes_per_device"].values())
    t_coll = coll / (ICI_LINKS * ICI_LINK_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(row)
    hlo_total = row["flops_per_device"] * n_chips
    return {
        "arch": row["arch"],
        "shape": row["shape"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_flop_ratio": mf / hlo_total if hlo_total else 0.0,
        # achievable fraction of the compute roofline if the dominant term
        # were fully overlapped elsewhere: T_ideal_compute / T_bound
        "roofline_fraction": t_comp / max(terms.values()) if max(terms.values()) else 0.0,
        "fits_hbm": (row["memory"]["temp_bytes"] + row["memory"]["argument_bytes"])
        <= 16 * 1024**3,
        "hbm_gb": (row["memory"]["temp_bytes"] + row["memory"]["argument_bytes"]) / 1e9,
    }


def build_table(path: str, n_chips: int = 256) -> list[dict]:
    rows = json.load(open(path))
    return [roofline_row(r, n_chips) for r in rows if r.get("status") == "ok"]


def format_table(table: list[dict]) -> str:
    hdr = (
        f"{'arch':18s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
        f"{'bound':>7s} {'useful':>7s} {'roofl%':>7s} {'HBM GB':>7s} fits"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in table:
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['t_compute_s']:9.2e} "
            f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
            f"{r['bottleneck'][:7]:>7s} {r['useful_flop_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.1f}% {r['hbm_gb']:7.1f} "
            f"{'Y' if r['fits_hbm'] else 'N'}"
        )
    return "\n".join(lines)


def main(path="results/dryrun_single_pod.json"):
    table = build_table(path)
    print(format_table(table))
    return table


if __name__ == "__main__":
    main()
