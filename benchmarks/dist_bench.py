"""Distributed executor benchmark: sharded SCV aggregation on a forced
8-host-device mesh (DESIGN.md §5).

The PR gate for the unified plan-executor rework: on the serving-scale
sparse regime (131k nodes, 1M power-law edges — the `sparse_graph` record
of BENCH_kernel.json), an nnz-bucketed plan placed by
``core.exec.PlanExecutor`` must

* match the single-device bucketed result **bit for bit** under tile-span,
  feature-axis, and 2-D sharding (integer-valued inputs: every partial sum
  is exactly representable in f32, so psum reassociation cannot change
  bits),
* keep the equal-nnz span split balanced (imbalance < IMBALANCE_GATE —
  the paper's §V-G fine-grain claim), and
* stay within MAX_OVERHEAD x of the single-device bucketed wall time (the
  no-regression gate: the 8 "devices" here are XLA host-platform fakes
  time-slicing ONE CPU, so the sharded path cannot be faster — the gate
  bounds the placement + collective overhead that a real mesh would
  amortize across real chips).

Results land in ``BENCH_dist.json`` (repo root) and as
``name,us_per_call,derived`` CSV rows matching benchmarks/run.py.

    PYTHONPATH=src python benchmarks/dist_bench.py
"""
from __future__ import annotations

import os

# append (not setdefault): an inherited XLA_FLAGS must not silently leave
# this bench on one device — the 8-part placements would then error out
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

import json
import pathlib
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate_scv_plan
from repro.core.exec import PlanExecutor, ShardingDecision, placement_bytes
from repro.core.scv import (
    bucket_caps_for,
    coo_to_scv_tiles,
    plan_from_tiles_bucketed,
    tile_nnz_histogram,
)
from repro.simul.datasets import powerlaw_graph
from repro.tune import plan_launched_slots

N_NODES = 1 << 17
N_EDGES = 1_000_000
TILE = 64
FEATURES = 64
REPS = 2
IMBALANCE_GATE = 1.5
#: Sharded-on-fake-devices wall time may not exceed this multiple of the
#: single-device bucketed time (8 fakes time-slice one CPU; the collective
#: and dispatch overhead is what this bounds).
MAX_OVERHEAD = 6.0
#: Gates on the feature-axis placement specifically.  Pad-once Z slabs +
#: skipping the psum at tile_parts == 1 cut the features wall time ~14%
#: (5.14s -> ~4.4s on this host), but coverage-free plans sped the
#: single-device denominator even more (1.71s -> ~1.35s), so the *ratio*
#: sits near 2.8-3.4 with CPU time-slicing noise: each of the 8 fake
#: devices repeats the full O(nnz) index walk on its narrow slab, which
#: the feature axis cannot divide — parity with the t8f1 tile placement
#: is not reachable in emulation.  The ratio gate bounds regression; the
#: absolute gate holds the measured wall-time win on this host.
FEATURES_OVERHEAD_GATE = 3.6
FEATURES_SECONDS_GATE = 5.0
#: Resident-bytes act/pred window.  The byte model prices *launched*
#: capacity slots (``placement_bytes(..., n_slots=...)``), not logical
#: nnz, so the old 1.11x (tiles) / 3.79x (features) optimism collapses
#: to the residual slop of integer tile-boundary splits: observed
#: ratios on this regime are ~1.008 (t8), 1.000 exactly (f8 — the plan
#: is unsplit, so modeled slots == placed slots), ~1.019 (2d).
VMEM_ACT_PRED_GATE = (0.95, 1.10)

DECISIONS = (
    ShardingDecision("tiles", 8, 1),
    ShardingDecision("features", 1, 8),
    ShardingDecision("2d", 4, 2),
)


def bench(fn, *args) -> float:
    fn(*args).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> int:
    adj = powerlaw_graph(N_NODES, N_EDGES, seed=0)
    # small integer weights/features: bit-identical under any reassociation
    rng = np.random.default_rng(0)
    adj.vals[:] = rng.integers(1, 4, size=adj.nnz).astype(np.float32)
    # derive the ladder BEFORE tiling (as build_graph(bucket_caps="auto")
    # does): auto-cap tiling first would chain-split everything to the
    # smallest cap and collapse the ladder to one segment, and this gate
    # exists precisely to exercise the multi-segment sharded path (one
    # psum across all segments)
    caps = bucket_caps_for(tile_nnz_histogram(adj, TILE), TILE)
    tiles = coo_to_scv_tiles(adj, TILE, cap=caps[-1])
    plan = plan_from_tiles_bucketed(tiles, caps=caps)
    assert len(plan.segments) > 1, f"gate needs a multi-segment plan, got {caps}"
    z = jnp.asarray(
        rng.integers(-3, 4, size=(adj.shape[1], FEATURES)).astype(np.float32)
    )

    agg = jax.jit(lambda p, zz: aggregate_scv_plan(p, zz, backend="jnp"))
    t_single = bench(agg, plan, z)
    single = np.asarray(agg(plan, z))

    ex = PlanExecutor()
    rows = []
    print("name,us_per_call,derived")
    print(f"dist_single_bucketed,{t_single * 1e6:.0f},"
          f"{adj.nnz / t_single / 1e6:.1f} Mnnz/s")
    for dec in DECISIONS:
        sp = ex.prepare(plan, decision=dec)
        t = bench(agg, sp, z)
        out = np.asarray(agg(sp, z))
        exact = bool(np.array_equal(out, single))
        imb = sp.imbalance
        # VMEM model check: predicted per-device resident bytes (the
        # ShardingDecision cost model) vs the placed plan's actual
        # leaves.  ``n_slots`` makes the model price launched capacity
        # slots (chain splits, remainder buckets, coverage dummies) the
        # way the built plan does, so act/pred must sit near 1.0; the
        # residual is per-device rounding when a span split lands
        # mid-bucket.
        pred = placement_bytes(
            int(adj.nnz), FEATURES, dec.tile_parts, dec.feature_parts,
            n_rows=N_NODES, n_slots=plan_launched_slots(plan),
        )
        actual_plan = sum(
            seg.rows.nbytes + seg.cols.nbytes + seg.vals.nbytes
            for seg in sp.segments
        ) / dec.tile_parts
        actual = {
            "plan": actual_plan,
            "z_slab": z.nbytes / dec.feature_parts,
            "out": N_NODES * FEATURES * 4 / dec.feature_parts,
        }
        actual["resident"] = sum(actual.values())
        rows.append({
            "decision": dec.signature,
            "seconds": t,
            "overhead_vs_single": t / t_single,
            "bit_exact": exact,
            "imbalance": imb,
            "imbalance_per_segment": list(sp.imbalance_per_segment),
            "vmem_predicted_bytes": {
                k: pred[k] for k in ("plan", "z_slab", "out", "resident")
            },
            "vmem_actual_bytes": actual,
            "vmem_actual_over_predicted":
                actual["resident"] / pred["resident"],
        })
        print(f"dist_{dec.kind},{t * 1e6:.0f},"
              f"x{t / t_single:.2f} vs single; imb {imb:.3f}; "
              f"exact {exact}; vmem act/pred "
              f"{actual['resident'] / pred['resident']:.2f}")

    payload = {
        "n_nodes": N_NODES,
        "n_edges": N_EDGES,
        "tile": TILE,
        "features": FEATURES,
        "caps": list(plan.caps),
        "n_devices": len(jax.devices()),
        "single_bucketed_seconds": t_single,
        "max_overhead_gate": MAX_OVERHEAD,
        "features_overhead_gate": FEATURES_OVERHEAD_GATE,
        "features_seconds_gate": FEATURES_SECONDS_GATE,
        "imbalance_gate": IMBALANCE_GATE,
        "vmem_act_pred_gate": list(VMEM_ACT_PRED_GATE),
        "placements": rows,
    }
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dist.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out_path}")

    ok = all(r["bit_exact"] for r in rows)
    ok = ok and all(r["imbalance"] < IMBALANCE_GATE for r in rows)
    ok = ok and max(r["overhead_vs_single"] for r in rows) <= MAX_OVERHEAD
    feat = next(r for r in rows if r["decision"].startswith("features"))
    ok = ok and feat["overhead_vs_single"] <= FEATURES_OVERHEAD_GATE
    ok = ok and feat["seconds"] <= FEATURES_SECONDS_GATE
    lo, hi = VMEM_ACT_PRED_GATE
    ok = ok and all(
        lo <= r["vmem_actual_over_predicted"] <= hi for r in rows
    )
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
