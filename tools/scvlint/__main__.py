"""``python -m tools.scvlint [paths...]`` — see tools/scvlint/__init__.py."""
import sys

from tools.scvlint import main

if __name__ == "__main__":
    sys.exit(main())
