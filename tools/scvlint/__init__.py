"""scvcheck leg 3: repo-specific AST lint rules generic tools can't express.

Rules (all stdlib ``ast`` + ``tokenize``; no third-party dependency):

* **SCV001 np-in-traced** — no ``np.*`` calls inside jitted or Pallas
  kernel bodies.  A numpy call under a trace either crashes on tracers
  or silently constant-folds host-side values into the compiled program.
  "Traced" functions are detected structurally: decorated with
  ``jax.jit`` / ``jax.custom_vjp`` (including ``functools.partial``
  forms), wrapped by a module-level ``name = jax.jit(fn, ...)``,
  registered via ``X.defvjp(fwd, bwd)``, referenced inside a
  ``pallas_call``, or named ``_kernel*`` (the kernel-body idiom in
  ``kernels/scv_spmm``).
* **SCV002 magic-constant** — no literals duplicating the kernel-model
  constants that ``core/scv.py`` owns: the MXU/VPU ratio (``1/16`` /
  ``0.0625`` — import ``MXU_VPU_RATIO``) and chunk-size bindings whose
  name contains ``chunk`` assigned a bare ``128`` (import
  ``DEFAULT_CHUNK``).  Inside ``src/repro/`` the rule further rejects
  re-declared *tunable* plan constants: ``tile`` / ``cap`` bindings or
  parameter defaults with integer literals, and ``bucket_caps`` /
  ``*ladder*`` bindings with literal int tuples — these may only be
  introduced via the ``core/scv.py`` defaults (``DEFAULT_TILE`` /
  ``DEFAULT_CAP`` / ``DEFAULT_LADDER``) or a threaded
  ``repro.tune.TunedConfig`` (``tune/config.py`` is the other exempt
  owner).  Benchmarks and tests sweep candidate values by design and
  stay out of scope.  Drift between the roofline model and the kernel
  is exactly how a "tuned" constant silently stops matching hardware.
* **SCV003 nondiff-plan** — no ``nondiff_argnums`` positions naming
  plan-leaf parameters (``tile_row`` / ``rows`` / ``vals`` / ``perm``
  ...).  Plan leaves arrive as tracers under the end-to-end jitted
  forward; ``nondiff_argnums`` rejects tracers at call time (the PR 3
  regression this rule fossilizes).
* **SCV004 shim-hygiene** — every ``try/except ImportError`` shim whose
  body imports from ``jax`` must carry a version-pin audit comment
  (``# ... jax >= 0.6 ...``) within the preceding 3 lines or the try
  body, so the ROADMAP housekeeping sweep can drop shims by grepping
  pins instead of re-auditing code.
* **SCV005 no-unroll-fori** — no ``unroll=`` keyword on
  ``jax.lax.fori_loop``: jax (0.4.x and current) raises ``ValueError``
  for unrolled loops with traced bounds, and kernel trip counts are
  prefetched data (the PR 2 breakage this rule fossilizes).
* **SCV006 stream-no-rebuild** — no full-rebuild entry points
  (``coo_to_scv_tiles`` / ``plan_from_tiles`` /
  ``plan_from_tiles_bucketed`` / ``build_graph``) called inside
  ``src/repro/stream/``.  The delta package exists to *patch* plans in
  sub-rebuild time; a rebuild call hiding inside it silently converts
  the O(delta) contract back into the O(nnz) path it replaces.  Tests
  and benchmarks rebuild freely — the rule is scoped to the package.
* **SCV007 queue-ownership** — no direct ``self.queue`` mutation inside
  ``src/repro/serve/`` outside the scheduler/intake module
  (``serve/scheduler.py``).  The intake queue is the single place where
  admission control, backpressure, and deadline accounting happen; an
  append or slice-assignment that bypasses it silently exempts those
  requests from every admission policy.  Both rebinding
  (``self.queue = ...``, slice/index assignment, ``del``) and mutating
  method calls (``append`` / ``extend`` / ``pop`` / ...) fire.  The
  legacy LM ``serve/engine.py`` loop predates the rule and is
  baselined.

Suppression: append ``# scvlint: ignore[SCV00N]`` (or a bare
``# scvlint: ignore``) to the offending line.  Pre-existing violations
live in ``baseline.txt`` next to this file — matched by (path, rule,
stripped source line) so line-number drift doesn't resurrect them; new
violations fail the run.  Regenerate with ``--write-baseline``.

Run as ``python -m tools.scvlint src/`` (wired into ``scripts/lint.sh``
and ``scripts/ci.sh``).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import io
import os
import re
import sys
import tokenize

RULES = {
    "SCV001": "np.* call inside a jitted/Pallas-traced body",
    "SCV002": "literal duplicates a core/scv.py kernel-model constant",
    "SCV003": "nondiff_argnums names a plan-leaf parameter",
    "SCV004": "jax import shim lacks a version-pin audit comment",
    "SCV005": "fori_loop(unroll=) raises with traced bounds",
    "SCV006": "full plan rebuild called inside src/repro/stream/",
    "SCV007": "direct self.queue mutation outside the scheduler/intake module",
}

#: Mutating container methods that bypass intake admission when called on
#: ``self.queue`` directly (SCV007).
QUEUE_MUTATORS = frozenset(
    {"append", "extend", "insert", "pop", "remove", "clear", "sort",
     "reverse", "appendleft", "popleft"}
)

#: Full-rebuild entry points the stream/ delta package must never call
#: (SCV006) — patching that falls back to a rebuild is a silent
#: O(delta) -> O(nnz) regression.
REBUILD_ENTRY_POINTS = frozenset(
    {"coo_to_scv_tiles", "plan_from_tiles", "plan_from_tiles_bucketed",
     "build_graph"}
)

#: SCVPlan / SCVTiles leaf parameter names (SCV003).
PLAN_LEAF_NAMES = frozenset(
    {"tile_row", "tile_col", "rows", "cols", "vals", "nnz_in_tile", "perm",
     "plan", "segments"}
)

_PIN_RE = re.compile(r"jax\s*[<>=!]=?\s*v?\d")
_IGNORE_RE = re.compile(r"#\s*scvlint:\s*ignore(?:\[(?P<rules>[A-Z0-9, ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str  # repo-relative, forward slashes
    line: int
    col: int
    rule: str
    message: str
    source_line: str  # stripped — the baseline identity

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    @property
    def baseline_key(self) -> str:
        return f"{self.path}|{self.rule}|{self.source_line}"


# ---------------------------------------------------------------------------
# helpers over the AST
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``jax.lax.fori_loop``)."""
    if isinstance(node, ast.Attribute):
        return f"{_dotted(node.value)}.{node.attr}"
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _collect_traced_functions(tree: ast.Module, rel: str = "") -> set[str]:
    """Names of functions whose bodies run under a jax trace (SCV001)."""
    traced: set[str] = set()
    defvjp_args: set[str] = set()
    jit_wrapped: set[str] = set()
    pallas_refs: set[str] = set()

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = _dotted(node.func)
            last = fn.rsplit(".", 1)[-1]
            if last == "defvjp":
                for a in node.args:
                    defvjp_args |= _names_in(a)
            # Wrapping sites only: `jax.jit(fn)` / `pl.pallas_call(body)`.
            # A *call of* a jitted function (`foo_jit(...)`) does not drag
            # its arguments under the trace at definition level.
            if last in ("jit", "pallas_call"):
                for a in list(node.args) + [k.value for k in node.keywords]:
                    (pallas_refs if last == "pallas_call" else jit_wrapped).update(
                        _names_in(a)
                    )
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # The `_kernel*` naming idiom marks Pallas bodies, but only
            # inside the kernels/ tree (benchmarks reuse the prefix for
            # host-side drivers).
            if node.name.startswith("_kernel") and "kernels/" in rel:
                traced.add(node.name)
            for dec in node.decorator_list:
                parts = set(_dotted(dec).split("."))
                if parts & {"jit", "custom_vjp"}:
                    traced.add(node.name)
                # functools.partial(jax.jit, ...) / partial(jax.custom_vjp, ...)
                if isinstance(dec, ast.Call):
                    for a in dec.args:
                        if set(_dotted(a).split(".")) & {"jit", "custom_vjp"}:
                            traced.add(node.name)
    return traced | defvjp_args | jit_wrapped | pallas_refs


def _function_defs(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _int_literal(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _int_literal(node.operand)
        return None if v is None else -v
    return None


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------
class FileChecker:
    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.comments: dict[int, str] = {}
        self.ignores: dict[int, set[str] | None] = {}  # None = all rules
        self._scan_comments()

    def _scan_comments(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
                    m = _IGNORE_RE.search(tok.string)
                    if m:
                        rules = m.group("rules")
                        self.ignores[tok.start[0]] = (
                            {r.strip() for r in rules.split(",")} if rules
                            else None
                        )
        except tokenize.TokenError:
            pass

    def _line(self, n: int) -> str:
        return self.lines[n - 1].strip() if 0 < n <= len(self.lines) else ""

    def _emit(self, out: list[Violation], node: ast.AST, rule: str, msg: str):
        line = getattr(node, "lineno", 1)
        ig = self.ignores.get(line, ...)
        if ig is None or (ig is not ... and rule in ig):
            return
        out.append(
            Violation(
                path=self.rel, line=line,
                col=getattr(node, "col_offset", 0) + 1,
                rule=rule, message=msg, source_line=self._line(line),
            )
        )

    def check(self) -> list[Violation]:
        try:
            tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as e:
            return [
                Violation(
                    path=self.rel, line=e.lineno or 1, col=e.offset or 1,
                    rule="SCV000", message=f"syntax error: {e.msg}",
                    source_line=self._line(e.lineno or 1),
                )
            ]
        out: list[Violation] = []
        self._check_np_in_traced(tree, out)
        self._check_magic_constants(tree, out)
        self._check_nondiff_plan(tree, out)
        self._check_shim_hygiene(tree, out)
        self._check_fori_unroll(tree, out)
        self._check_stream_no_rebuild(tree, out)
        self._check_queue_ownership(tree, out)
        return out

    # -- SCV001 ------------------------------------------------------------
    def _check_np_in_traced(self, tree: ast.Module, out: list[Violation]):
        traced = _collect_traced_functions(tree, self.rel.replace("\\", "/"))
        for fn in _function_defs(tree):
            if fn.name not in traced:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = _dotted(node.func)
                if d.startswith(("np.", "numpy.")):
                    self._emit(
                        out, node, "SCV001",
                        f"`{d}` called inside traced body `{fn.name}` — "
                        "use jnp/lax, or mark a deliberate host-side "
                        "constant with `# scvlint: ignore[SCV001]`",
                    )

    # -- SCV002 ------------------------------------------------------------
    def _check_magic_constants(self, tree: ast.Module, out: list[Violation]):
        rel = self.rel.replace("\\", "/")
        if rel.endswith(("core/scv.py", "tune/config.py")):
            return  # the owners of the constants
        # The tunable plan constants (tile / cap / ladder) are policed
        # inside src/repro/ only: benchmarks and tests sweep candidate
        # values by design (serve_bench ladder A/B, kernel_bench TILE).
        tunable_scope = "src/repro/" in rel or rel.startswith("repro/")
        for node in ast.walk(tree):
            # 1/16 or 1.0/16.0 → MXU_VPU_RATIO
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
                lv = getattr(node.left, "value", None)
                rv = getattr(node.right, "value", None)
                if lv in (1, 1.0) and rv in (16, 16.0):
                    self._emit(
                        out, node, "SCV002",
                        "`1/16` duplicates core.scv.MXU_VPU_RATIO — import it",
                    )
            if isinstance(node, ast.Constant) and node.value == 1 / 16:  # scvlint: ignore[SCV002]
                self._emit(
                    out, node, "SCV002",
                    "`0.0625` duplicates core.scv.MXU_VPU_RATIO — import it",
                )
            # <name containing 'chunk'> = 128 → DEFAULT_CHUNK
            targets: list[tuple[str, ast.AST]] = []
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        targets.append((t.id, node.value))
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if isinstance(node.target, ast.Name):
                    targets.append((node.target.id, node.value))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                pos = args.posonlyargs + args.args
                for a, dflt in zip(pos[len(pos) - len(args.defaults):],
                                   args.defaults):
                    targets.append((a.arg, dflt))
                for a, dflt in zip(args.kwonlyargs, args.kw_defaults):
                    if dflt is not None:
                        targets.append((a.arg, dflt))
            for name, value in targets:
                low = name.lower()
                if "chunk" in low and _int_literal(value) == 128:
                    self._emit(
                        out, value, "SCV002",
                        f"`{name} = 128` duplicates core.scv.DEFAULT_CHUNK — "
                        "import it",
                    )
                if not tunable_scope:
                    continue
                # tunable plan constants may only be introduced through
                # core/scv.py defaults or a threaded TunedConfig — a
                # re-declared literal is exactly the drift the autotuner
                # exists to eliminate
                if low in ("tile", "cap") and _int_literal(value) is not None:
                    self._emit(
                        out, value, "SCV002",
                        f"`{name} = {_int_literal(value)}` re-declares a "
                        f"tunable plan constant — import "
                        f"core.scv.DEFAULT_{low.upper()} or thread a "
                        "repro.tune.TunedConfig",
                    )
                if ("bucket_caps" in low or "ladder" in low) and isinstance(
                    value, (ast.Tuple, ast.List)
                ):
                    if value.elts and all(
                        _int_literal(e) is not None for e in value.elts
                    ):
                        self._emit(
                            out, value, "SCV002",
                            f"`{name} = (...)` re-declares a capacity "
                            "ladder — import core.scv.DEFAULT_LADDER or "
                            "thread a repro.tune.TunedConfig",
                        )

    # -- SCV003 ------------------------------------------------------------
    def _check_nondiff_plan(self, tree: ast.Module, out: list[Violation]):
        for fn in _function_defs(tree):
            for dec in fn.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                d = _dotted(dec)
                inner = " ".join(_dotted(a) for a in dec.args)
                if "custom_vjp" not in d and "custom_vjp" not in inner:
                    continue
                for kw in dec.keywords:
                    if kw.arg != "nondiff_argnums":
                        continue
                    nums = []
                    if isinstance(kw.value, (ast.Tuple, ast.List)):
                        nums = [
                            v for v in
                            (_int_literal(e) for e in kw.value.elts)
                            if v is not None
                        ]
                    else:
                        v = _int_literal(kw.value)
                        nums = [v] if v is not None else []
                    params = _positional_params(fn)
                    bad = [
                        params[i] for i in nums
                        if 0 <= i < len(params) and params[i] in PLAN_LEAF_NAMES
                    ]
                    if bad:
                        self._emit(
                            out, dec, "SCV003",
                            f"nondiff_argnums marks plan leaf param(s) "
                            f"{bad} on `{fn.name}` — plan leaves arrive as "
                            "tracers under the jitted forward; carry them "
                            "as residuals with float0 cotangents instead",
                        )

    # -- SCV004 ------------------------------------------------------------
    def _check_shim_hygiene(self, tree: ast.Module, out: list[Violation]):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Try):
                continue
            catches_import = any(
                "ImportError" in _names_in(h.type) if h.type is not None else False
                for h in node.handlers
            ) or any(
                isinstance(h.type, ast.Attribute) and h.type.attr == "ImportError"
                for h in node.handlers if h.type is not None
            )
            if not catches_import:
                continue
            imports_jax = any(
                isinstance(s, (ast.Import, ast.ImportFrom))
                and any(
                    (getattr(s, "module", None) or "").split(".")[0] == "jax"
                    or (isinstance(s, ast.Import)
                        and any(a.name.split(".")[0] == "jax" for a in s.names))
                    for _ in (0,)
                )
                for s in node.body
            )
            if not imports_jax:
                continue
            lo = max(1, node.lineno - 3)
            hi = max(
                (getattr(s, "end_lineno", s.lineno) for s in node.body),
                default=node.lineno,
            )
            pinned = any(
                _PIN_RE.search(self.comments.get(ln, ""))
                for ln in range(lo, hi + 1)
            )
            if not pinned:
                self._emit(
                    out, node, "SCV004",
                    "jax import shim without a version-pin audit comment — "
                    "add e.g. `# jax >= 0.6 re-homes X; drop the except "
                    "branch once the image moves` near the try",
                )

    # -- SCV005 ------------------------------------------------------------
    def _check_fori_unroll(self, tree: ast.Module, out: list[Violation]):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _dotted(node.func).endswith(
                "fori_loop"
            ):
                for kw in node.keywords:
                    if kw.arg == "unroll":
                        self._emit(
                            out, node, "SCV005",
                            "fori_loop(unroll=) raises ValueError with "
                            "traced bounds (jax 0.4.x and current); kernel "
                            "trip counts are prefetched data — drop it",
                        )

    # -- SCV006 ------------------------------------------------------------
    def _check_stream_no_rebuild(self, tree: ast.Module, out: list[Violation]):
        rel = self.rel.replace("\\", "/")
        if "repro/stream/" not in rel:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            last = _dotted(node.func).rsplit(".", 1)[-1]
            if last in REBUILD_ENTRY_POINTS:
                self._emit(
                    out, node, "SCV006",
                    f"`{last}` is a full O(nnz) plan rebuild — stream/ "
                    "patches plans in O(delta); splice the change in "
                    "instead of rebuilding",
                )

    # -- SCV007 ------------------------------------------------------------
    def _check_queue_ownership(self, tree: ast.Module, out: list[Violation]):
        rel = self.rel.replace("\\", "/")
        if "repro/serve/" not in rel or rel.endswith("serve/scheduler.py"):
            return

        def root_is_self_queue(node: ast.AST) -> bool:
            # peel subscripts: `self.queue[0] = ...`, `del self.queue[:]`
            while isinstance(node, ast.Subscript):
                node = node.value
            return (
                isinstance(node, ast.Attribute)
                and node.attr == "queue"
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
            )

        msg = (
            "direct `self.queue` mutation bypasses intake admission "
            "(backpressure, deadline accounting) — go through "
            "serve.scheduler.IntakeQueue"
        )
        for node in ast.walk(tree):
            targets: list[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = list(node.targets)
            for t in targets:
                subs = (
                    list(ast.walk(t))
                    if isinstance(t, (ast.Tuple, ast.List)) else [t]
                )
                if any(root_is_self_queue(s) for s in subs):
                    self._emit(out, node, "SCV007", msg)
                    break
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in QUEUE_MUTATORS
                and root_is_self_queue(node.func.value)
            ):
                self._emit(out, node, "SCV007", msg)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def _iter_py_files(paths: list[str]):
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs if d not in
                           ("__pycache__", ".git", ".venv", "node_modules")]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def check_paths(paths: list[str], repo_root: str | None = None) -> list[Violation]:
    root = os.path.abspath(repo_root or os.getcwd())
    out: list[Violation] = []
    for path in _iter_py_files(paths):
        rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except (OSError, UnicodeDecodeError) as e:
            print(f"scvlint: cannot read {path}: {e}", file=sys.stderr)
            continue
        out.extend(FileChecker(path, rel, source).check())
    return sorted(out, key=lambda v: (v.path, v.line, v.col, v.rule))


def check_source(source: str, rel: str = "<string>") -> list[Violation]:
    """Lint a source string (the unit-test entry point)."""
    return FileChecker(rel, rel, source).check()


DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.txt")


def load_baseline(path: str) -> set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        return {
            line.rstrip("\n") for line in f
            if line.strip() and not line.startswith("#")
        }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="scvlint",
        description="SCV-GNN repo-specific lint (see tools/scvlint).",
    )
    ap.add_argument("paths", nargs="*", default=["src"])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--no-baseline", action="store_true",
        help="report every violation, including baselined ones",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="accept current violations as the new baseline",
    )
    args = ap.parse_args(argv)

    violations = check_paths(args.paths or ["src"])
    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w", encoding="utf-8") as f:
            f.write(
                "# scvlint baseline — pre-existing violations that do not\n"
                "# fail the run.  One `path|rule|stripped source line` per\n"
                "# entry; regenerate with `python -m tools.scvlint "
                "--write-baseline`.\n"
            )
            for key in sorted({v.baseline_key for v in violations}):
                f.write(key + "\n")
        print(f"scvlint: wrote {len(violations)} violation(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    fresh = [v for v in violations if v.baseline_key not in baseline]
    old = len(violations) - len(fresh)
    for v in fresh:
        print(v.format())
    if fresh:
        print(
            f"scvlint: {len(fresh)} new violation(s)"
            + (f" ({old} baselined)" if old else "")
        )
        return 1
    print(
        "scvlint: clean"
        + (f" ({old} baselined violation(s) tolerated)" if old else "")
        + f" — checked {len(RULES)} rule(s)"
    )
    return 0
